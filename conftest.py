"""Pytest shim: allow `pytest python/tests/` from the repo root by
putting `python/` (the package root for `compile` and `tests`) on the
path. The Makefile's `cd python && pytest tests/` needs nothing, but the
repo-root invocation is what CI-style drivers use."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))
