//! Design-space exploration (paper §4.1's future work: "Determining the
//! optimal RH_m for a given model and platform"): sweep RH_m across FPGA
//! devices and report the latency/resource trade-off, plus the PWL
//! segment-count accuracy trade-off of the activation unit.
//!
//! ```bash
//! cargo run --release --example design_space -- --model F64-D6 --timesteps 64
//! ```

use lstm_ae_accel::accel::energy::{energy_per_timestep_mj, fpga_power_w};
use lstm_ae_accel::accel::latency::LatencyModel;
use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::resources::{estimate, min_fitting_rh_m};
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::activations::{ActKind, Pwl};
use lstm_ae_accel::model::Topology;
use lstm_ae_accel::util::cli::Args;
use lstm_ae_accel::util::table::Table;

fn main() {
    let args = Args::from_env();
    let model = args.get_or("model", "F64-D6");
    let t = args.get_usize("timesteps", 64);
    let topo = Topology::from_name(model).expect("model name");

    // ---- RH_m sweep on the paper's device -------------------------------
    let dev = FpgaDevice::ZCU104;
    let mut table = Table::new(&format!("RH_m design space for {} on {} (T={t})", topo.name, dev.name))
        .header(&["RH_m", "Lat (ms)", "E/t (mJ)", "LUT%", "BRAM%", "DSP%", "mults", "fits"]);
    for rh_m in [1u64, 2, 4, 8, 16, 32] {
        let cfg = BalancedConfig::balance(&topo, rh_m);
        let lm = LatencyModel::of(&cfg);
        let usage = estimate(&cfg);
        let pct = usage.pct(&dev);
        let lat = lm.acc_lat_ms(t, dev.clock_hz);
        let e = energy_per_timestep_mj(fpga_power_w(&pct, &dev), lat, t);
        table.row(vec![
            rh_m.to_string(),
            format!("{lat:.4}"),
            format!("{e:.4}"),
            format!("{:.1}", pct.lut),
            format!("{:.1}", pct.bram),
            format!("{:.1}", pct.dsp),
            cfg.total_multipliers().to_string(),
            if usage.fits(&dev) { "yes".into() } else { "NO".into() },
        ]);
    }
    print!("{}", table.render());

    // ---- device portability (the §4.1 embedded-device claim) ------------
    let mut table = Table::new("Minimum fitting RH_m per device (all paper models)")
        .header(&["Device", "F32-D2", "F64-D2", "F32-D6", "F64-D6"]);
    for dev in FpgaDevice::catalog() {
        let mut row = vec![dev.name.to_string()];
        for topo in Topology::paper_models() {
            row.push(match min_fitting_rh_m(&topo, dev, 512) {
                Some((rh_m, _)) => {
                    let lm = LatencyModel::of(&BalancedConfig::balance(&topo, rh_m));
                    format!("{rh_m} ({:.3} ms)", lm.acc_lat_ms(t, dev.clock_hz))
                }
                None => "-".into(),
            });
        }
        table.row(row);
    }
    print!("{}", table.render());

    // ---- PWL activation unit accuracy vs size ----------------------------
    let mut table = Table::new("PWL activation design space (max |error| vs exact)")
        .header(&["Segments", "sigmoid", "tanh", "BRAM words"]);
    for segs in [16usize, 32, 64, 128, 256, 512] {
        let sig = Pwl::new(ActKind::Sigmoid, segs).max_error(40_000);
        let tanh = Pwl::new(ActKind::Tanh, segs).max_error(40_000);
        table.row(vec![
            segs.to_string(),
            format!("{sig:.2e}"),
            format!("{tanh:.2e}"),
            (2 * (segs + 1)).to_string(),
        ]);
    }
    print!("{}", table.render());
    println!("(paper §4.1 uses PWL sigmoid/tanh; we default to 128 segments: tanh error");
    println!(" ~1.4e-3, below the Q8.24 datapath's compounded rounding on deep models.)");
}
