//! END-TO-END VALIDATION (DESIGN.md §3, experiment V2): the full system
//! on a real small workload, proving all layers compose:
//!
//!   L2/L1 (build time): the LSTM-AE was trained in JAX on synthetic
//!   benign telemetry and AOT-lowered (Pallas cell kernel → scan → HLO
//!   text) into `artifacts/`.
//!   L3 (this binary): loads the artifact via PJRT, calibrates an anomaly
//!   threshold on benign traffic, then serves a Poisson stream of
//!   telemetry windows through the dynamic batcher, reporting
//!   latency/throughput and detection quality, and cross-checks the
//!   quantized (FPGA-datapath) scores against the f32 artifact scores.
//!
//! ```bash
//! make artifacts && cargo run --release --example anomaly_detection
//! ```
//! (falls back to the bit-accurate Q8.24 golden model when artifacts are
//! missing, so the example always runs.)

use std::sync::Arc;

use lstm_ae_accel::model::{LstmAutoencoder, ModelWeights, Topology};
use lstm_ae_accel::server::{
    calibrate_threshold, AnomalyServer, Backend, PjrtBackend, QuantBackend, ServerConfig,
};
use lstm_ae_accel::util::cli::Args;
use lstm_ae_accel::util::table::Table;
use lstm_ae_accel::workload::{trace::poisson_trace, TelemetryGen};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    let args = Args::from_env();
    let model = args.get_or("model", "F32-D2").to_string();
    let t = args.get_usize("timesteps", 16);
    let n = args.get_usize("requests", 2000);
    let rate = args.get_f64("rate", 4000.0);
    let anomaly_rate = args.get_f64("anomaly-rate", 0.15);
    let topo = Topology::from_name(&model).expect("model");

    // ---- backend: AOT artifact via PJRT, golden model as fallback -------
    let backend: Arc<dyn Backend> = match PjrtBackend::new(artifacts_dir(), &model, t) {
        Ok(b) => {
            println!("backend: {} (AOT artifact, python-free request path)", b.name());
            Arc::new(b)
        }
        Err(e) => {
            println!("backend: quant golden model (no artifacts: {e})");
            // Use trained weights if present even without HLO artifacts.
            let w_path = artifacts_dir().join(format!("weights_{}.bin", topo.name));
            let ae = match ModelWeights::load(&w_path) {
                Ok(w) => LstmAutoencoder::new(topo.clone(), w).expect("weights"),
                Err(_) => LstmAutoencoder::random(topo.clone(), 7),
            };
            Arc::new(QuantBackend::new(ae))
        }
    };

    // ---- telemetry: stream the family the model was trained on ----------
    let spec_path = artifacts_dir().join(format!("telemetry_F{}.json", topo.features));
    let mk_gen = |seed: u64| -> TelemetryGen {
        TelemetryGen::from_spec_file(&spec_path, seed)
            .unwrap_or_else(|_| TelemetryGen::new(topo.features, seed))
    };

    // ---- threshold calibration on benign traffic -------------------------
    let mut gen = mk_gen(21);
    let benign_scores: Vec<f64> =
        (0..128).map(|_| backend.score_batch(&[&gen.benign_window(t)])[0]).collect();
    let threshold = calibrate_threshold(&benign_scores, 0.99);
    println!(
        "calibrated threshold: {threshold:.6} (benign p50 {:.6})",
        lstm_ae_accel::util::stats::Summary::of(&benign_scores).p50
    );

    // ---- quantization cross-check (FPGA datapath vs f32 artifact) --------
    if let Ok(w) = ModelWeights::load(&artifacts_dir().join(format!("weights_{}.bin", topo.name)))
    {
        let ae = LstmAutoencoder::new(topo.clone(), w).expect("weights");
        let mut agree = 0usize;
        let total = 64usize;
        let mut gen2 = mk_gen(33);
        for i in 0..total {
            let w = if i % 3 == 0 {
                gen2.anomalous_window(t, lstm_ae_accel::workload::AnomalyKind::Spike)
            } else {
                gen2.benign_window(t)
            };
            let f32_dec = backend.score_batch(&[&w])[0] > threshold;
            let q_dec = ae.score_quant(&w.data) > threshold;
            agree += (f32_dec == q_dec) as usize;
        }
        println!(
            "quantization decision agreement (Q8.24+PWL vs f32): {agree}/{total} windows"
        );
    }

    // ---- serve a Poisson trace -------------------------------------------
    let cfg = ServerConfig {
        max_batch: args.get_usize("max-batch", 8),
        max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us", 400)),
        workers: args.get_usize("workers", 2),
        queue_capacity: args.get_usize("queue", 1024),
        threshold,
        autoscale: None,
        cache: None,
    };
    let srv = AnomalyServer::start(backend, cfg);
    let mut gen = mk_gen(55);
    let trace = poisson_trace(&mut gen, 77, rate, n, t, anomaly_rate);
    println!("replaying {n} requests at {rate:.0} rps (anomaly rate {anomaly_rate}) ...");
    let start = std::time::Instant::now();
    let mut inflight = Vec::with_capacity(n);
    let mut shed = 0u64;
    for req in trace {
        let target = std::time::Duration::from_secs_f64(req.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        let truth = req.window.anomaly.map(|k| k);
        match srv.submit(req.window) {
            Ok(rx) => inflight.push((rx, truth)),
            // Bounded admission: over-capacity traffic is shed with an
            // explicit error instead of queuing unboundedly.
            Err(e) => {
                assert!(matches!(e, lstm_ae_accel::server::SubmitError::Overloaded), "{e}");
                shed += 1;
            }
        }
    }
    let mut per_kind: std::collections::BTreeMap<String, (u64, u64)> = Default::default();
    let (mut tp, mut fp, mut fneg, mut tn) = (0u64, 0u64, 0u64, 0u64);
    for (rx, truth) in inflight {
        let r = rx.recv().expect("response");
        match (r.is_anomaly, truth) {
            (true, Some(k)) => {
                tp += 1;
                per_kind.entry(format!("{k:?}")).or_default().0 += 1;
            }
            (false, Some(k)) => {
                fneg += 1;
                per_kind.entry(format!("{k:?}")).or_default().1 += 1;
            }
            (true, None) => fp += 1,
            (false, None) => tn += 1,
        }
    }
    let wall = start.elapsed().as_secs_f64();

    // ---- report -----------------------------------------------------------
    println!("\n{}", srv.metrics().report());
    println!("wall time {wall:.2}s → {:.0} windows/s sustained", n as f64 / wall);
    if shed > 0 {
        println!("load shed at admission: {shed} (raise --queue or lower --rate)");
    }
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    let recall = tp as f64 / (tp + fneg).max(1) as f64;
    let f1 = 2.0 * precision * recall / (precision + recall).max(1e-9);
    println!(
        "detection: TP {tp} FP {fp} FN {fneg} TN {tn} | precision {precision:.3} recall {recall:.3} F1 {f1:.3}"
    );
    let mut table = Table::new("Per-anomaly-kind recall").header(&["Kind", "detected", "missed", "recall"]);
    for (k, (d, m)) in &per_kind {
        let total = (d + m).max(1);
        table.row(vec![
            k.clone(),
            d.to_string(),
            m.to_string(),
            format!("{:.2}", *d as f64 / total as f64),
        ]);
    }
    print!("{}", table.render());
    srv.shutdown();
}
