//! Depth-scalability study (paper §4.2): how latency grows with network
//! depth on the temporal-parallel FPGA vs CPU/GPU.
//!
//! The paper's claim: tripling layers (D2→D6, F64, T=64) costs the CPU
//! ~2.9x and the GPU ~2.2x, but the dataflow FPGA only ~1.4x, because
//! added layers overlap with existing ones and only contribute pipeline
//! fill.
//!
//! ```bash
//! cargo run --release --example depth_scaling -- --width 64 --timesteps 64
//! ```

use lstm_ae_accel::accel::dataflow::DataflowSim;
use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::baselines::{CalibratedModel, Platform};
use lstm_ae_accel::model::Topology;
use lstm_ae_accel::report::tables::PS_INVOCATION_OVERHEAD_MS;
use lstm_ae_accel::util::cli::Args;
use lstm_ae_accel::util::table::Table;

fn main() {
    let args = Args::from_env();
    let width = args.get_usize("width", 64);
    let t = args.get_usize("timesteps", 64);
    let cpu = CalibratedModel::fit(Platform::XeonGold5218R);
    let gpu = CalibratedModel::fit(Platform::V100);
    let dev = FpgaDevice::ZCU104;

    let mut table = Table::new(&format!(
        "Depth scaling, F{width}, T={t} (latency ms; ratio vs shallowest)"
    ))
    .header(&[
        "Depth",
        "FPGA kernel",
        "FPGA (+ovh)",
        "ratio",
        "CPU model",
        "ratio",
        "GPU model",
        "ratio",
        "fill cyc",
        "steady II",
    ]);

    let mut base: Option<(f64, f64, f64)> = None;
    for depth in (2..=10).step_by(2) {
        let Ok(topo) = Topology::new(width, depth) else {
            continue;
        };
        // Hold the hardware policy constant across depths (the paper's
        // Table 1 varies RH_m per model because of resource limits; for a
        // clean scaling figure a single RH_m isolates the depth effect).
        let rh_m = args.get_u64("rhm", 4);
        let cfg = BalancedConfig::balance(&topo, rh_m);
        let run = DataflowSim::new(&cfg).run_sequence(t);
        let kernel_ms = run.total_ms(dev.clock_hz);
        let fpga = PS_INVOCATION_OVERHEAD_MS + kernel_ms;
        let c = cpu.latency_ms(&topo, t);
        let g = gpu.latency_ms(&topo, t);
        let (bf, bc, bg) = *base.get_or_insert((fpga, c, g));
        let fill: u64 = run.total_cycles.saturating_sub(t as u64 * run.steady_ii);
        table.row(vec![
            format!("D{depth}"),
            format!("{kernel_ms:.4}"),
            format!("{fpga:.4}"),
            format!("x{:.2}", fpga / bf),
            format!("{c:.3}"),
            format!("x{:.2}", c / bc),
            format!("{g:.3}"),
            format!("x{:.2}", g / bg),
            fill.to_string(),
            run.steady_ii.to_string(),
        ]);
    }
    print!("{}", table.render());
    println!("paper reference (F64, T=64, D2→D6): CPU x2.9, GPU x2.2, FPGA ~x1.4");
    println!("note: the steady II column is depth-invariant — added depth costs only");
    println!("pipeline fill, which is the temporal-parallelism claim in its purest form.");
}
