//! MULTI-MODEL SERVING FABRIC: the four paper topologies (F32/F64 ×
//! D2/D6, §4.1) served concurrently from one process — the serving
//! analog of SHARP-style configuration-adaptive RNN acceleration.
//!
//! Each model gets its own **lane**: a bounded admission queue (full →
//! explicit `Overloaded` load shedding, never unbounded buffering), a
//! dynamic batcher with a per-model policy (the deep F64-D6 lane holds
//! windows up to 2 ms to form big MMM batches; the shallow F32-D2 lane
//! flushes at 200 µs for latency), a worker pool, and metrics. Deep
//! lanes score lone windows on a pool of temporal-pipeline replicas, so
//! workers don't serialize on one pipeline. Per-lane metrics roll up
//! into a fleet report.
//!
//! ```bash
//! cargo run --release --example multi_model_serving
//! cargo run --release --example multi_model_serving -- --autoscale
//! cargo run --release --example multi_model_serving -- --async
//! ```
//! (quantized golden-model backends — no artifacts needed. With
//! `--autoscale`, each lane carries an `AutoscalePolicy` and a fleet
//! autoscaler resizes worker pools and pipeline-replica pools from the
//! per-lane metrics while the trace replays. With `--async`, the
//! open-loop replay is swapped for a closed-loop driver over the async
//! ticket front: a handful of client threads keep thousands of requests
//! outstanding through `CompletionSet`s instead of parking one OS thread
//! per in-flight request.)

use std::sync::Arc;
use std::time::{Duration, Instant};

use lstm_ae_accel::engine::{ExecMode, PIPELINE_MIN_DEPTH};
use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::server::{
    calibrate_threshold, AutoscalePolicy, Backend, ModelRegistry, QuantBackend, ServerConfig,
    ServingSurface, SubmitError,
};
use lstm_ae_accel::util::cli::Args;
use lstm_ae_accel::workload::{
    trace::{closed_loop_async, merged_poisson},
    TelemetryGen,
};

fn main() {
    let args = Args::from_env();
    let t = args.get_usize("timesteps", 16);
    let n = args.get_usize("requests", 2000);
    let rate = args.get_f64("rate", 4000.0);
    let anomaly_rate = args.get_f64("anomaly-rate", 0.15);
    let replicas = args.get_usize("replicas", 2);
    let autoscale = args.has("autoscale");

    // ---- assemble the fleet: backend + calibrated threshold per model --
    let mut registry = ModelRegistry::new();
    let mut backends: Vec<(String, Arc<QuantBackend>)> = Vec::new();
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let deep = topo.depth >= PIPELINE_MIN_DEPTH;
        let backend = Arc::new(QuantBackend::with_options(
            LstmAutoencoder::random(topo.clone(), 7 + i as u64),
            ExecMode::Auto,
            replicas,
        ));
        // Calibrate each model's threshold on its own benign traffic.
        let mut gen = TelemetryGen::new(topo.features, 21 + i as u64);
        let benign: Vec<f64> = (0..64)
            .map(|_| backend.score_batch(&[&gen.benign_window(t)])[0])
            .collect();
        let threshold = calibrate_threshold(&benign, 0.99);
        // The paper-fleet lane policy (deep models trade deadline for
        // batch size), with this run's threshold and queue bound.
        let cfg = ServerConfig {
            queue_capacity: args.get_usize("queue", 1024),
            threshold,
            autoscale: autoscale
                .then(|| AutoscalePolicy { up_ticks: 1, down_ticks: 5, ..Default::default() }),
            ..ModelRegistry::paper_lane_config(&topo, replicas)
        };
        println!(
            "lane {:<16} threshold {threshold:.6} | max_batch {:>2}, max_wait {:>4} µs, \
             {} workers{}",
            topo.name,
            cfg.max_batch,
            cfg.max_wait.as_micros(),
            cfg.workers,
            if deep { format!(", {replicas} pipeline replicas") } else { String::new() },
        );
        registry.register(&topo.name, backend.clone() as Arc<dyn Backend>, cfg);
        backends.push((topo.name, backend));
    }

    // ---- mixed open-loop Poisson traffic across all lanes at once -----
    if autoscale {
        let watched = registry.start_autoscaler(Duration::from_millis(20), None);
        println!("\nautoscaler running over {watched} lanes (tick 20 ms)");
    }
    let models: Vec<String> = registry.models().map(String::from).collect();

    if args.has("async") {
        run_async_closed_loop(&registry, &models, &args, n, t);
        registry.shutdown();
        return;
    }

    let topos: Vec<Topology> = models
        .iter()
        .map(|m| Topology::from_name(m).expect("registered names are canonical"))
        .collect();
    let merged = merged_poisson(&topos, 55, rate, n, t, anomaly_rate);
    println!(
        "\nreplaying {} requests across {} lanes at {rate:.0} rps aggregate ...",
        merged.len(),
        models.len()
    );

    let start = Instant::now();
    let mut inflight = Vec::with_capacity(merged.len());
    let mut shed = 0u64;
    for (mi, req) in merged {
        let target = Duration::from_secs_f64(req.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        let truth = req.window.anomaly.is_some();
        match registry.submit(&models[mi], req.window) {
            Ok(rx) => inflight.push((mi, rx, truth)),
            Err(SubmitError::Overloaded) => shed += 1, // bounded queue: shed, don't buffer
            Err(e) => panic!("submit to {}: {e}", models[mi]),
        }
    }
    let mut per_model = vec![(0u64, 0u64); models.len()]; // (tp, fn)
    for (mi, rx, truth) in inflight {
        let r = rx.recv().expect("accepted work completes");
        if truth {
            if r.is_anomaly {
                per_model[mi].0 += 1;
            } else {
                per_model[mi].1 += 1;
            }
        }
    }
    let wall = start.elapsed().as_secs_f64();

    // ---- fleet report --------------------------------------------------
    println!();
    print!("{}", registry.fleet_report());
    println!("wall {wall:.2}s | {shed} shed at admission");
    for (mi, name) in models.iter().enumerate() {
        let (tp, fneg) = per_model[mi];
        let recall = tp as f64 / (tp + fneg).max(1) as f64;
        print!("{name}: recall {recall:.2}  ");
    }
    println!();
    // Cumulative across calibration + serving (calibration alone already
    // rotates through the pool; the serving-path guarantee of ≥ 2
    // replicas in use is asserted by `tests/integration_fabric.rs`).
    for (name, backend) in &backends {
        if let Some((total, used)) = backend.replica_stats() {
            println!("{name}: {used}/{total} pipeline replicas exercised");
        }
    }
    if autoscale {
        for name in &models {
            let lane = registry.lane(name).expect("registered");
            let (ups, downs) = lane.scale_counts();
            println!(
                "{name}: {} workers now, scaled up {ups}× / down {downs}×",
                lane.workers()
            );
        }
    }
    registry.shutdown();
}

/// Closed-loop serving through the async ticket front: first one ticket's
/// callback lifecycle in miniature, then a handful of client threads
/// sustaining thousands of outstanding requests via `CompletionSet`s —
/// outstanding work the blocking surface could only hold with one parked
/// OS thread per request.
fn run_async_closed_loop(
    registry: &ModelRegistry,
    models: &[String],
    args: &Args,
    n: usize,
    t: usize,
) {
    // Submit, register a callback, drop the ticket: the lane's completion
    // router runs the callback at delivery — fire-and-forget.
    let topo = Topology::from_name(&models[0]).expect("registered names are canonical");
    let mut gen = TelemetryGen::new(topo.features, 77);
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    registry
        .submit_async(&models[0], gen.benign_window(t))
        .expect("admitted")
        .on_complete(move |outcome| {
            let r = outcome.expect("accepted work completes");
            let _ = done_tx.send(format!(
                "callback: request {} scored {:.6} ({} µs end to end)",
                r.id, r.score, r.e2e_us as u64
            ));
        });
    println!("\n{}", done_rx.recv().expect("router delivers the callback"));

    let clients = args.get_usize("clients", 4).max(1);
    let outstanding = args.get_usize("outstanding", 2048);
    let per_client = (outstanding / clients).max(1);
    println!(
        "closed loop: {clients} client threads × {per_client} outstanding tickets each, \
         {n} requests total ..."
    );
    let stats = closed_loop_async(registry, models, clients, per_client, n, t, 91);
    println!();
    print!("{}", registry.fleet_report());
    let wall = stats.wall.as_secs_f64().max(1e-9);
    println!(
        "wall {wall:.2}s | {} completed ({:.0}/s) | peak outstanding {} across {clients} \
         threads (blocking surface: {clients}) | {} shed retries | {} failed",
        stats.completed,
        stats.completed as f64 / wall,
        stats.max_outstanding,
        stats.shed_retries,
        stats.failed
    );
}
