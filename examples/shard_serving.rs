//! Shard-fabric walkthrough: the `submit(model, window)` surface
//! stretched over TCP, inside one process for demonstration.
//!
//! Spins up **two shard servers** on ephemeral loopback ports (each a
//! full paper fleet — the deployment `fleet serve` runs per host), wires
//! a [`ShardRouter`] over both, and shows the three properties the wire
//! fabric guarantees:
//!
//! 1. **Transparency** — tickets from a remote shard behave exactly like
//!    local ones (`wait`/`poll`), and scores are bit-identical to the
//!    sequential reference arithmetic.
//! 2. **One surface, many shards** — submissions balance across shards
//!    by power-of-two-choices on in-flight load.
//! 3. **Failover** — killing a shard loses nothing: in-flight tickets
//!    resolve `Err(Closed)`, re-offers route to the survivor, and
//!    `shard_failovers` counts the reroutes.
//!
//! Run with `cargo run --release --example shard_serving`.

use std::sync::Arc;
use std::time::Duration;

use lstm_ae_accel::engine::ExecMode;
use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::net::ShardServer;
use lstm_ae_accel::server::{ModelRegistry, ServingSurface, ShardRouter, SubmitError};
use lstm_ae_accel::workload::TelemetryGen;

fn main() {
    let seed = 42;
    // Two "hosts", identical model weights (the usual replicated-shard
    // deployment): each is what `fleet serve --bind <addr>` runs.
    let srv_a = ShardServer::bind(
        "127.0.0.1:0",
        Arc::new(ModelRegistry::paper_fleet(seed, ExecMode::Auto, 2)),
    )
    .expect("bind shard A");
    let srv_b = ShardServer::bind(
        "127.0.0.1:0",
        Arc::new(ModelRegistry::paper_fleet(seed, ExecMode::Auto, 2)),
    )
    .expect("bind shard B");
    let addrs = [srv_a.local_addr().to_string(), srv_b.local_addr().to_string()];
    println!("shards up: {} and {}", addrs[0], addrs[1]);

    // One router = one fleet-wide submission surface (`fleet connect`).
    let router = ShardRouter::connect(&addrs).expect("connect both shards");

    // 1) Remote tickets, bit-identical scores.
    println!("\n— bit-identity over the wire —");
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let reference = LstmAutoencoder::random(topo.clone(), seed + i as u64);
        let mut gen = TelemetryGen::new(topo.features, 7 + i as u64);
        let w = gen.benign_window(8);
        let want = reference.score_quant(&w.data);
        let got = router.submit_async(&topo.name, w).expect("submitted").wait().expect("scored");
        assert_eq!(got.score.to_bits(), want.to_bits());
        println!("  {:<16} remote score {:.6} == sequential (bit-exact)", topo.name, got.score);
    }

    // 2) Load spreads over both shards.
    println!("\n— balanced fan-out —");
    let mut gen = TelemetryGen::new(32, 99);
    let tickets: Vec<_> = (0..64)
        .map(|_| router.submit_async("LSTM-AE-F32-D2", gen.benign_window(6)).expect("submitted"))
        .collect();
    let mid = (router.shard_inflight(0), router.shard_inflight(1));
    for t in tickets {
        t.wait().expect("scored");
    }
    println!(
        "  64 requests over {} shards, in-flight mid-burst: shard A {} / shard B {}",
        router.len(),
        mid.0,
        mid.1
    );
    println!("  router metrics: {}", router.metrics().report());

    // 3) Kill shard A mid-flight: zero loss.
    println!("\n— failover —");
    let mut pending = Vec::new();
    for k in 0..40 {
        let w = gen.benign_window(4);
        pending.push((w.clone(), router.submit_async("LSTM-AE-F32-D2", w).expect("submitted")));
        if k == 20 {
            srv_a.shutdown();
            // Wait for the router to observe the death so the re-offers
            // below deterministically route to the survivor.
            while router.live_shards() != 1 {
                std::thread::sleep(Duration::from_millis(1));
            }
            println!("  shard A killed with requests in flight");
        }
    }
    let (mut completed, mut retried) = (0, 0);
    for (w, t) in pending {
        match t.wait() {
            Ok(_) => completed += 1,
            Err(SubmitError::Closed) => {
                // Re-offer: the router routes around the dead shard.
                let t2 = router.submit_async("LSTM-AE-F32-D2", w).expect("survivor accepts");
                t2.wait().expect("retry scores");
                retried += 1;
                completed += 1;
            }
            Err(e) => panic!("unexpected {e}"),
        }
    }
    println!(
        "  40/40 completed ({completed} total, {retried} re-offered), \
         {} of {} shards live, {} failovers counted",
        router.live_shards(),
        router.len(),
        router.metrics().shard_failovers()
    );

    router.shutdown();
    srv_b.shutdown();
    println!("\nfleet serve --bind <addr> / fleet connect --shards <a,b,...> run this for real.");
}
