//! Quickstart: configure the accelerator for a paper model, balance the
//! dataflow, simulate a sequence, and print the latency/energy story.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use lstm_ae_accel::accel::dataflow::DataflowSim;
use lstm_ae_accel::accel::energy::{energy_per_timestep_mj, fpga_power_w};
use lstm_ae_accel::accel::latency::LatencyModel;
use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::resources::estimate;
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::model::{LstmAutoencoder, ModelWeights, Topology};
use lstm_ae_accel::util::table::Table;

fn main() {
    // 1. The paper's LSTM-AE-F32-D2: 32 → 16 → 32 features.
    let topo = Topology::from_name("LSTM-AE-F32-D2").expect("known model");
    println!("model: {}  chain: {:?}", topo.name, topo.chain());

    // 2. Balance the dataflow around the paper's RH_m = 1 (Table 1).
    let cfg = BalancedConfig::balance(&topo, 1);
    let mut t = Table::new("Balanced configuration (Eqs 5–8)")
        .header(&["Layer", "LX", "LH", "RX(exact)", "RH(exact)", "MX", "MH", "Lat_t"]);
    for (i, l) in cfg.layers.iter().enumerate() {
        t.row(vec![
            format!("LSTM_{i}{}", if i == cfg.bottleneck { " (m)" } else { "" }),
            l.lx.to_string(),
            l.lh.to_string(),
            format!("{:.2}", l.rx_exact),
            format!("{:.2}", l.rh_exact),
            l.mx.to_string(),
            l.mh.to_string(),
            l.lat_t().to_string(),
        ]);
    }
    print!("{}", t.render());

    // 3. Cycle-accurate simulation vs the paper's Eq 1.
    let dev = FpgaDevice::ZCU104;
    let lm = LatencyModel::of(&cfg);
    let sim = DataflowSim::new(&cfg);
    let mut t = Table::new("Latency: simulator vs analytical Eq 1 (300 MHz)")
        .header(&["T", "sim cycles", "Eq1 cycles", "ms", "steady II"]);
    for steps in [1usize, 4, 16, 64] {
        let run = sim.run_sequence(steps);
        t.row(vec![
            steps.to_string(),
            run.total_cycles.to_string(),
            lm.acc_lat(steps).to_string(),
            format!("{:.4}", run.total_ms(dev.clock_hz)),
            run.steady_ii.to_string(),
        ]);
    }
    print!("{}", t.render());

    // 4. Resources + energy.
    let usage = estimate(&cfg);
    let pct = usage.pct(&dev);
    let power = fpga_power_w(&pct, &dev);
    println!(
        "resources on {}: LUT {:.1}% FF {:.1}% BRAM {:.1}% DSP {:.1}%  (fits: {})",
        dev.name,
        pct.lut,
        pct.ff,
        pct.bram,
        pct.dsp,
        usage.fits(&dev)
    );
    let lat64 = lm.acc_lat_ms(64, dev.clock_hz);
    println!(
        "power {power:.1} W → energy/timestep at T=64: {:.4} mJ",
        energy_per_timestep_mj(power, lat64, 64)
    );

    // 5. Functional pass through the bit-accurate Q8.24 datapath.
    let weights = ModelWeights::random(&topo, 42);
    let ae = LstmAutoencoder::new(topo, weights).expect("weights match");
    let window: Vec<Vec<f32>> =
        (0..8).map(|t| (0..32).map(|f| (0.1 * (t + f) as f32).sin() * 0.5).collect()).collect();
    println!(
        "reconstruction MSE (f32 path {:.6} | Q8.24+PWL datapath {:.6})",
        ae.score_f32(&window),
        ae.score_quant(&window)
    );
    println!(
        "temporal-parallelism speedup vs layer-by-layer at T=64: x{:.2}",
        lm.temporal_speedup(64)
    );
}
