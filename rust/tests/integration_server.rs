//! Integration: the anomaly-detection service end to end — batching under
//! open-loop load, threshold calibration, detection quality, and (when
//! artifacts exist) the PJRT backend.

use std::sync::Arc;

use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::server::{
    calibrate_threshold, AnomalyServer, Backend, PjrtBackend, QuantBackend, ServerConfig,
};
use lstm_ae_accel::workload::{trace::poisson_trace, AnomalyKind, TelemetryGen};

fn artifacts_exist() -> bool {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn serve_trace(
    backend: Arc<dyn Backend>,
    t: usize,
    mk_gen: impl Fn(u64) -> TelemetryGen,
) -> (u64, u64, u64, u64) {
    // Calibrate on benign, then classify a mixed trace.
    let mut gen = mk_gen(5);
    let benign: Vec<f64> = (0..48)
        .map(|_| backend.score_batch(&[&gen.benign_window(t)])[0])
        .collect();
    let threshold = calibrate_threshold(&benign, 0.99);
    let cfg = ServerConfig::builder()
        .max_batch(4)
        .max_wait(std::time::Duration::from_micros(300))
        .workers(2)
        .queue_capacity(1024)
        .threshold(threshold)
        .build();
    let srv = AnomalyServer::start(backend, cfg);
    let mut gen = mk_gen(6);
    let trace = poisson_trace(&mut gen, 7, 5000.0, 300, t, 0.25);
    let mut inflight = Vec::new();
    for req in trace {
        let truth = req.window.anomaly.is_some();
        inflight.push((srv.submit(req.window).expect("queue sized for the trace"), truth));
    }
    let (mut tp, mut fp, mut fneg, mut tn) = (0u64, 0u64, 0u64, 0u64);
    for (rx, truth) in inflight {
        let r = rx.recv().expect("response");
        match (r.is_anomaly, truth) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, true) => fneg += 1,
            (false, false) => tn += 1,
        }
    }
    assert_eq!(srv.metrics().completed(), 300);
    srv.shutdown();
    (tp, fp, fneg, tn)
}

#[test]
fn quant_backend_under_load_completes_all() {
    let topo = Topology::from_name("F32-D2").unwrap();
    let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(topo, 1)));
    let (tp, fp, fneg, tn) = serve_trace(backend, 8, |s| TelemetryGen::new(32, s));
    assert_eq!(tp + fp + fneg + tn, 300);
    // Untrained weights give weak separation; just require the pipeline
    // not to classify everything one way.
    assert!(tp + fneg > 0 && fp + tn > 0);
}

#[test]
fn pjrt_backend_detects_anomalies_with_trained_model() {
    if !artifacts_exist() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    }
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let backend = Arc::new(PjrtBackend::new(dir.clone(), "F32-D2", 16).expect("backend"));
    // Stream the training-distribution family (exported spec).
    let spec_path = dir.join("telemetry_F32.json");
    let (tp, fp, fneg, tn) = serve_trace(backend, 16, move |s| {
        TelemetryGen::from_spec_file(&spec_path, s).expect("telemetry spec")
    });
    assert_eq!(tp + fp + fneg + tn, 300);
    let recall = tp as f64 / (tp + fneg).max(1) as f64;
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    // Trained model on this synthetic family should detect most
    // anomalies without flagging everything.
    assert!(recall > 0.6, "recall {recall} (tp {tp} fn {fneg})");
    assert!(precision > 0.6, "precision {precision} (tp {tp} fp {fp})");
}

#[test]
fn batcher_amortizes_under_burst() {
    let topo = Topology::from_name("F32-D2").unwrap();
    let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(topo, 2)));
    let cfg = ServerConfig::builder()
        .max_batch(8)
        .max_wait(std::time::Duration::from_millis(2))
        .workers(1)
        .queue_capacity(1024)
        .threshold(1.0)
        .build();
    let srv = AnomalyServer::start(backend, cfg);
    let mut gen = TelemetryGen::new(32, 8);
    // Burst of 64 requests at once → batches should form.
    let rxs: Vec<_> = (0..64)
        .map(|_| srv.submit(gen.benign_window(8)).expect("queue sized for the burst"))
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    assert!(
        srv.metrics().mean_batch_size() > 1.5,
        "burst should batch (mean {})",
        srv.metrics().mean_batch_size()
    );
    assert!(srv.metrics().max_batch_seen() <= 8);
    srv.shutdown();
}
