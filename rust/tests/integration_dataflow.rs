//! Integration: the three latency views — analytical Eq 1, the fast
//! max-plus simulator, and the per-cycle stepped reference — must agree,
//! and the ablation orderings must hold end-to-end.

use lstm_ae_accel::accel::dataflow::{DataflowSim, SimOptions};
use lstm_ae_accel::accel::latency::LatencyModel;
use lstm_ae_accel::accel::layer_by_layer::{run_layer_by_layer, MemModel};
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::accel::stepped::run_stepped;
use lstm_ae_accel::model::{LstmAutoencoder, ModelWeights, Topology};
use lstm_ae_accel::util::prop::props;
use lstm_ae_accel::util::rng::Xoshiro256;

#[test]
fn three_way_latency_agreement_full_grid() {
    for topo in Topology::paper_models() {
        let rh_m = BalancedConfig::paper_rh_m(&topo.name).unwrap();
        let cfg = BalancedConfig::balance(&topo, rh_m);
        let lm = LatencyModel::of(&cfg);
        let sim = DataflowSim::new(&cfg);
        for t in [1usize, 2, 4, 6, 16, 64] {
            let fast = sim.run_sequence(t);
            let slow = run_stepped(&cfg, SimOptions::default(), t);
            assert_eq!(fast.total_cycles, lm.acc_lat(t), "{} T={t} fast vs Eq1", topo.name);
            assert_eq!(fast.total_cycles, slow.total_cycles, "{} T={t} fast vs stepped", topo.name);
        }
    }
}

#[test]
fn agreement_under_stress_configs() {
    props("integration_threeway", 64, |g| {
        let f = 1usize << g.usize_in(3, 6);
        let d = 2 * g.usize_in(1, 3);
        let Ok(topo) = Topology::new(f, d) else { return };
        let cfg = if g.bool() {
            BalancedConfig::balance(&topo, g.u64_below(8) + 1)
        } else {
            BalancedConfig::uniform(&topo, g.u64_below(4) + 1)
        };
        let opts = SimOptions {
            fifo_capacity: g.usize_in(1, 3),
            reader_cycles_per_t: g.u64_below(2) * f as u64,
            writer_cycles_per_t: g.u64_below(2) * f as u64,
        };
        let t = g.usize_in(1, 40);
        let fast = DataflowSim::with_options(&cfg, opts).run_sequence(t);
        let slow = run_stepped(&cfg, opts, t);
        assert_eq!(fast.total_cycles, slow.total_cycles);
        assert_eq!(fast.output_times, slow.output_times);
    });
}

#[test]
fn temporal_parallelism_beats_layer_by_layer_everywhere() {
    for topo in Topology::paper_models() {
        let cfg = BalancedConfig::paper_config(&topo);
        for t in [2usize, 16, 64] {
            let df = DataflowSim::new(&cfg).run_sequence(t).total_cycles;
            let lbl = run_layer_by_layer(&cfg, MemModel::default(), t).total_cycles;
            assert!(lbl > df, "{} T={t}", topo.name);
        }
    }
}

#[test]
fn balancing_beats_uniform_on_total_latency_per_multiplier() {
    // The methodology's promise: for similar silicon, balanced dataflow
    // sustains higher throughput. Compare cycles·multipliers (lower is
    // better silicon-time product).
    for topo in Topology::paper_models() {
        let bal = BalancedConfig::paper_config(&topo);
        let uni = BalancedConfig::uniform(&topo, bal.rh_m);
        let t = 64;
        let bal_cost = DataflowSim::new(&bal).run_sequence(t).total_cycles as f64
            * bal.total_multipliers() as f64;
        let uni_cost = DataflowSim::new(&uni).run_sequence(t).total_cycles as f64
            * uni.total_multipliers() as f64;
        assert!(
            bal_cost < uni_cost * 1.05,
            "{}: balanced {bal_cost:.0} vs uniform {uni_cost:.0}",
            topo.name
        );
    }
}

#[test]
fn functional_equivalence_sim_vs_golden_all_models() {
    let mut rng = Xoshiro256::seeded(2024);
    for topo in Topology::paper_models() {
        let weights = ModelWeights::random(&topo, 77);
        let cfg = BalancedConfig::paper_config(&topo);
        let x: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..topo.features).map(|_| rng.uniform(-1.0, 1.0) as f32).collect())
            .collect();
        let (_, sim_out) = DataflowSim::new(&cfg).run_with_data(&weights, &x);
        let ae = LstmAutoencoder::new(topo.clone(), weights).unwrap();
        assert_eq!(sim_out, ae.forward_quant(&x), "{}", topo.name);
    }
}

#[test]
fn quant_datapath_tracks_f32_on_realistic_signals() {
    // On telemetry-like inputs the Q8.24+PWL datapath must stay close to
    // f32 — quantization must not change anomaly decisions.
    use lstm_ae_accel::workload::TelemetryGen;
    for topo in Topology::paper_models() {
        let f = topo.features;
        let ae = LstmAutoencoder::random(topo, 3);
        let mut gen = TelemetryGen::new(f, 9);
        let w = gen.benign_window(16);
        let sf = ae.score_f32(&w.data);
        let sq = ae.score_quant(&w.data);
        let rel = (sf - sq).abs() / sf.max(1e-9);
        assert!(rel < 0.25, "{}: f32 {sf:.5} quant {sq:.5}", ae.topo.name);
    }
}
