//! Integration over the AOT artifacts: PJRT execution of the lowered
//! LSTM-AE vs the Rust f32 golden model over the shared weights binary —
//! the cross-language numerics contract.
//!
//! These tests require `make artifacts`; without artifacts they are
//! skipped (not failed) so `cargo test` stays useful pre-build.

use std::path::PathBuf;

use lstm_ae_accel::model::{LstmAutoencoder, ModelWeights, Topology};
use lstm_ae_accel::runtime::Runtime;
use lstm_ae_accel::util::rng::Xoshiro256;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn open_runtime_or_skip() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(&artifacts_dir()).expect("open runtime"))
}

#[test]
fn manifest_covers_all_paper_models_and_timesteps() {
    let Some(rt) = open_runtime_or_skip() else { return };
    for topo in Topology::paper_models() {
        let entry = rt.manifest().find(&topo.name).expect(&topo.name);
        assert_eq!(entry.features, topo.features);
        assert_eq!(entry.depth, topo.depth);
        assert_eq!(entry.layers, topo.chain());
        for t in [1usize, 2, 4, 6, 16, 64] {
            assert!(entry.hlo_for_t(t).is_some(), "{} T={t}", topo.name);
        }
        assert!(
            entry.train_loss.unwrap_or(1.0) < 0.05,
            "{} training converged (loss {:?})",
            topo.name,
            entry.train_loss
        );
    }
}

#[test]
fn artifact_matches_rust_f32_golden_model() {
    let Some(rt) = open_runtime_or_skip() else { return };
    let mut rng = Xoshiro256::seeded(99);
    for topo in Topology::paper_models() {
        let weights =
            ModelWeights::load(&artifacts_dir().join(format!("weights_{}.bin", topo.name)))
                .expect("load weights");
        let ae = LstmAutoencoder::new(topo.clone(), weights).unwrap();
        for t in [1usize, 4, 16] {
            let x: Vec<Vec<f32>> = (0..t)
                .map(|_| {
                    (0..topo.features).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
                })
                .collect();
            let flat: Vec<f32> = x.iter().flatten().copied().collect();
            let got = rt.infer(&topo.name, t, &flat).expect("infer");
            let want: Vec<f32> = ae.forward_f32(&x).into_iter().flatten().collect();
            assert_eq!(got.len(), want.len());
            let mut max_d = 0.0f32;
            for (a, b) in got.iter().zip(&want) {
                max_d = max_d.max((a - b).abs());
            }
            // f32 accumulation-order differences only.
            assert!(max_d < 2e-4, "{} T={t}: max |Δ| = {max_d}", topo.name);
        }
    }
}

#[test]
fn artifact_reconstructs_benign_telemetry_with_low_error() {
    // The trained model must actually have learned the telemetry family:
    // benign windows reconstruct well, anomalous ones reconstruct worse.
    use lstm_ae_accel::workload::AnomalyKind;
    let Some(rt) = open_runtime_or_skip() else { return };
    for name in ["LSTM-AE-F32-D2", "LSTM-AE-F64-D6"] {
        // In-distribution telemetry: the family the model was trained on.
        let mut gen = rt.telemetry_for(name, 4242).expect("telemetry spec");
        let t = 16;
        let score = |w: &[Vec<f32>]| -> f64 {
            let flat: Vec<f32> = w.iter().flatten().copied().collect();
            let out = rt.infer(name, t, &flat).unwrap();
            flat.iter()
                .zip(&out)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / flat.len() as f64
        };
        let benign: f64 =
            (0..8).map(|_| score(&gen.benign_window(t).data)).sum::<f64>() / 8.0;
        let spike: f64 = (0..8)
            .map(|_| score(&gen.anomalous_window(t, AnomalyKind::Spike).data))
            .sum::<f64>()
            / 8.0;
        assert!(benign < 0.05, "{name}: benign score {benign}");
        assert!(
            spike > 2.0 * benign,
            "{name}: spike {spike} vs benign {benign} — separation too weak"
        );
    }
}

#[test]
fn batched_artifact_matches_per_window_inference() {
    let Some(rt) = open_runtime_or_skip() else { return };
    let entry = rt.manifest().find("F32-D2").unwrap();
    let t = 16;
    if entry.batch_sizes(t).is_empty() {
        eprintln!("SKIP: no batched artifacts");
        return;
    }
    let f = entry.features;
    let mut rng = Xoshiro256::seeded(31);
    // 13 windows: exercises the greedy 8 + 4 + 1 decomposition.
    let b = 13usize;
    let x: Vec<f32> = (0..b * t * f).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let batched = rt.infer_batch("F32-D2", t, b, &x).expect("batched");
    assert_eq!(batched.len(), b * t * f);
    for i in 0..b {
        let single = rt.infer("F32-D2", t, &x[i * t * f..(i + 1) * t * f]).unwrap();
        for (a, s) in batched[i * t * f..(i + 1) * t * f].iter().zip(&single) {
            assert!((a - s).abs() < 1e-5, "window {i}: {a} vs {s}");
        }
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = open_runtime_or_skip() else { return };
    let a = rt.executable("F32-D2", 1).expect("compile");
    let b = rt.executable("F32-D2", 1).expect("cached");
    assert!(std::sync::Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
}

#[test]
fn infer_rejects_bad_shapes() {
    let Some(rt) = open_runtime_or_skip() else { return };
    assert!(rt.infer("F32-D2", 4, &[0.0; 3]).is_err(), "wrong length");
    assert!(rt.infer("F32-D2", 3, &[0.0; 96]).is_err(), "no artifact for T=3");
    assert!(rt.infer("NOPE", 4, &[0.0; 128]).is_err(), "unknown model");
}
