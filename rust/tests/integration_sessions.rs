//! Integration: streaming sessions end to end — bit-identity of the
//! incremental scoring path against full-window `ExecMode::Sequential`
//! re-runs from zero on all four paper topologies (including across
//! batcher-grouped concurrent streams), session lifecycle edges
//! (close / eviction / reopen), and the shard-failover reopen semantic
//! (state reset, counted as a stream reset).

use std::sync::Arc;
use std::time::{Duration, Instant};

use lstm_ae_accel::engine::ExecMode;
use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::net::ShardServer;
use lstm_ae_accel::server::{
    ModelRegistry, QuantBackend, RouterConfig, ServerConfig, SessionConfig, ShardRouter,
    ShardState, SubmitError,
};
use lstm_ae_accel::workload::TelemetryGen;

/// The settled incremental-scoring semantics, stated as stateless
/// arithmetic: the session score after k samples equals running the FULL
/// k-sample history through the quantized forward pass from zeroed state
/// and taking the flat MSE over the trailing `min(k, w)` rows. Every
/// assertion below compares bitwise against this.
fn rescore_reference(ae: &LstmAutoencoder, history: &[Vec<f32>], w: usize) -> f64 {
    let recon = ae.forward_quant(history);
    let tail = history.len().saturating_sub(w);
    LstmAutoencoder::mse(&history[tail..], &recon[tail..])
}

#[test]
fn incremental_scores_match_full_window_reruns_on_all_four_topologies() {
    // Three concurrent streams per lane, samples interleaved round-robin
    // and submitted without waiting — so the batcher groups same-lane
    // steps into batched step calls — then every returned score is
    // checked bitwise against the full-history rerun from zero. 24
    // samples over a window of 16 also exercises the ring wrap.
    const W: usize = 16;
    const STREAMS: u64 = 3;
    const SAMPLES: usize = 24;
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let seed = 510 + i as u64;
        let reference = LstmAutoencoder::random(topo.clone(), seed);
        let mut registry = ModelRegistry::new();
        registry.register(
            &topo.name,
            Arc::new(QuantBackend::new(LstmAutoencoder::random(topo.clone(), seed))),
            ServerConfig::builder()
                .max_batch(8)
                .max_wait(Duration::from_micros(500))
                .workers(2)
                .queue_capacity(1024)
                .threshold(1.0)
                .build(),
        );
        let mut histories: Vec<Vec<Vec<f32>>> = Vec::new();
        for s in 0..STREAMS {
            registry.open_stream(&topo.name, s, W).expect("session lane");
            let mut gen = TelemetryGen::new(topo.features, 620 + 10 * i as u64 + s);
            histories.push((0..SAMPLES).map(|_| gen.benign_window(1).data.remove(0)).collect());
        }
        let mut pending = Vec::new();
        for k in 0..SAMPLES {
            for s in 0..STREAMS {
                let sample = histories[s as usize][k].clone();
                let ticket = registry.submit_sample(&topo.name, s, sample).expect("open session");
                pending.push((s, k, ticket));
            }
        }
        for (s, k, ticket) in pending {
            let r = ticket.wait().expect("every admitted step resolves to a score");
            let want = rescore_reference(&reference, &histories[s as usize][..=k], W);
            assert_eq!(
                r.score.to_bits(),
                want.to_bits(),
                "{} stream {s} step {k}: incremental score must be bit-identical to the \
                 full-window sequential rerun from zero",
                topo.name
            );
        }
        registry.shutdown();
    }
}

/// One F32-D2 lane with a deliberately tiny session table.
fn tiny_table_registry(capacity: usize) -> (ModelRegistry, LstmAutoencoder, String) {
    let topo = Topology::from_name("F32-D2").unwrap();
    let reference = LstmAutoencoder::random(topo.clone(), 77);
    let mut registry = ModelRegistry::new();
    registry.register(
        &topo.name,
        Arc::new(QuantBackend::new(LstmAutoencoder::random(topo.clone(), 77))),
        ServerConfig::builder()
            .max_batch(4)
            .max_wait(Duration::from_micros(100))
            .workers(1)
            .queue_capacity(64)
            .threshold(1.0)
            .sessions(SessionConfig { capacity, window: 8 })
            .build(),
    );
    let name = topo.name;
    (registry, reference, name)
}

fn one_sample(seed: u64) -> Vec<f32> {
    TelemetryGen::new(32, seed).benign_window(1).data.remove(0)
}

#[test]
fn samples_after_close_fail_fast_with_unknown_stream() {
    let (registry, _, model) = tiny_table_registry(4);
    registry.open_stream(&model, 1, 0).unwrap();
    registry.submit_sample(&model, 1, one_sample(1)).expect("open").wait().expect("scored");
    registry.close_stream(&model, 1);
    assert!(matches!(
        registry.submit_sample(&model, 1, one_sample(2)),
        Err(SubmitError::UnknownStream(1))
    ));
    // Never-opened sessions get the same verdict, and closing an unknown
    // session is an idempotent no-op rather than an error.
    assert!(matches!(
        registry.submit_sample(&model, 99, one_sample(3)),
        Err(SubmitError::UnknownStream(99))
    ));
    registry.close_stream(&model, 42);
    registry.shutdown();
}

#[test]
fn opening_past_capacity_evicts_the_lru_session_and_reopen_starts_fresh() {
    let (registry, reference, model) = tiny_table_registry(2);
    // Fill the table, then overflow it: streams 1 and 2 occupy both
    // slots; opening 3 must evict the least-recently-touched (1).
    registry.open_stream(&model, 1, 0).unwrap();
    registry.open_stream(&model, 2, 0).unwrap();
    registry.open_stream(&model, 3, 0).unwrap();
    let table = registry.lane(&model).unwrap().session_table().expect("session lane");
    assert_eq!(table.len(), 2, "the table never exceeds its capacity");
    assert!(matches!(
        registry.submit_sample(&model, 1, one_sample(4)),
        Err(SubmitError::UnknownStream(1))
    ));
    for s in [2u64, 3] {
        registry
            .submit_sample(&model, s, one_sample(10 + s))
            .expect("survivors keep scoring")
            .wait()
            .expect("scored");
    }
    // Open-after-eviction: stream 1 reopens into a fresh slot, and its
    // first score proves the state is zeroed — bit-identical to a
    // single-sample full rerun, not a continuation of its old history.
    registry.open_stream(&model, 1, 8).unwrap();
    let sample = one_sample(5);
    let r = registry
        .submit_sample(&model, 1, sample.clone())
        .expect("reopened")
        .wait()
        .expect("scored");
    let want = rescore_reference(&reference, &[sample], 8);
    assert_eq!(r.score.to_bits(), want.to_bits(), "a reopened session starts from zero");
    assert_eq!(table.len(), 2, "the reopen evicted another LRU slot to make room");
    registry.shutdown();
}

#[test]
fn shard_restart_reopens_sessions_fresh_and_counts_stream_resets() {
    // The failover reset semantic end to end: a session sticky-routed to
    // a shard whose process dies is reopened on rejoin with zeroed state
    // — scores restart as a fresh session (bit-asserted), and the reset
    // is counted, never silent.
    const W: usize = 16;
    let seed = 350;
    let registry = Arc::new(ModelRegistry::paper_fleet(seed, ExecMode::Auto, 2));
    let server = ShardServer::bind("127.0.0.1:0", registry).expect("bind loopback");
    let addr = server.local_addr().to_string();
    let cfg = RouterConfig {
        heartbeat_ms: 25,
        suspect_after: 2,
        dead_after: 4,
        reconnect_max_backoff_ms: 200,
    };
    let router = ShardRouter::connect_with(&[addr.clone()], cfg).expect("connect");
    let topo = &Topology::paper_models()[0];
    let reference = LstmAutoencoder::random(topo.clone(), seed);
    let mut gen = TelemetryGen::new(topo.features, 910);
    let stream = 5u64;
    router.open_stream(&topo.name, stream, W).expect("live shard");
    let mut history: Vec<Vec<f32>> = Vec::new();
    for _ in 0..6 {
        history.push(gen.benign_window(1).data.remove(0));
        let r = router
            .submit_sample(&topo.name, stream, history.last().unwrap().clone())
            .expect("sticky shard accepts")
            .wait()
            .expect("scored");
        let want = rescore_reference(&reference, &history, W);
        assert_eq!(r.score.to_bits(), want.to_bits(), "pre-kill steps carry state");
    }
    assert_eq!(router.stream_resets(), 0, "a healthy session never resets");

    // Kill the process and restart the same deployment on the same port:
    // the router redials it, but the carried session state died with the
    // old process — the sticky route's generation check forces a reopen.
    server.shutdown();
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.metrics().shard_deaths() == 0 {
        assert!(Instant::now() < deadline, "health loop must demote the killed shard");
        std::thread::sleep(Duration::from_millis(5));
    }
    let registry2 = Arc::new(ModelRegistry::paper_fleet(seed, ExecMode::Auto, 2));
    let server2 = loop {
        match ShardServer::bind(&addr, Arc::clone(&registry2)) {
            Ok(s) => break s,
            Err(_) if Instant::now() < deadline => std::thread::sleep(Duration::from_millis(50)),
            Err(e) => panic!("rebind {addr}: {e}"),
        }
    };
    while router.shard_state(0) != ShardState::Live {
        assert!(Instant::now() < deadline, "restarted shard must rejoin automatically");
        std::thread::sleep(Duration::from_millis(5));
    }

    // First post-restart sample: submitted with a small retry loop (the
    // rejoin can race the submit), it must score as a BRAND-NEW session
    // — the documented state-reset failover semantic.
    let mut fresh_history = vec![gen.benign_window(1).data.remove(0)];
    let score = loop {
        assert!(Instant::now() < deadline, "rejoined shard must serve the stream");
        match router.submit_sample(&topo.name, stream, fresh_history[0].clone()) {
            Ok(ticket) => match ticket.wait() {
                Ok(r) => break r.score,
                Err(SubmitError::Closed) => std::thread::sleep(Duration::from_millis(10)),
                Err(e) => panic!("unexpected outcome {e}"),
            },
            Err(SubmitError::Closed) | Err(SubmitError::UnknownStream(_)) => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => panic!("unexpected submit error {e}"),
        }
    };
    let want = rescore_reference(&reference, &fresh_history, W);
    assert_eq!(
        score.to_bits(),
        want.to_bits(),
        "a failed-over session restarts from zeroed state, not its old history"
    );
    assert!(router.stream_resets() >= 1, "the reset is counted, never silent");

    // And the reopened session carries state again from here on.
    fresh_history.push(gen.benign_window(1).data.remove(0));
    let r = router
        .submit_sample(&topo.name, stream, fresh_history.last().unwrap().clone())
        .expect("rejoined shard accepts")
        .wait()
        .expect("scored");
    let want = rescore_reference(&reference, &fresh_history, W);
    assert_eq!(r.score.to_bits(), want.to_bits(), "post-reset steps carry state again");
    router.close_stream(&topo.name, stream);
    router.shutdown();
    server2.shutdown();
}
