//! Integration: the fleet process autoscaler — real child processes of
//! this crate's own binary (`fleet serve --ephemeral`) spawned under a
//! surge, drained and reaped on the cool-down.
//!
//! The headline test runs the same surge-then-quiet trace twice against
//! the same deliberately undersized static shard: once bare (the
//! min-shard baseline) and once with the [`FleetScaler`] allowed to grow
//! the fleet. It pins the whole contract at once: the fleet grows under
//! pressure, sheds strictly less than the static baseline at equal
//! offered load, retires back to the floor with zero lost tickets and
//! conserved accounting, and every score completed mid-churn is
//! bit-identical to the `ExecMode::Sequential` reference.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::server::{
    Backend, FleetScalePolicy, FleetScaler, ModelRegistry, RouterConfig, ServerConfig,
    ServingSurface, ShardRouter, ShardSpawner,
};
use lstm_ae_accel::net::ShardServer;
use lstm_ae_accel::workload::trace::{replay_fleet, surge_poisson};
use lstm_ae_accel::workload::{TelemetryGen, Window};

/// The crate's own binary — what the fleet CLI hands the spawner too.
const BIN: &str = env!("CARGO_BIN_EXE_lstm-ae-accel");

/// A correct-but-slow scorer: real `score_quant` arithmetic (so remote
/// scores stay bit-comparable to the sequential reference) behind a
/// fixed per-batch floor. The floor caps the static shard's throughput
/// far below the surge rate, which is what makes the baseline shed.
struct SlowQuant {
    model: LstmAutoencoder,
    floor: Duration,
}

impl Backend for SlowQuant {
    fn name(&self) -> String {
        "slow-quant".to_string()
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        std::thread::sleep(self.floor);
        windows.iter().map(|w| self.model.score_quant(&w.data)).collect()
    }
}

/// The undersized floor shard both runs share: every paper model behind
/// a 2 ms-per-window lane with a tiny queue, served in-process.
fn spawn_slow_floor_shard(seed: u64) -> (ShardServer, String) {
    let mut registry = ModelRegistry::new();
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let backend = SlowQuant {
            model: LstmAutoencoder::random(topo.clone(), seed + i as u64),
            floor: Duration::from_millis(2),
        };
        registry.register(
            &topo.name,
            Arc::new(backend),
            ServerConfig::builder()
                .max_batch(1)
                .max_wait(Duration::from_micros(50))
                .workers(1)
                .queue_capacity(8)
                .threshold(1.0)
                .build(),
        );
    }
    let server = ShardServer::bind("127.0.0.1:0", Arc::new(registry)).expect("bind loopback");
    let addr = server.local_addr().to_string();
    (server, addr)
}

fn router_config() -> RouterConfig {
    RouterConfig::builder().heartbeat_ms(25).suspect_after(3).dead_after(6).build()
}

/// The surge-then-quiet schedule: ~2.5 s far above the floor shard's
/// capacity, then a ~3 s quiet tail the scaler can drain into. Both runs
/// regenerate it from the same seed, so offered load is byte-identical.
fn surge_trace(seed: u64) -> Vec<(usize, lstm_ae_accel::workload::trace::TimedRequest)> {
    let topos = Topology::paper_models();
    surge_poisson(&topos, seed, 4000.0, 150.0, 10_000, 450, 8)
}

/// Spawner for ephemeral children of this very binary, seeded like the
/// floor shard so model weights — and therefore scores — line up.
fn child_spawner(seed: u64) -> ShardSpawner {
    ShardSpawner::new(
        BIN,
        vec!["fleet".into(), "serve".into(), "--seed".into(), seed.to_string()],
    )
    .ready_timeout(Duration::from_secs(60))
}

#[test]
fn surge_grows_the_fleet_sheds_less_than_static_and_retires_to_floor_losslessly() {
    let seed = 300;
    let topos = Topology::paper_models();
    let models: Vec<String> = topos.iter().map(|t| t.name.clone()).collect();

    // Run 1 — static min-shard baseline: the slow floor shard alone.
    let (static_srv, static_addr) = spawn_slow_floor_shard(seed);
    let static_router =
        ShardRouter::connect_with(&[static_addr], router_config()).expect("connect static");
    let static_stats = replay_fleet(&static_router, &models, surge_trace(seed), true);
    static_router.shutdown();
    static_srv.shutdown();
    assert!(static_stats.conserves(), "static baseline must conserve accounting");
    assert!(
        static_stats.shed > 0,
        "the surge must overwhelm the floor shard, or the comparison is vacuous"
    );

    // Run 2 — same floor shard and the same trace, autoscaled.
    let (auto_srv, auto_addr) = spawn_slow_floor_shard(seed);
    let router =
        Arc::new(ShardRouter::connect_with(&[auto_addr], router_config()).expect("connect"));
    let policy = FleetScalePolicy {
        min_shards: 1,
        max_shards: 3,
        up_inflight_per_shard: 8.0,
        up_ticks: 2,
        down_inflight_per_shard: 2.0,
        down_ticks: 4,
    };
    let scaler = FleetScaler::start(
        router.clone(),
        child_spawner(seed),
        policy,
        Duration::from_millis(25),
    );

    // Concurrent churn verifier: while the replay runs (shards joining
    // and leaving underneath), keep submitting windows with known
    // sequential references and insist every completed score is
    // bit-identical. Shed/closed outcomes are legitimate mid-churn; a
    // wrong bit never is.
    let done = AtomicBool::new(false);
    let peak_live = AtomicUsize::new(0);
    let verified = AtomicUsize::new(0);
    let stats = std::thread::scope(|sc| {
        let verifier = {
            let router = &*router;
            let (done, peak_live, verified) = (&done, &peak_live, &verified);
            let topos = &topos;
            sc.spawn(move || {
                let refs: Vec<LstmAutoencoder> = topos
                    .iter()
                    .enumerate()
                    .map(|(i, t)| LstmAutoencoder::random(t.clone(), seed + i as u64))
                    .collect();
                let mut gens: Vec<TelemetryGen> = topos
                    .iter()
                    .enumerate()
                    .map(|(i, t)| TelemetryGen::new(t.features, 900 + i as u64))
                    .collect();
                while !done.load(Ordering::Acquire) {
                    peak_live.fetch_max(router.live_shards(), Ordering::Relaxed);
                    for (i, topo) in topos.iter().enumerate() {
                        let w = gens[i].benign_window(6);
                        let want = refs[i].score_quant(&w.data);
                        let Ok(ticket) = router.submit_async(&topo.name, w) else {
                            continue;
                        };
                        if let Ok(r) = ticket.wait() {
                            assert_eq!(
                                r.score.to_bits(),
                                want.to_bits(),
                                "{}: score completed mid-churn must be bit-identical",
                                topo.name
                            );
                            verified.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            })
        };
        let stats = replay_fleet(&*router, &models, surge_trace(seed), true);
        done.store(true, Ordering::Release);
        verifier.join().expect("verifier thread panicked");
        stats
    });

    // Growth under pressure: the scaler spawned, and the fleet was
    // observed above the floor while traffic flowed.
    let m = router.metrics();
    assert!(m.shard_spawns() >= 1, "the surge must force at least one spawn");
    assert!(
        peak_live.load(Ordering::Relaxed) >= 2,
        "the fleet must have been observed above the one-shard floor"
    );
    assert!(
        verified.load(Ordering::Relaxed) > 0,
        "the churn verifier must have completed at least one scored window"
    );

    // Strictly fewer sheds than the static baseline at equal offered
    // load — the autoscaler paid for itself.
    assert!(
        stats.shed < static_stats.shed,
        "autoscaled fleet must shed strictly less: {} vs static {}",
        stats.shed,
        static_stats.shed
    );

    // Zero lost tickets and conserved accounting through the churn.
    assert!(stats.conserves(), "autoscaled run must conserve accounting");
    assert_eq!(stats.rejected_closed, 0, "no ticket may be lost to the churn");
    assert_eq!(stats.offered, static_stats.offered, "equal offered load by construction");
    assert!(stats.completed > 0);

    // Cool-down: the quiet tail drains the fleet back to the floor and
    // the children are reaped.
    let deadline = Instant::now() + Duration::from_secs(60);
    while (router.live_shards() > 1 || m.shard_retires() < m.shard_spawns())
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(50));
    }
    scaler.stop();
    assert_eq!(router.live_shards(), 1, "fleet must retire back to the one-shard floor");
    assert!(m.shard_retires() >= 1, "every drained child counts a retire");
    assert_eq!(
        m.shard_retires(),
        m.shard_spawns(),
        "every spawned child must eventually be retired"
    );
    router.shutdown();
    auto_srv.shutdown();
}

#[test]
fn ephemeral_child_serves_bit_identical_scores_then_exits_on_drain_request() {
    // The spawn→serve→drain→exit lifecycle of one child, no scaler: the
    // spawner's readiness handshake, `add_shard` admission at connect,
    // and the `--ephemeral` self-exit once `retire_shard`'s Leave lands.
    let seed = 7;
    let mut spawned = child_spawner(seed).spawn_shard().expect("child becomes ready");
    let router = ShardRouter::connect_with(&[spawned.addr().to_string()], router_config())
        .expect("connect to child");
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let reference = LstmAutoencoder::random(topo.clone(), seed + i as u64);
        let mut gen = TelemetryGen::new(topo.features, 950 + i as u64);
        let w = gen.benign_window(6);
        let want = reference.score_quant(&w.data);
        let r = router
            .submit_async(&topo.name, w)
            .expect("child is live")
            .wait()
            .expect("child scores");
        assert_eq!(
            r.score.to_bits(),
            want.to_bits(),
            "{}: child-process score must be bit-identical to sequential",
            topo.name
        );
    }
    router.retire_shard(0).expect("drain request reaches the child");
    assert!(router.shard_retired(0), "slot must be marked retired");
    // The drain completes (slot → Dead, connection closed), after which
    // the ephemeral child exits on its own — no kill involved.
    let deadline = Instant::now() + Duration::from_secs(30);
    let status = loop {
        if let Some(status) = spawned.try_wait().expect("child is this process's to reap") {
            break status;
        }
        if Instant::now() >= deadline {
            spawned.kill();
            panic!("ephemeral child did not exit within 30s of its drain");
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.success(), "drained child must exit cleanly, got {status}");
    router.shutdown();
}
