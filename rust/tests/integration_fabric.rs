//! Integration: the multi-model serving fabric — all four paper
//! topologies served concurrently with bit-identical scores, pipeline
//! replica-pool utilization, Poisson-overload shedding + recovery, and
//! per-model metrics isolation.

use std::sync::Arc;
use std::time::{Duration, Instant};

use lstm_ae_accel::engine::{ExecMode, PIPELINE_MIN_DEPTH};
use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::server::{
    Backend, ModelRegistry, QuantBackend, ServerConfig, SubmitError,
};
use lstm_ae_accel::workload::{trace::poisson_trace, TelemetryGen, Window};

/// Registry over the four paper models plus per-model reference scorers
/// built from the same seeds — the reference path is pure
/// `ExecMode::Sequential` arithmetic (`score_quant`), so any fabric
/// response can be checked for bit-identity.
fn paper_registry_with_references(
    replicas: usize,
) -> (ModelRegistry, Vec<(String, LstmAutoencoder, TelemetryGen)>) {
    let mut registry = ModelRegistry::new();
    let mut refs = Vec::new();
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let seed = 100 + i as u64;
        let backend = Arc::new(QuantBackend::with_options(
            LstmAutoencoder::random(topo.clone(), seed),
            ExecMode::Auto,
            replicas,
        ));
        // The fleet's per-model batching policy (the deep F64-D6 lane
        // holds a longer max_wait than the latency-sensitive F32-D2
        // lane), with a queue sized so this test never sheds.
        let cfg = ServerConfig {
            queue_capacity: 4096,
            ..ModelRegistry::paper_lane_config(&topo, replicas)
        };
        registry.register(&topo.name, backend, cfg);
        let reference = LstmAutoencoder::random(topo.clone(), seed);
        let gen = TelemetryGen::new(topo.features, 200 + i as u64);
        refs.push((topo.name, reference, gen));
    }
    (registry, refs)
}

#[test]
fn mixed_traffic_is_bit_identical_to_sequential_scoring() {
    let (registry, mut refs) = paper_registry_with_references(2);
    // Interleaved mixed-length traffic across all four lanes at once, so
    // every lane sees multi-window batches (batched MMM kernel), lone
    // windows (pipeline/sequential), and mixed-T groups.
    let mut inflight = Vec::new();
    for round in 0..30usize {
        for (mi, (name, reference, gen)) in refs.iter_mut().enumerate() {
            let t = [4usize, 8, 8, 6, 1][(round + mi) % 5];
            let w = gen.benign_window(t);
            let want = reference.score_quant(&w.data);
            let rx = registry.submit(name, w).expect("queue sized for the test");
            inflight.push((name.clone(), rx, want));
        }
    }
    for (name, rx, want) in inflight {
        let r = rx.recv().expect("response");
        assert_eq!(
            r.score.to_bits(),
            want.to_bits(),
            "{name}: fabric score must be bit-identical to sequential"
        );
    }
    // Every lane really saw its own traffic.
    for (name, _, _) in &refs {
        assert_eq!(registry.lane(name).unwrap().metrics().completed(), 30, "{name}");
    }
    registry.shutdown();
}

#[test]
fn deep_lane_workers_use_multiple_pipeline_replicas() {
    // max_batch = 1 forces singleton batches, so Auto routes every window
    // through the pipeline pool; the rotating least-loaded checkout must
    // spread them across ≥ 2 replicas (no global pipeline lock on the
    // hot path).
    let topo = Topology::from_name("F64-D6").unwrap();
    assert!(topo.depth >= PIPELINE_MIN_DEPTH, "test needs a pipeline-routed model");
    let seed = 7u64;
    let backend = Arc::new(QuantBackend::with_options(
        LstmAutoencoder::random(topo.clone(), seed),
        ExecMode::Auto,
        3,
    ));
    let reference = LstmAutoencoder::random(topo.clone(), seed);
    let mut registry = ModelRegistry::new();
    let cfg = ServerConfig::builder()
        .max_batch(1)
        .max_wait(Duration::from_micros(50))
        .workers(3)
        .queue_capacity(4096)
        .threshold(0.05)
        .build();
    registry.register(&topo.name, backend.clone() as Arc<dyn Backend>, cfg);
    let mut gen = TelemetryGen::new(topo.features, 9);
    let mut inflight = Vec::new();
    for _ in 0..48 {
        let w = gen.benign_window(8);
        let want = reference.score_quant(&w.data);
        inflight.push((registry.submit(&topo.name, w).expect("admitted"), want));
    }
    for (rx, want) in inflight {
        let r = rx.recv().expect("response");
        assert_eq!(r.score.to_bits(), want.to_bits(), "replica scores must be bit-identical");
    }
    let (replicas, used) = backend.replica_stats().expect("deep Auto backend has a pool");
    assert_eq!(replicas, 3);
    assert!(used >= 2, "expected ≥ 2 replicas in use, saw {used}");
    registry.shutdown();
}

/// Deterministically slow backend: a fixed floor per scored batch makes
/// over-capacity arrival rates overwhelm the lane regardless of host
/// speed.
struct SlowBackend {
    floor: Duration,
}

impl Backend for SlowBackend {
    fn name(&self) -> String {
        "slow".into()
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        std::thread::sleep(self.floor);
        vec![0.0; windows.len()]
    }
}

#[test]
fn poisson_overload_sheds_then_recovers() {
    // Lane capacity ≈ 500 batches/s (2 ms per singleton batch, 1 worker);
    // the open-loop Poisson trace arrives at ~50k rps — two orders of
    // magnitude over capacity — so the bounded queue must shed.
    let mut registry = ModelRegistry::new();
    let cfg = ServerConfig::builder()
        .max_batch(1)
        .max_wait(Duration::from_micros(1))
        .workers(1)
        .queue_capacity(4)
        .threshold(1.0)
        .build();
    registry.register(
        "slow-model",
        Arc::new(SlowBackend { floor: Duration::from_millis(2) }),
        cfg,
    );
    let mut gen = TelemetryGen::new(8, 3);
    let trace = poisson_trace(&mut gen, 17, 50_000.0, 300, 2, 0.0);
    let start = Instant::now();
    let mut accepted = Vec::new();
    let mut shed = 0u64;
    for req in trace {
        // Open loop: honor arrival times, never wait for responses.
        let target = Duration::from_secs_f64(req.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        match registry.submit("slow-model", req.window) {
            Ok(rx) => accepted.push(rx),
            Err(SubmitError::Overloaded) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed > 0, "over-capacity arrivals must shed");
    assert!(!accepted.is_empty(), "the queue still admits up to its bound");
    let m = registry.lane("slow-model").unwrap().metrics();
    assert_eq!(m.shed(), shed);
    assert_eq!(m.submitted(), accepted.len() as u64);
    // Every accepted request completes: shedding protects admitted work.
    for rx in accepted {
        let r = rx.recv().expect("accepted work completes");
        assert_eq!(r.score, 0.0);
    }
    // Recovery: once the backlog drains, sub-capacity traffic flows again.
    for _ in 0..3 {
        let r = registry
            .score_blocking("slow-model", gen.benign_window(2))
            .expect("lane recovers after overload");
        assert_eq!(r.score, 0.0);
    }
    assert_eq!(m.shed(), shed, "recovered traffic must not shed");
    registry.shutdown();
}

#[test]
fn per_model_metrics_are_isolated() {
    let mk = |name: &str, seed: u64| {
        Arc::new(QuantBackend::new(LstmAutoencoder::random(
            Topology::from_name(name).unwrap(),
            seed,
        )))
    };
    let mut registry = ModelRegistry::new();
    registry.register("LSTM-AE-F32-D2", mk("F32-D2", 1), ServerConfig::default());
    registry.register("LSTM-AE-F64-D2", mk("F64-D2", 2), ServerConfig::default());
    let mut gen32 = TelemetryGen::new(32, 5);
    let mut gen64 = TelemetryGen::new(64, 6);

    // Traffic to A only: B's counters must stay untouched.
    for _ in 0..25 {
        registry.score_blocking("F32-D2", gen32.benign_window(6)).unwrap();
    }
    let a = registry.lane("F32-D2").unwrap().metrics();
    let b = registry.lane("F64-D2").unwrap().metrics();
    assert_eq!(a.submitted(), 25);
    assert_eq!(a.completed(), 25);
    assert_eq!((b.submitted(), b.completed(), b.shed()), (0, 0, 0));

    // Then traffic to B: A's counters must not move.
    for _ in 0..10 {
        registry.score_blocking("F64-D2", gen64.benign_window(6)).unwrap();
    }
    assert_eq!((a.submitted(), a.completed()), (25, 25));
    assert_eq!((b.submitted(), b.completed()), (10, 10));
    registry.shutdown();
}

#[test]
fn registry_shutdown_closes_every_lane() {
    let (registry, mut refs) = paper_registry_with_references(2);
    registry.shutdown();
    for (name, _, gen) in refs.iter_mut() {
        assert!(matches!(
            registry.submit(name, gen.benign_window(4)),
            Err(SubmitError::Closed)
        ));
    }
}
