//! Integration: the async submission front — tickets are bit-identical
//! to `ExecMode::Sequential` across all four paper topologies, shed and
//! backpressure semantics are unchanged from the blocking surface,
//! dropped tickets leak nothing, poisoned tickets wake instead of
//! hanging, and the closed-loop ticket driver sustains ≥ 4× the
//! outstanding work of the blocking driver at equal client-thread count
//! without shedding.

use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use lstm_ae_accel::engine::ExecMode;
use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::server::{
    Backend, CompletionSet, ModelRegistry, QuantBackend, ServerConfig, SubmitError,
};
use lstm_ae_accel::workload::trace::{
    closed_loop_async, closed_loop_blocking, merged_poisson, replay_async,
};
use lstm_ae_accel::workload::{TelemetryGen, Window};

/// Registry over the four paper models plus per-model reference scorers
/// built from the same seeds — the reference path is pure
/// `ExecMode::Sequential` arithmetic (`score_quant`), so any ticket can
/// be checked for bit-identity.
fn paper_registry_with_references(
) -> (ModelRegistry, Vec<(String, LstmAutoencoder, TelemetryGen)>) {
    let mut registry = ModelRegistry::new();
    let mut refs = Vec::new();
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let seed = 300 + i as u64;
        let backend = Arc::new(QuantBackend::with_options(
            LstmAutoencoder::random(topo.clone(), seed),
            ExecMode::Auto,
            2,
        ));
        let cfg = ServerConfig {
            queue_capacity: 4096,
            ..ModelRegistry::paper_lane_config(&topo, 2)
        };
        registry.register(&topo.name, backend, cfg);
        let reference = LstmAutoencoder::random(topo.clone(), seed);
        let gen = TelemetryGen::new(topo.features, 400 + i as u64);
        refs.push((topo.name, reference, gen));
    }
    (registry, refs)
}

fn wait_for(mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_secs(5);
    while !cond() {
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    true
}

#[test]
fn async_tickets_are_bit_identical_to_sequential_across_the_paper_fleet() {
    let (registry, mut refs) = paper_registry_with_references();
    // Mixed-length traffic across all four lanes, every redemption style
    // in rotation: poll-spin, wait, wait_timeout, and a CompletionSet.
    let mut tickets = Vec::new();
    for round in 0..24usize {
        for (mi, (name, reference, gen)) in refs.iter_mut().enumerate() {
            let t = [4usize, 8, 8, 6, 1][(round + mi) % 5];
            let w = gen.benign_window(t);
            let want = reference.score_quant(&w.data);
            let ticket = registry.submit_async(name, w).expect("queue sized for the test");
            tickets.push((name.clone(), ticket, want));
        }
    }
    let mut set = CompletionSet::new();
    let mut set_wants = Vec::new();
    for (i, (name, ticket, want)) in tickets.into_iter().enumerate() {
        let got = match i % 4 {
            0 => ticket.wait(),
            1 => {
                // Poll-spin (bounded): the nonblocking check eventually
                // observes the completion the router delivered.
                loop {
                    if let Some(outcome) = ticket.poll() {
                        break outcome;
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            2 => ticket
                .wait_timeout(Duration::from_secs(30))
                .expect("completes well inside the deadline"),
            _ => {
                set_wants.push(want);
                set.add(set_wants.len() as u64 - 1, ticket);
                continue;
            }
        };
        let r = got.expect("accepted async work completes");
        assert_eq!(
            r.score.to_bits(),
            want.to_bits(),
            "{name}: async front must be bit-identical to sequential"
        );
    }
    // The set fans the remaining quarter in, in delivery order.
    while let Some((key, outcome)) = set.wait() {
        let r = outcome.expect("accepted async work completes");
        let want = set_wants[key as usize];
        assert_eq!(r.score.to_bits(), want.to_bits(), "set-reaped ticket must match");
    }
    for (name, _, _) in &refs {
        assert_eq!(registry.lane(name).unwrap().metrics().completed(), 24, "{name}");
        assert!(
            wait_for(|| registry.lane(name).unwrap().async_inflight() == 0),
            "{name}: delivered slots must drain from the router"
        );
    }
    registry.shutdown();
}

#[test]
fn completion_set_fans_in_first_of_n_across_lanes() {
    let (registry, mut refs) = paper_registry_with_references();
    let mut set = CompletionSet::new();
    let mut wants = Vec::new();
    for (mi, (name, reference, gen)) in refs.iter_mut().enumerate() {
        let w = gen.benign_window(6);
        wants.push(reference.score_quant(&w.data));
        set.add(mi as u64, registry.submit_async(name, w).expect("admitted"));
    }
    assert_eq!(set.pending(), refs.len());
    // "First of N lanes": completions arrive in whatever order the lanes
    // finish; every lane shows up exactly once and bits match per key.
    let mut seen = vec![false; refs.len()];
    while let Some((key, outcome)) = set.wait() {
        let r = outcome.expect("accepted work completes");
        assert!(!seen[key as usize], "each lane completes once");
        seen[key as usize] = true;
        assert_eq!(r.score.to_bits(), wants[key as usize].to_bits());
    }
    assert!(seen.iter().all(|&s| s), "all four lanes fan in");
    assert_eq!(set.pending(), 0);
    registry.shutdown();
}

/// Backend whose scoring blocks until the test drops the gate sender —
/// makes queue-full conditions deterministic.
struct GatedBackend {
    gate: Mutex<Receiver<()>>,
}

impl Backend for GatedBackend {
    fn name(&self) -> String {
        "gated".into()
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        let _ = self.gate.lock().unwrap().recv();
        vec![0.0; windows.len()]
    }
}

fn tiny_window() -> Window {
    Window { data: vec![vec![0.0f32]], anomaly: None }
}

#[test]
fn async_shed_and_backpressure_semantics_match_blocking() {
    // Same stalled-backend setup as the blocking shed test in
    // server/fabric.rs: bounded queues fill behind a gated worker, and
    // the async surface must shed with Overloaded exactly where the
    // blocking one does — before any ticket is issued — while accepted
    // tickets survive the overload and complete after release.
    let (gate_tx, gate_rx) = channel::<()>();
    let backend = Arc::new(GatedBackend { gate: Mutex::new(gate_rx) });
    let mut registry = ModelRegistry::new();
    let cfg = ServerConfig::builder()
        .max_batch(1)
        .max_wait(Duration::from_micros(1))
        .workers(1)
        .queue_capacity(2)
        .threshold(1.0)
        .build();
    registry.register("gated", backend, cfg);
    let lane = registry.lane("gated").unwrap();
    let attempts = 32u64;
    let mut tickets = Vec::new();
    let mut rxs = Vec::new();
    let mut shed = 0u64;
    for i in 0..attempts {
        // Interleave the two surfaces: both feed the same bounded queue.
        if i % 2 == 0 {
            match registry.submit_async("gated", tiny_window()) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Overloaded) => shed += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        } else {
            match registry.submit("gated", tiny_window()) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::Overloaded) => shed += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
    }
    let m = lane.metrics();
    assert!(shed > 0, "bounded queues must shed under a stalled backend");
    assert!(!tickets.is_empty());
    assert_eq!(m.submitted() + m.shed() + m.rejected_closed(), attempts);
    assert_eq!(m.shed(), shed);
    assert_eq!(m.rejected_closed(), 0);
    let inflight_before = lane.async_inflight();
    assert_eq!(inflight_before, tickets.len(), "one router slot per accepted ticket");
    // Release the gate: every accepted request completes (recovery)
    // through whichever surface submitted it; the shed ones were never
    // issued a ticket or a receiver at all.
    drop(gate_tx);
    for t in &tickets {
        let r = t.wait().expect("accepted work survives overload");
        assert_eq!(r.score, 0.0);
    }
    for rx in rxs {
        let r = rx.recv().expect("accepted blocking work survives overload");
        assert_eq!(r.score, 0.0);
    }
    // Conservation after drain: submitted == completed, in-flight == 0.
    assert!(wait_for(|| m.completed() == m.submitted()));
    assert!(wait_for(|| lane.async_inflight() == 0));
    // Fresh traffic flows again through both surfaces.
    assert!(registry.score_blocking("gated", tiny_window()).is_ok());
    assert!(registry.submit_async("gated", tiny_window()).unwrap().wait().is_ok());
    registry.shutdown();
}

#[test]
fn dropped_tickets_leak_no_router_slots_and_never_block_shutdown() {
    let topo = Topology::from_name("F32-D2").unwrap();
    let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(topo.clone(), 21)));
    let mut registry = ModelRegistry::new();
    registry.register(&topo.name, backend, ServerConfig::default());
    let lane = registry.lane("F32-D2").unwrap();
    let mut gen = TelemetryGen::new(32, 23);
    // Submit and immediately drop every ticket: the requests still run,
    // the router still delivers, and the slots drain to zero — abandoned
    // tickets cost nothing.
    for _ in 0..20 {
        let ticket = registry.submit_async("F32-D2", gen.benign_window(4)).expect("admitted");
        drop(ticket);
    }
    assert!(
        wait_for(|| lane.metrics().completed() == 20),
        "dropped tickets must not cancel accepted work"
    );
    assert!(
        wait_for(|| lane.async_inflight() == 0),
        "slots of dropped tickets must drain, not leak \
         (still {} in flight)",
        lane.async_inflight()
    );
    // A callback registered before the drop is fire-and-forget: it runs
    // even though nothing holds the ticket.
    let (cb_tx, cb_rx) = channel();
    registry
        .submit_async("F32-D2", gen.benign_window(4))
        .expect("admitted")
        .on_complete(move |outcome| {
            let _ = cb_tx.send(outcome.expect("completes").score);
        });
    let score = cb_rx.recv_timeout(Duration::from_secs(5)).expect("callback fires");
    assert!(score.is_finite() && score >= 0.0);
    // Shutdown with zero live tickets must not block.
    registry.shutdown();
    assert!(matches!(
        registry.submit_async("F32-D2", gen.benign_window(4)),
        Err(SubmitError::Closed)
    ));
}

/// Panics on the marker window — kills its worker mid-batch.
struct PanickingBackend;

impl Backend for PanickingBackend {
    fn name(&self) -> String {
        "panicking".into()
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        if windows.iter().any(|w| w.data[0][0] == 666.0) {
            panic!("injected backend failure (expected by integration_front)");
        }
        vec![0.0; windows.len()]
    }
}

#[test]
fn shutdown_poisons_tickets_orphaned_by_a_worker_panic() {
    let mut registry = ModelRegistry::new();
    let cfg = ServerConfig::builder()
        .max_batch(1)
        .max_wait(Duration::from_micros(1))
        .workers(1)
        .queue_capacity(64)
        .threshold(1.0)
        .build();
    registry.register("panicky", Arc::new(PanickingBackend), cfg);
    let lane = registry.lane("panicky").unwrap();
    let poison = Window { data: vec![vec![666.0f32]], anomaly: None };
    let ticket = registry.submit_async("panicky", poison).expect("admitted");
    // The worker dies without replying: the ticket stays in flight (a
    // timeout-bounded wait returns None, ticket still live) ...
    assert!(
        wait_for(|| lane.metrics().worker_panics() == 1),
        "panic must be counted"
    );
    assert!(ticket.wait_timeout(Duration::from_millis(50)).is_none());
    assert_eq!(lane.async_inflight(), 1);
    // ... until shutdown, whose router drain poisons the orphaned slot so
    // waiters wake with Closed instead of hanging forever.
    registry.shutdown();
    assert_eq!(ticket.wait().unwrap_err(), SubmitError::Closed);
    assert_eq!(lane.async_inflight(), 0);
}

#[test]
fn async_driver_sustains_4x_outstanding_at_equal_threads_without_shedding() {
    // The acceptance bar, deterministically: at the same client-thread
    // count, the ticket driver holds ≥ 4× the outstanding requests of
    // the blocking driver and the lanes shed nothing either way (peak
    // outstanding is reached by construction — the driver fills its
    // CompletionSet before reaping — so this does not depend on timing).
    let clients = 4usize;
    let per_client = 16usize; // 16× blocking per thread
    for (name, seed) in [("F32-D2", 31u64), ("F64-D2", 32u64)] {
        let topo = Topology::from_name(name).unwrap();
        let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(topo.clone(), seed)));
        let mut registry = ModelRegistry::new();
        registry.register(
            &topo.name,
            backend,
            ServerConfig::builder().queue_capacity(1024).build(),
        );
        let models = vec![topo.name.clone()];
        let blocking = closed_loop_blocking(&registry, &models, clients, 256, 4, 33);
        let async_stats = closed_loop_async(&registry, &models, clients, per_client, 256, 4, 33);
        assert_eq!(blocking.completed, 256);
        assert_eq!(async_stats.completed, 256);
        assert_eq!(async_stats.failed, 0);
        assert_eq!(blocking.max_outstanding, clients, "blocking: one per thread");
        assert!(
            async_stats.max_outstanding >= 4 * blocking.max_outstanding,
            "{name}: async outstanding {} must be ≥ 4× blocking {}",
            async_stats.max_outstanding,
            blocking.max_outstanding
        );
        let m = registry.lane(name).unwrap().metrics();
        assert_eq!(m.shed(), 0, "{name}: equal shed rate (zero) for both drivers");
        assert_eq!(async_stats.shed_retries + blocking.shed_retries, 0);
        registry.shutdown();
    }
}

#[test]
fn open_loop_trace_replay_through_tickets_matches_blocking_accounting() {
    // The same merged Poisson trace the fleet CLI replays, pushed through
    // tickets by a single submitter thread: accounting is exhaustive and
    // accepted work all completes — shed/backpressure semantics are the
    // blocking replay's, with no thread parked per request.
    let registry = ModelRegistry::paper_fleet(51, ExecMode::Auto, 2);
    let models: Vec<String> = registry.models().map(String::from).collect();
    let topos: Vec<Topology> = models
        .iter()
        .map(|m| Topology::from_name(m).unwrap())
        .collect();
    let trace = merged_poisson(&topos, 53, 8000.0, 400, 4, 0.1);
    let n = trace.len() as u64;
    let stats = replay_async(&registry, &models, trace);
    assert_eq!(stats.accepted + stats.shed + stats.rejected, n);
    assert_eq!(stats.rejected, 0, "no lane closed mid-replay");
    assert_eq!(stats.completed + stats.failed, stats.accepted);
    assert_eq!(stats.failed, 0, "healthy lanes complete every accepted ticket");
    let completed: u64 = models
        .iter()
        .map(|m| registry.lane(m).unwrap().metrics().completed())
        .sum();
    assert_eq!(completed, stats.accepted);
    registry.shutdown();
}
