//! Cross-module property-test battery: invariants that span modules, run
//! at higher case counts than the in-module unit tests.

use std::sync::Arc;

use lstm_ae_accel::accel::dataflow::{DataflowSim, SimOptions};
use lstm_ae_accel::accel::latency::LatencyModel;
use lstm_ae_accel::accel::multi::run_batch;
use lstm_ae_accel::accel::optimizer::{evaluate, optimize, Objective};
use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::engine::{BatchEngine, PipelineOptions, TemporalPipeline};
use lstm_ae_accel::fixed::Q8_24;
use lstm_ae_accel::model::lstm::{QuantLstmCell, QuantLstmState, StepScratch};
use lstm_ae_accel::model::topology::LayerDims;
use lstm_ae_accel::model::weights::LayerWeights;
use lstm_ae_accel::model::{LstmAutoencoder, ModelWeights, Topology};
use lstm_ae_accel::util::json::Json;
use lstm_ae_accel::util::prop::props;
use lstm_ae_accel::util::rng::Xoshiro256;

fn random_topo(g: &mut lstm_ae_accel::util::prop::Gen) -> Option<Topology> {
    let f = 1usize << g.usize_in(3, 6);
    let d = 2 * g.usize_in(1, 3);
    Topology::new(f, d).ok()
}

#[test]
fn acc_lat_additive_in_t() {
    // acc_lat(a + b) = acc_lat(a) + b·Lat_m for any split (affine form).
    props("acc_lat_affine", 256, |g| {
        let Some(topo) = random_topo(g) else { return };
        let lm = LatencyModel::of(&BalancedConfig::balance(&topo, g.u64_below(8) + 1));
        let a = g.usize_in(1, 200);
        let b = g.usize_in(1, 200);
        assert_eq!(lm.acc_lat(a + b), lm.acc_lat(a) + b as u64 * lm.lat_t_m());
    });
}

#[test]
fn sim_never_beats_analytical() {
    // Eq 1 is the lower bound; bounded FIFOs / reader rates only add.
    props("sim_lower_bound", 128, |g| {
        let Some(topo) = random_topo(g) else { return };
        let cfg = if g.bool() {
            BalancedConfig::balance(&topo, g.u64_below(8) + 1)
        } else {
            BalancedConfig::uniform(&topo, g.u64_below(4) + 1)
        };
        let lm = LatencyModel::of(&cfg);
        let opts = SimOptions {
            fifo_capacity: g.usize_in(1, 4),
            reader_cycles_per_t: g.u64_below(3),
            writer_cycles_per_t: g.u64_below(3),
        };
        let t = g.usize_in(1, 64);
        let run = DataflowSim::with_options(&cfg, opts).run_sequence(t);
        assert!(run.total_cycles >= lm.acc_lat(t));
    });
}

#[test]
fn batch_throughput_monotone_in_batch_size() {
    props("batch_monotone", 64, |g| {
        let Some(topo) = random_topo(g) else { return };
        let cfg = BalancedConfig::balance(&topo, g.u64_below(4) + 1);
        let t = g.usize_in(1, 16);
        let n1 = g.usize_in(1, 8);
        let n2 = n1 + g.usize_in(1, 8);
        let hz = 300.0e6;
        let tp1 = run_batch(&cfg, SimOptions::default(), t, n1).throughput_seq_per_s(hz);
        let tp2 = run_batch(&cfg, SimOptions::default(), t, n2).throughput_seq_per_s(hz);
        assert!(tp2 >= tp1 * 0.999, "throughput must not degrade with batch: {tp1} -> {tp2}");
    });
}

#[test]
fn optimizer_output_always_fits_and_is_minimal() {
    props("optimizer_sound", 32, |g| {
        let Some(topo) = random_topo(g) else { return };
        let dev = *g.choose(&[FpgaDevice::ZCU104, FpgaDevice::ALVEO_U50]);
        let t = g.usize_in(1, 64);
        if let Some(p) = optimize(&topo, &dev, t, Objective::Latency) {
            assert!(p.fits);
            for smaller in 1..p.rh_m {
                assert!(!evaluate(&topo, &dev, smaller, t).fits);
            }
        }
    });
}

#[test]
fn quant_forward_bounded_outputs() {
    // LSTM output gate bounds |h| ≤ 1 regardless of input magnitude;
    // holds through the entire quantized stack (saturation-safe).
    props("quant_bounded", 24, |g| {
        let Some(topo) = random_topo(g) else { return };
        let f = topo.features;
        let ae = LstmAutoencoder::random(topo, g.case as u64);
        let x: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..f).map(|_| g.f32_in(-50.0, 50.0)).collect())
            .collect();
        for row in ae.forward_quant(&x) {
            for v in row {
                assert!(v.abs() <= 1.0 + 1e-6, "output {v} out of gate bound");
            }
        }
    });
}

#[test]
fn engine_paths_bit_identical_to_forward_quant() {
    // The tentpole invariant: every engine execution path — per-layer
    // worker pipeline and batched MMM kernel — reproduces
    // forward_quant to the bit across random topologies, seeds, sequence
    // lengths (including T=1), and batch sizes (including B=1).
    props("engine_bit_identical", 20, |g| {
        let Some(topo) = random_topo(g) else { return };
        let f = topo.features;
        let ae = Arc::new(LstmAutoencoder::random(topo, g.case as u64 + 7));
        let t = *g.choose(&[1usize, 2, 3, 8, 17]);
        let b = g.usize_in(1, 5);
        let windows: Vec<Vec<Vec<f32>>> = (0..b)
            .map(|_| (0..t).map(|_| g.vec_f32(f, -2.0, 2.0)).collect())
            .collect();
        let refs: Vec<&[Vec<f32>]> = windows.iter().map(|w| w.as_slice()).collect();
        let golden: Vec<Vec<Vec<f32>>> =
            windows.iter().map(|w| ae.forward_quant(w)).collect();

        let batch = BatchEngine::new(ae.clone());
        assert_eq!(batch.forward_batch(&refs), golden, "batched MMM path");

        let pipe = TemporalPipeline::new(ae.clone());
        assert_eq!(pipe.forward_batch(&refs), golden, "pipelined path");

        // Scores too (the serving contract), down to the f64 bit.
        let batch_scores = batch.score_batch(&refs);
        for (i, w) in windows.iter().enumerate() {
            let want = ae.score_quant(w).to_bits();
            assert_eq!(pipe.score(w).to_bits(), want, "pipeline score {i}");
            assert_eq!(batch_scores[i].to_bits(), want, "batch score {i}");
        }
    });
}

#[test]
fn engine_agrees_with_dataflow_sim_functional_output() {
    // Sim functional pass (now also on the engine scratch path), the
    // pipeline, and the golden model must all coincide exactly.
    props("engine_vs_sim", 12, |g| {
        let Some(topo) = random_topo(g) else { return };
        let f = topo.features;
        let weights = ModelWeights::random(&topo, g.case as u64 + 31);
        let cfg = BalancedConfig::balance(&topo, g.u64_below(4) + 1);
        let t = g.usize_in(1, 10);
        let x: Vec<Vec<f32>> = (0..t).map(|_| g.vec_f32(f, -1.0, 1.0)).collect();
        let (_, sim_out) = DataflowSim::new(&cfg).run_with_data(&weights, &x);
        let ae = Arc::new(LstmAutoencoder::new(topo, weights).unwrap());
        assert_eq!(sim_out, ae.forward_quant(&x), "sim vs golden");
        let pipe = TemporalPipeline::new(ae.clone());
        assert_eq!(sim_out, pipe.forward_quant(&x), "sim vs pipeline");
    });
}

#[test]
fn interleaved_kernels_bit_identical_on_paper_topologies() {
    // Layout equivalence at the paper's four operating points: the
    // gate-interleaved kernels must reproduce the row-major reference
    // to the bit on every layer of every paper model — single-step and
    // batched, with batch sizes straddling the kernel's B-tile.
    for topo in Topology::paper_models() {
        let name = topo.name.clone();
        let ae = LstmAutoencoder::random(topo, 91);
        let mut rng = Xoshiro256::seeded(17);
        let mut scratch = StepScratch::new();
        for (li, cell) in ae.quant_cells().iter().enumerate() {
            let (lx, lh) = (cell.w.dims.lx, cell.w.dims.lh);
            let mut a = QuantLstmState::zeros(lh);
            let mut b = QuantLstmState::zeros(lh);
            for _ in 0..3 {
                let x: Vec<Q8_24> =
                    (0..lx).map(|_| Q8_24::from_f64(rng.uniform(-2.0, 2.0))).collect();
                cell.step_into(&mut a, &x, &mut scratch);
                cell.step_into_rowmajor(&mut b, &x, &mut scratch);
                assert_eq!(a.h, b.h, "{name} layer {li}: h diverged");
                assert_eq!(a.c, b.c, "{name} layer {li}: c diverged");
            }
            for bsz in [1usize, 7, 9] {
                let xb: Vec<Q8_24> =
                    (0..bsz * lx).map(|_| Q8_24::from_f64(rng.uniform(-2.0, 2.0))).collect();
                let mut h1 = vec![Q8_24::ZERO; bsz * lh];
                let mut c1 = vec![Q8_24::ZERO; bsz * lh];
                let mut h2 = vec![Q8_24::ZERO; bsz * lh];
                let mut c2 = vec![Q8_24::ZERO; bsz * lh];
                for _ in 0..3 {
                    cell.step_batch_into(bsz, &mut h1, &mut c1, &xb, &mut scratch);
                    cell.step_batch_into_rowmajor(bsz, &mut h2, &mut c2, &xb, &mut scratch);
                }
                assert_eq!(h1, h2, "{name} layer {li} B={bsz}: batched h diverged");
                assert_eq!(c1, c2, "{name} layer {li} B={bsz}: batched c diverged");
            }
        }
    }
}

#[test]
fn interleaved_kernels_bit_identical_on_edge_shapes() {
    // The shapes most likely to break an interleave or tiling bug:
    // lh = 1 (a single four-lane block), lx ≠ lh (rectangular weights),
    // B = 1 (degenerate tile), and batch sizes straddling BATCH_TILE.
    props("layout_edge_shapes", 48, |g| {
        let lx = g.usize_in(1, 24);
        let lh = if g.bool() { 1 } else { g.usize_in(1, 24) };
        let mut rng = Xoshiro256::seeded(g.case as u64 + 3);
        let w = LayerWeights::random(LayerDims { lx, lh }, &mut rng);
        let cell = QuantLstmCell::new(&w);
        let mut scratch = StepScratch::new();

        let mut a = QuantLstmState::zeros(lh);
        let mut b = QuantLstmState::zeros(lh);
        for _ in 0..4 {
            let x: Vec<Q8_24> = (0..lx).map(|_| Q8_24::from_f64(rng.uniform(-3.0, 3.0))).collect();
            cell.step_into(&mut a, &x, &mut scratch);
            cell.step_into_rowmajor(&mut b, &x, &mut scratch);
        }
        assert_eq!(a.h, b.h, "{lx}x{lh}: h diverged");
        assert_eq!(a.c, b.c, "{lx}x{lh}: c diverged");

        let bsz = *g.choose(&[1usize, 2, 7, 8, 9, 13]);
        let xb: Vec<Q8_24> =
            (0..bsz * lx).map(|_| Q8_24::from_f64(rng.uniform(-3.0, 3.0))).collect();
        let mut h1 = vec![Q8_24::ZERO; bsz * lh];
        let mut c1 = vec![Q8_24::ZERO; bsz * lh];
        let mut h2 = vec![Q8_24::ZERO; bsz * lh];
        let mut c2 = vec![Q8_24::ZERO; bsz * lh];
        for _ in 0..4 {
            cell.step_batch_into(bsz, &mut h1, &mut c1, &xb, &mut scratch);
            cell.step_batch_into_rowmajor(bsz, &mut h2, &mut c2, &xb, &mut scratch);
        }
        assert_eq!(h1, h2, "{lx}x{lh} B={bsz}: batched h diverged");
        assert_eq!(c1, c2, "{lx}x{lh} B={bsz}: batched c diverged");
    });
}

#[test]
fn mixed_length_batches_bit_identical_through_backend() {
    // Mixed-T batches take every routing branch of the quant backend
    // (length-grouped MMM, pooled pipeline pass over the singletons);
    // all of them sit on the interleaved kernels and must reproduce the
    // sequential scorer bit for bit.
    use lstm_ae_accel::server::{Backend, QuantBackend};
    use lstm_ae_accel::workload::Window;
    props("mixed_t_backend", 8, |g| {
        let Some(topo) = random_topo(g) else { return };
        let f = topo.features;
        let ae = LstmAutoencoder::random(topo, g.case as u64 + 51);
        let windows: Vec<Window> = (0..g.usize_in(2, 6))
            .map(|_| {
                let t = *g.choose(&[1usize, 2, 5, 5, 9]); // repeats force grouping
                Window {
                    data: (0..t).map(|_| g.vec_f32(f, -2.0, 2.0)).collect(),
                    anomaly: None,
                }
            })
            .collect();
        let golden: Vec<u64> = windows.iter().map(|w| ae.score_quant(&w.data).to_bits()).collect();
        let backend = QuantBackend::new(ae);
        let refs: Vec<&Window> = windows.iter().collect();
        let got = backend.score_batch(&refs);
        for (want, s) in golden.into_iter().zip(got) {
            assert_eq!(s.to_bits(), want, "mixed-T batch diverged from sequential scorer");
        }
    });
}

#[test]
fn pinned_pipeline_bit_identical_to_unpinned() {
    // Core pinning is a scheduling hint, never a numeric change: the
    // pinned pipeline must reproduce the unpinned one (and thus
    // forward_quant) exactly, whatever cores the mask lands on.
    props("pinned_identity", 6, |g| {
        let Some(topo) = random_topo(g) else { return };
        let f = topo.features;
        let ae = Arc::new(LstmAutoencoder::random(topo, g.case as u64 + 23));
        let t = *g.choose(&[1usize, 3, 11]);
        let x: Vec<Vec<f32>> = (0..t).map(|_| g.vec_f32(f, -2.0, 2.0)).collect();
        let golden = ae.forward_quant(&x);
        let pinned = TemporalPipeline::with_options(
            ae.clone(),
            PipelineOptions {
                pin_base_core: Some(g.usize_in(0, 3)),
                ..Default::default()
            },
        );
        assert_eq!(pinned.forward_quant(&x), golden, "pinned pipeline diverged");
    });
}

#[test]
fn fixed_point_distributivity_within_rounding() {
    // a·(b + c) ≈ a·b + a·c within 1.5 ulp (two extra roundings).
    props("fixed_distrib", 1024, |g| {
        let a = Q8_24::from_f64(g.f64_in(-8.0, 8.0));
        let b = Q8_24::from_f64(g.f64_in(-4.0, 4.0));
        let c = Q8_24::from_f64(g.f64_in(-4.0, 4.0));
        let lhs = a.mul(b.add(c));
        let rhs = a.mul(b).add(a.mul(c));
        let d = (lhs.0 as i64 - rhs.0 as i64).abs();
        assert!(d <= 2, "distributivity gap {d} ulp");
    });
}

#[test]
fn json_roundtrip_fuzz() {
    // Random JSON trees survive serialize → parse exactly.
    fn gen_json(g: &mut lstm_ae_accel::util::prop::Gen, depth: usize) -> Json {
        if depth == 0 {
            return match g.u64_below(4) {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                _ => {
                    let n = g.u64_below(1000);
                    Json::Str(format!("s{}-{}", g.case, n))
                }
            };
        }
        match g.u64_below(2) {
            0 => Json::Arr((0..g.usize_in(0, 4)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    props("json_fuzz", 256, |g| {
        let v = gen_json(g, 3);
        let compact = Json::parse(&v.to_string()).expect("compact parse");
        assert_eq!(compact, v);
        let pretty = Json::parse(&v.to_string_pretty()).expect("pretty parse");
        assert_eq!(pretty, v);
    });
}

#[test]
fn telemetry_spec_roundtrip_preserves_stream() {
    // Export a generator's family as JSON (as aot.py does), reload, and
    // verify the deterministic latent part matches. (Noise differs by
    // seed; compare with noise quenched via large window means.)
    props("spec_roundtrip", 16, |g| {
        use lstm_ae_accel::workload::{TelemetryGen, LATENTS};
        let f = 8 * (1 + g.usize_in(0, 3));
        let seed = g.case as u64 + 1;
        // Build a spec JSON by sampling one generator's behaviour: we
        // re-derive the family params by constructing from_spec with
        // values pulled from a fresh generator's JSON round trip.
        let mut mk = Xoshiro256::seeded(seed);
        let freq: Vec<f64> =
            (0..LATENTS).map(|_| 2.0 * std::f64::consts::PI / mk.uniform(8.0, 64.0)).collect();
        let phase: Vec<f64> = (0..LATENTS).map(|_| mk.uniform(0.0, 6.28)).collect();
        let mix: Vec<f64> = (0..f * LATENTS).map(|_| mk.uniform(-0.2, 0.2)).collect();
        let spec = Json::obj(vec![
            ("features", Json::num(f as f64)),
            ("latents", Json::num(LATENTS as f64)),
            ("freq", Json::Arr(freq.iter().map(|&v| Json::num(v)).collect())),
            ("phase", Json::Arr(phase.iter().map(|&v| Json::num(v)).collect())),
            ("mix", Json::Arr(mix.iter().map(|&v| Json::num(v)).collect())),
            ("noise_std", Json::num(0.0)),
        ]);
        let mut a = TelemetryGen::from_spec(&spec, 1).expect("spec");
        let mut b = TelemetryGen::from_spec(&Json::parse(&spec.to_string()).unwrap(), 2)
            .expect("spec roundtrip");
        // Zero noise ⇒ identical streams regardless of seed.
        assert_eq!(a.benign_window(16).data, b.benign_window(16).data);
    });
}
