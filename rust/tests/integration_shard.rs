//! Integration: the network shard fabric over real loopback sockets —
//! bit-identity of remote scores vs `ExecMode::Sequential`, cross-shard
//! backpressure (`Shed` frames → `Err(Overloaded)` tickets), the version
//! handshake gate, remote fleet reports, and zero-loss failover when a
//! shard process dies mid-trace.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use lstm_ae_accel::engine::ExecMode;
use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::net::{
    wire, Frame, ShardClient, ShardServer, WireError, WIRE_VERSION,
};
use lstm_ae_accel::server::{
    CompletionSet, ModelRegistry, RouterConfig, ServerConfig, ServingSurface, ShardRouter,
    ShardState, SubmitError, ThrottledBackend,
};
use lstm_ae_accel::workload::{trace, TelemetryGen, Window};

/// A shard process in miniature: a paper-fleet registry behind a
/// `ShardServer` on an ephemeral loopback port.
fn spawn_shard(seed: u64) -> (ShardServer, String) {
    let registry = Arc::new(ModelRegistry::paper_fleet(seed, ExecMode::Auto, 2));
    let server = ShardServer::bind("127.0.0.1:0", registry).expect("bind loopback");
    let addr = server.local_addr().to_string();
    (server, addr)
}

#[test]
fn remote_scores_are_bit_identical_to_sequential_across_all_four_models() {
    let seed = 170;
    let (server, addr) = spawn_shard(seed);
    let router = ShardRouter::connect(&[addr]).expect("connect");
    // References rebuilt from the paper_fleet seeding convention: model i
    // uses seed + i, and score_quant IS ExecMode::Sequential arithmetic.
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let reference = LstmAutoencoder::random(topo.clone(), seed + i as u64);
        let mut gen = TelemetryGen::new(topo.features, 400 + i as u64);
        let mut pending = Vec::new();
        for round in 0..12usize {
            let t = [4usize, 8, 6, 1][round % 4];
            let w = gen.benign_window(t);
            let want = reference.score_quant(&w.data);
            let ticket = router.submit_async(&topo.name, w).expect("submitted");
            pending.push((ticket, want));
        }
        for (ticket, want) in pending {
            let r = ticket.wait().expect("remote score arrives");
            assert_eq!(
                r.score.to_bits(),
                want.to_bits(),
                "{}: wire-transported score must be bit-identical to sequential",
                topo.name
            );
        }
    }
    router.shutdown();
    server.shutdown();
}

#[test]
fn remote_shed_resolves_tickets_overloaded_and_lane_recovers() {
    // A deliberately tiny lane (slow backend, queue of 2) behind a shard:
    // a burst must shed — and the shed must cross the wire as a Shed
    // frame, resolving tickets to Err(Overloaded), not hanging them.
    let mut registry = ModelRegistry::new();
    registry.register(
        "tiny",
        Arc::new(ThrottledBackend::zeros(Duration::from_millis(30))),
        ServerConfig::builder()
            .max_batch(1)
            .max_wait(Duration::from_micros(50))
            .workers(1)
            .queue_capacity(2)
            .threshold(1.0)
            .build(),
    );
    let server = ShardServer::bind("127.0.0.1:0", Arc::new(registry)).expect("bind");
    let client = ShardClient::connect(&server.local_addr().to_string()).expect("connect");
    let window = || Window { data: vec![vec![0.0f32; 4]; 2], anomaly: None };
    let tickets: Vec<_> =
        (0..48).map(|_| client.submit_async("tiny", &window()).expect("conn up")).collect();
    let (mut ok, mut shed) = (0u64, 0u64);
    for t in tickets {
        match t.wait() {
            Ok(r) => {
                assert_eq!(r.score, 0.0);
                ok += 1;
            }
            Err(SubmitError::Overloaded) => shed += 1,
            Err(e) => panic!("unexpected outcome {e}"),
        }
    }
    assert!(shed > 0, "a burst of 48 into queue=2 must shed over the wire");
    assert!(ok > 0, "accepted work survives the overload");
    // Backpressure is load shedding, not failure: fresh traffic scores.
    let r = client.submit_async("tiny", &window()).unwrap().wait().expect("lane recovered");
    assert_eq!(r.score, 0.0);
    // Unknown models are rejected per-request, not per-connection.
    let verdict = client.submit_async("no-such-model", &window()).unwrap().wait();
    assert!(matches!(verdict, Err(SubmitError::UnknownModel(_))));
    // So are windows too large for a wire frame — the pre-flight gate
    // fires before the socket, and the connection stays healthy.
    let giant = Window { data: vec![vec![0.0f32; 4096]; 1025], anomaly: None };
    assert!(matches!(client.submit_async("tiny", &giant), Err(SubmitError::TooLarge)));
    // ...and so are ragged windows, which the frame layout cannot carry.
    let ragged = Window { data: vec![vec![0.0f32; 4], vec![0.0f32; 3]], anomaly: None };
    assert!(matches!(client.submit_async("tiny", &ragged), Err(SubmitError::TooLarge)));
    let r = client.submit_async("tiny", &window()).unwrap().wait().expect("conn survives");
    assert_eq!(r.score, 0.0);
    client.shutdown();
    server.shutdown();
}

#[test]
fn version_mismatch_hello_is_refused_by_the_server() {
    let (server, addr) = spawn_shard(3);
    let mut stream = TcpStream::connect(&addr).expect("tcp connect");
    // Speak a future protocol version; the server must answer with its
    // own Hello (so we can diagnose) and then refuse the connection.
    wire::write_frame(&mut stream, &Frame::Hello { version: WIRE_VERSION + 1 }).unwrap();
    match wire::read_frame(&mut stream) {
        Ok(Some(Frame::Hello { version })) => assert_eq!(version, WIRE_VERSION),
        other => panic!("server must send its Hello before refusing, got {other:?}"),
    }
    // No submission is ever served on a refused connection: the server
    // closes, so the next read is clean EOF (or a reset, depending on
    // timing) — never a Response.
    let _ = wire::write_frame(
        &mut stream,
        &Frame::Submit { id: 0, model: "LSTM-AE-F32-D2".into(), window: vec![vec![0.0]] },
    );
    match wire::read_frame(&mut stream) {
        Ok(None) | Err(_) => {}
        Ok(Some(f)) => panic!("refused connection must not serve frames, got {f:?}"),
    }
    server.shutdown();
}

#[test]
fn version_mismatch_hello_is_refused_by_the_client() {
    // A fake shard speaking a different version: ShardClient::connect
    // must fail the handshake with BadVersion, not hand out tickets.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let _ = wire::write_frame(&mut s, &Frame::Hello { version: WIRE_VERSION + 7 });
        let _ = wire::read_frame(&mut s); // the client's Hello
    });
    match ShardClient::connect(&addr) {
        Err(WireError::BadVersion { got, want }) => {
            assert_eq!(got, WIRE_VERSION + 7);
            assert_eq!(want, WIRE_VERSION);
        }
        other => panic!("want BadVersion, got {other:?}"),
    }
    fake.join().unwrap();
}

#[test]
fn fleet_report_travels_over_the_wire() {
    let (server, addr) = spawn_shard(9);
    let client = ShardClient::connect(&addr).expect("connect");
    let mut gen = TelemetryGen::new(32, 5);
    let t = client.submit_async("LSTM-AE-F32-D2", &gen.benign_window(4)).unwrap();
    t.wait().expect("scored");
    let report = client.fleet_report(Duration::from_secs(5)).expect("report");
    assert!(report.contains("LSTM-AE-F64-D6"), "{report}");
    assert!(report.contains("4 lanes"), "{report}");
    client.shutdown();
    server.shutdown();
}

#[test]
fn killing_a_shard_mid_trace_fails_over_with_zero_lost_tickets() {
    // Two shards with identical seeds (identical weights), one router
    // over both. Kill shard A with half the trace in flight: every
    // ticket must still resolve — in-flight ones poison Err(Closed) and
    // are re-offered to shard B — and every completed score must still
    // be bit-identical to the sequential reference.
    let seed = 210;
    let (srv_a, addr_a) = spawn_shard(seed);
    let (srv_b, addr_b) = spawn_shard(seed);
    let router = ShardRouter::connect(&[addr_a, addr_b]).expect("connect both");
    assert_eq!(router.live_shards(), 2);

    let topos = Topology::paper_models();
    let refs: Vec<LstmAutoencoder> = topos
        .iter()
        .enumerate()
        .map(|(i, topo)| LstmAutoencoder::random(topo.clone(), seed + i as u64))
        .collect();
    let mut gens: Vec<TelemetryGen> = topos
        .iter()
        .enumerate()
        .map(|(i, topo)| TelemetryGen::new(topo.features, 600 + i as u64))
        .collect();

    let total = 240usize;
    let mut set = CompletionSet::new();
    // key → (model index, window, reference score bits): enough to retry
    // a Closed outcome and to verify bit-identity wherever it completes.
    let mut inflight: HashMap<u64, (usize, Window, u64)> = HashMap::new();
    for k in 0..total {
        let mi = k % topos.len();
        let w = gens[mi].benign_window(4);
        let want = refs[mi].score_quant(&w.data).to_bits();
        let ticket = router.submit_async(&topos[mi].name, w.clone()).expect("two live shards");
        inflight.insert(k as u64, (mi, w, want));
        set.add(k as u64, ticket);
        if k == total / 2 {
            // Mid-trace shard death, with up to half the trace in flight.
            srv_a.shutdown();
            // Wait for the router to observe the death (its client's
            // reader sees EOF asynchronously) so the back half of the
            // trace deterministically routes around the dead shard.
            let deadline = std::time::Instant::now() + Duration::from_secs(5);
            while router.live_shards() != 1 {
                assert!(
                    std::time::Instant::now() < deadline,
                    "client must observe the shard death"
                );
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    let mut completed = 0u64;
    let mut retried = 0u64;
    while let Some((key, outcome)) = set.wait() {
        match outcome {
            Ok(r) => {
                let (_, _, want) = inflight.remove(&key).expect("known key");
                assert_eq!(
                    r.score.to_bits(),
                    want,
                    "failover must not change a single bit of any score"
                );
                completed += 1;
            }
            Err(SubmitError::Closed) => {
                // Died with shard A: re-offer through the router, which
                // must route it to the surviving shard.
                let (mi, w, _) = inflight.get(&key).expect("known key").clone();
                let ticket = router
                    .submit_async(&topos[mi].name, w)
                    .expect("surviving shard accepts the retry");
                retried += 1;
                set.add(key, ticket);
            }
            Err(e) => panic!("unexpected outcome {e}"),
        }
    }
    assert_eq!(completed as usize, total, "zero lost tickets across the shard death");
    assert!(inflight.is_empty());
    assert_eq!(router.live_shards(), 1, "the dead shard is routed around, not revived");
    assert!(
        router.metrics().shard_failovers() > 0,
        "submissions after the death must count as failovers (retried {retried})"
    );
    router.shutdown();
    srv_b.shutdown();
}

#[test]
fn restarted_shard_rejoins_the_fleet_without_operator_action() {
    // The self-healing loop end to end: kill a shard, restart the same
    // deployment on the SAME port, and the registry's backoff redial
    // must readmit it with zero operator action — while every score
    // stays bit-identical to the sequential reference throughout.
    let seed = 230;
    let (srv_a, addr_a) = spawn_shard(seed);
    let (srv_b, addr_b) = spawn_shard(seed);
    let cfg = RouterConfig {
        heartbeat_ms: 25,
        suspect_after: 2,
        dead_after: 4,
        reconnect_max_backoff_ms: 200,
    };
    let router = ShardRouter::connect_with(&[addr_a.clone(), addr_b], cfg).expect("connect both");
    assert_eq!(router.live_shards(), 2);

    let topos = Topology::paper_models();
    let refs: Vec<LstmAutoencoder> = topos
        .iter()
        .enumerate()
        .map(|(i, topo)| LstmAutoencoder::random(topo.clone(), seed + i as u64))
        .collect();
    let mut gens: Vec<TelemetryGen> = topos
        .iter()
        .enumerate()
        .map(|(i, topo)| TelemetryGen::new(topo.features, 700 + i as u64))
        .collect();
    // Submit-then-settle a burst; every ticket must resolve Ok with the
    // reference bits (no Closed leaks outside the kill window here —
    // each burst runs against a stable membership).
    let mut drive = |n: usize| {
        let mut pending = Vec::new();
        for k in 0..n {
            let mi = k % topos.len();
            let w = gens[mi].benign_window(4);
            let want = refs[mi].score_quant(&w.data).to_bits();
            let ticket = router.submit_async(&topos[mi].name, w).expect("routable shard");
            pending.push((ticket, want));
        }
        for (ticket, want) in pending {
            let r = ticket.wait().expect("scores");
            assert_eq!(r.score.to_bits(), want, "churn must not change a single score bit");
        }
    };
    drive(24);

    srv_a.shutdown();
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.metrics().shard_deaths() == 0 {
        assert!(Instant::now() < deadline, "health loop must demote the killed shard");
        std::thread::sleep(Duration::from_millis(5));
    }
    drive(12); // the survivor carries the trace while A is down

    // Same port, fresh process state: SO_REUSEADDR makes the rebind
    // immediate instead of waiting out TIME_WAIT.
    let registry = Arc::new(ModelRegistry::paper_fleet(seed, ExecMode::Auto, 2));
    let srv_a2 = loop {
        match ShardServer::bind(&addr_a, Arc::clone(&registry)) {
            Ok(s) => break s,
            Err(_) if Instant::now() < deadline => std::thread::sleep(Duration::from_millis(50)),
            Err(e) => panic!("rebind {addr_a}: {e}"),
        }
    };
    while router.live_shards() != 2 {
        assert!(Instant::now() < deadline, "restarted shard must rejoin automatically");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(router.shard_state(0), ShardState::Live);
    assert!(router.shard_generation(0) >= 1, "a rejoin bumps the slot generation");
    assert!(router.metrics().shard_reconnects() >= 1, "the rejoin is a counted reconnect");
    assert!(router.metrics().shard_deaths() >= 1);
    drive(24); // both shards again, still bit-identical

    router.shutdown();
    srv_a2.shutdown();
    srv_b.shutdown();
}

/// A scripted shard speaking the real wire protocol: answers `Submit`s
/// with a fixed score and echoes `HealthProbe`s — unless `withhold` is
/// set, in which case it stays silent (alive but unresponsive), which is
/// exactly the Suspect scenario.
fn scripted_shard(
    listener: TcpListener,
    withhold: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let (mut s, _) = listener.accept().expect("router dials");
        wire::handshake(&mut s).expect("handshake");
        if wire::write_frame(&mut s, &Frame::Join { shard_id: 0xFA4E, models: 4 }).is_err() {
            return;
        }
        loop {
            match wire::read_frame(&mut s) {
                Ok(Some(Frame::Submit { id, .. })) => {
                    let reply = Frame::Response {
                        id,
                        score: 0.25,
                        is_anomaly: false,
                        queue_us: 1.0,
                        service_us: 2.0,
                        e2e_us: 3.0,
                    };
                    if wire::write_frame(&mut s, &reply).is_err() {
                        break;
                    }
                }
                Ok(Some(Frame::HealthProbe { seq })) => {
                    if withhold.load(Ordering::SeqCst) {
                        continue; // alive, but not answering probes
                    }
                    let hb = Frame::Heartbeat {
                        seq,
                        inflight: 0,
                        shed_delta: 0,
                        p50_us: 10.0,
                        p99_us: 20.0,
                    };
                    if wire::write_frame(&mut s, &hb).is_err() {
                        break;
                    }
                }
                Ok(Some(_)) => {}
                Ok(None) | Err(_) => break,
            }
        }
    })
}

#[test]
fn slow_shard_flaps_to_suspect_and_back_without_poisoning_work() {
    // A shard that stops answering probes but keeps its socket (and its
    // service) alive must be demoted Suspect — not killed — and must
    // re-promote to Live on the next fresh heartbeat. Nothing completed
    // or in flight is poisoned across the flap.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let withhold = Arc::new(AtomicBool::new(false));
    let fake = scripted_shard(listener, Arc::clone(&withhold));
    let cfg = RouterConfig {
        heartbeat_ms: 20,
        suspect_after: 2,
        dead_after: 100_000, // flap test: never let Suspect decay to Dead
        reconnect_max_backoff_ms: 500,
    };
    let router = ShardRouter::connect_with(&[addr], cfg).expect("connect");
    let mut gen = TelemetryGen::new(32, 5);
    let score = |router: &ShardRouter, gen: &mut TelemetryGen| {
        let r = router
            .submit_async("LSTM-AE-F32-D2", gen.benign_window(4))
            .expect("routable")
            .wait()
            .expect("scripted shard answers");
        assert_eq!(r.score.to_bits(), 0.25f64.to_bits());
    };
    score(&router, &mut gen);

    withhold.store(true, Ordering::SeqCst);
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.shard_state(0) != ShardState::Suspect {
        assert!(Instant::now() < deadline, "missed probes must demote Live -> Suspect");
        std::thread::sleep(Duration::from_millis(5));
    }
    // Suspect is a soft state: with no Live candidate for the model the
    // router still routes here rather than failing the submission.
    score(&router, &mut gen);

    withhold.store(false, Ordering::SeqCst);
    while router.shard_state(0) != ShardState::Live {
        assert!(Instant::now() < deadline, "a fresh heartbeat must re-promote Suspect");
        std::thread::sleep(Duration::from_millis(5));
    }
    score(&router, &mut gen);
    assert!(router.metrics().shard_suspects() >= 1, "the demotion is counted");
    assert_eq!(router.metrics().shard_deaths(), 0, "a flap must never poison the slot");
    router.shutdown();
    fake.join().unwrap();
}

#[test]
fn leave_announcement_drains_a_shard_without_poisoning_in_flight_work() {
    // Graceful departure: `announce_leave` pushes a Leave frame to every
    // connected router, which must stop routing new work to the shard
    // and let in-flight requests finish — the opposite of the kill path,
    // where in-flight tickets poison Err(Closed).
    let seed = 240;
    let (srv_a, addr_a) = spawn_shard(seed);
    let (srv_b, addr_b) = spawn_shard(seed);
    let cfg = RouterConfig {
        heartbeat_ms: 20,
        suspect_after: 3,
        dead_after: 100_000,
        reconnect_max_backoff_ms: 5000,
    };
    let router = ShardRouter::connect_with(&[addr_a, addr_b], cfg).expect("connect both");
    let topo = &Topology::paper_models()[0];
    let reference = LstmAutoencoder::random(topo.clone(), seed);
    let mut gen = TelemetryGen::new(topo.features, 900);
    let mut pending = Vec::new();
    for _ in 0..16 {
        let w = gen.benign_window(4);
        let want = reference.score_quant(&w.data).to_bits();
        pending.push((router.submit_async(&topo.name, w).expect("submitted"), want));
    }
    srv_a.announce_leave();
    // The Leave must drive slot 0 out of Live (Draining, then Dead once
    // its in-flight count reaches zero) — observed via the health tick.
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.shard_state(0) == ShardState::Live {
        assert!(Instant::now() < deadline, "the health loop must observe the Leave");
        std::thread::sleep(Duration::from_millis(5));
    }
    for (ticket, want) in pending {
        let r = ticket.wait().expect("drain completes in-flight work, never poisons it");
        assert_eq!(r.score.to_bits(), want);
    }
    // New work keeps flowing through the rest of the fleet.
    for _ in 0..8 {
        let w = gen.benign_window(4);
        let want = reference.score_quant(&w.data).to_bits();
        let r = router.submit_async(&topo.name, w).expect("fleet accepts").wait().expect("scored");
        assert_eq!(r.score.to_bits(), want);
    }
    router.shutdown();
    srv_a.shutdown();
    srv_b.shutdown();
}

#[test]
fn replay_fleet_over_loopback_conserves_accounting() {
    // The in-process version of the CI loopback soak: drive a short
    // mixed Poisson trace across all four topologies through a real
    // socket and enforce the same conservation law `fleet connect` gates
    // on — offered == completed + shed + rejected_closed, with zero loss
    // on a healthy fleet.
    let (server, addr) = spawn_shard(77);
    let router = ShardRouter::connect(&[addr]).expect("connect");
    let topos = Topology::paper_models();
    let models: Vec<String> = topos.iter().map(|m| m.name.clone()).collect();
    let merged = trace::merged_poisson(&topos, 47, 3000.0, 400, 6, 0.1);
    let offered = merged.len() as u64;
    let stats = trace::replay_fleet(&router, &models, merged, true);
    assert_eq!(stats.offered, offered);
    assert!(stats.conserves(), "conservation must hold over the wire: {stats:?}");
    assert_eq!(stats.rejected_closed, 0, "healthy fleet loses nothing");
    assert!(stats.completed > 0);
    assert_eq!(stats.completed + stats.shed, offered);
    router.shutdown();
    server.shutdown();
}
