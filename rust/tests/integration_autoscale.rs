//! Integration: the metrics-driven per-lane autoscaler under a shifting
//! (rotating-hot-model) Poisson trace.
//!
//! The headline claim (ISSUE 3 acceptance): at an **equal total thread
//! budget**, the autoscaled fabric sheds strictly fewer requests than a
//! static allocation when the hot model rotates — the static fleet pins
//! threads to lanes that go cold, the autoscaler follows the heat — and
//! every scored response stays bit-identical to
//! `ExecMode::Sequential` arithmetic no matter how many workers or
//! replicas served it.
//!
//! Determinism: lane capacity is made a pure function of worker count by
//! a scoring backend with a fixed per-batch floor (1 ms), so the
//! overload/deficit arithmetic below holds on any host. Scores come from
//! `LstmAutoencoder::score_quant` — literally the sequential scorer.

use std::sync::Arc;
use std::time::{Duration, Instant};

use lstm_ae_accel::engine::ExecMode;
use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::server::{
    AutoscalePolicy, ModelRegistry, ServerConfig, SubmitError, ThrottledBackend,
};
use lstm_ae_accel::workload::trace::rotating_hot_poisson;

/// Shifting trace shared by both runs: all traffic goes to the hot lane,
/// which alternates between the two models every `rotate` requests.
fn shifting_trace(
    topos: &[Topology],
    n: usize,
    rotate: usize,
    rate: f64,
) -> Vec<(usize, lstm_ae_accel::workload::trace::TimedRequest)> {
    rotating_hot_poisson(topos, 42, rate, n, 4, 0.0, 1.0, rotate)
}

/// Build the two-lane registry. `autoscale` carries the per-lane policy
/// (None = static allocation). Seeds are fixed so the reference models
/// below rebuild identical weights.
fn build_registry(topos: &[Topology], autoscale: Option<AutoscalePolicy>) -> ModelRegistry {
    let mut registry = ModelRegistry::new();
    for (i, topo) in topos.iter().enumerate() {
        let backend = Arc::new(ThrottledBackend::scoring(
            LstmAutoencoder::random(topo.clone(), 900 + i as u64),
            Duration::from_millis(1),
        ));
        let mut cfg = ServerConfig::builder()
            .max_batch(1)
            .max_wait(Duration::from_micros(50))
            .workers(2)
            .queue_capacity(16)
            .threshold(1.0);
        if let Some(p) = autoscale.clone() {
            cfg = cfg.autoscale(p);
        }
        registry.register(&topo.name, backend, cfg.build());
    }
    registry
}

/// Replay the trace open-loop; returns (shed, completed bit-checked).
fn replay(
    registry: &ModelRegistry,
    topos: &[Topology],
    trace: &[(usize, lstm_ae_accel::workload::trace::TimedRequest)],
    want_bits: &[u64],
) -> (u64, usize) {
    let start = Instant::now();
    let mut inflight = Vec::with_capacity(trace.len());
    let mut shed = 0u64;
    for (i, (mi, req)) in trace.iter().enumerate() {
        let target = Duration::from_secs_f64(req.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        match registry.submit(&topos[*mi].name, req.window.clone()) {
            Ok(rx) => inflight.push((rx, want_bits[i])),
            Err(SubmitError::Overloaded) => shed += 1,
            Err(e) => panic!("submit: {e}"),
        }
    }
    let mut checked = 0usize;
    for (rx, want) in inflight {
        let r = rx.recv().expect("accepted work completes");
        assert_eq!(
            r.score.to_bits(),
            want,
            "autoscaled/static responses must be bit-identical to sequential scoring"
        );
        checked += 1;
    }
    (shed, checked)
}

#[test]
fn autoscaled_fleet_sheds_less_than_static_at_equal_thread_budget() {
    let topos =
        vec![Topology::from_name("F32-D2").unwrap(), Topology::from_name("F64-D2").unwrap()];
    // 3 phases × 1440 requests at 2400 rps ≈ 0.6 s per phase. Per-worker
    // capacity is 1000 singleton batches/s (1 ms floor), so the hot lane
    // needs 2.4 workers: a static 2 sheds ~400 rps all phase long, while
    // the autoscaler can reach 3 (budget permitting) and stop shedding.
    let n = 4320;
    let rotate = 1440;
    let trace = shifting_trace(&topos, n, rotate, 2400.0);

    // Reference scores: pure sequential arithmetic on same-seed models.
    let refs: Vec<LstmAutoencoder> = topos
        .iter()
        .enumerate()
        .map(|(i, t)| LstmAutoencoder::random(t.clone(), 900 + i as u64))
        .collect();
    let want_bits: Vec<u64> =
        trace.iter().map(|(mi, req)| refs[*mi].score_quant(&req.window.data).to_bits()).collect();

    // Static allocation: 2 + 2 workers, pinned. Total budget = 4.
    let static_registry = build_registry(&topos, None);
    let (static_shed, static_done) = replay(&static_registry, &topos, &trace, &want_bits);
    static_registry.shutdown();

    // Autoscaled: same starting allocation, same total budget (4),
    // min 1 / max 3 per lane — threads can only be *redistributed*.
    let policy = AutoscalePolicy {
        min_workers: 1,
        max_workers: 3,
        up_queue_frac: 0.3,
        up_ticks: 1,
        down_idle_frac: 0.5,
        down_ticks: 2,
        ..Default::default()
    };
    let auto_registry = build_registry(&topos, Some(policy));
    assert_eq!(auto_registry.start_autoscaler(Duration::from_millis(10), Some(4)), 2);
    let (auto_shed, auto_done) = replay(&auto_registry, &topos, &trace, &want_bits);

    // The autoscaler really moved threads around…
    let (mut total_ups, mut total_downs) = (0u64, 0u64);
    let mut total_workers = 0usize;
    for topo in &topos {
        let lane = auto_registry.lane(&topo.name).unwrap();
        let (ups, downs) = lane.scale_counts();
        total_ups += ups;
        total_downs += downs;
        total_workers += lane.workers();
    }
    assert!(total_ups >= 2, "both lanes were hot at some point: ups = {total_ups}");
    assert!(total_downs >= 1, "cold lanes must shrink: downs = {total_downs}");
    assert!(total_workers <= 4, "worker budget violated: {total_workers}");
    auto_registry.shutdown();

    // …and that is what wins: strictly fewer sheds at equal budget.
    assert!(static_shed > 0, "static allocation must shed under the rotating hot lane");
    assert!(
        auto_shed < static_shed,
        "autoscaled fleet must shed strictly less: autoscaled {auto_shed} vs static {static_shed}"
    );
    // Everything accepted was scored (and bit-checked above).
    assert_eq!(static_done as u64 + static_shed, n as u64);
    assert_eq!(auto_done as u64 + auto_shed, n as u64);
}

#[test]
fn paper_fleet_stays_bit_identical_while_autoscaling_replicas() {
    // The full four-topology fleet with per-lane policies: worker pools
    // and deep-lane pipeline-replica pools resize mid-traffic, and every
    // response still matches the same-seed sequential reference bit for
    // bit — scaling changes capacity, never results.
    let seed = 31u64;
    let policy = AutoscalePolicy {
        min_workers: 1,
        max_workers: 4,
        min_replicas: 1,
        max_replicas: 3,
        up_queue_frac: 0.2,
        up_ticks: 1,
        down_idle_frac: 0.5,
        down_ticks: 2,
        ..Default::default()
    };
    let registry = ModelRegistry::paper_fleet_with(seed, ExecMode::Auto, 2, Some(policy));
    assert!(registry.start_autoscaler(Duration::from_millis(10), None) == 4);

    let topos = Topology::paper_models();
    let refs: Vec<LstmAutoencoder> = topos
        .iter()
        .enumerate()
        .map(|(i, t)| LstmAutoencoder::random(t.clone(), seed + i as u64))
        .collect();
    let trace = rotating_hot_poisson(&topos, 77, 2000.0, 360, 4, 0.1, 0.9, 90);
    let start = Instant::now();
    let mut inflight = Vec::new();
    for (mi, req) in trace {
        let target = Duration::from_secs_f64(req.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        let want = refs[mi].score_quant(&req.window.data).to_bits();
        match registry.submit(&topos[mi].name, req.window) {
            Ok(rx) => inflight.push((rx, want)),
            Err(SubmitError::Overloaded) => {} // shedding is legal here
            Err(e) => panic!("submit: {e}"),
        }
    }
    assert!(!inflight.is_empty());
    for (rx, want) in inflight {
        let r = rx.recv().expect("accepted work completes");
        assert_eq!(r.score.to_bits(), want, "replica churn must never change scores");
    }
    // The deep lanes expose their (possibly resized) replica pools.
    let deep = registry.lane("F64-D6").unwrap();
    let replicas = deep.pipeline_replicas().expect("deep Auto lane has a pool");
    assert!((1..=3).contains(&replicas), "replicas within policy bounds: {replicas}");
    registry.shutdown();
}
