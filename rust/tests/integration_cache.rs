//! Integration: the per-lane score cache with single-flight coalescing —
//! hit, miss, and coalesced paths are bit-identical to
//! `ExecMode::Sequential` across all four paper topologies, admission
//! accounting extends conservatively to the new counters, followers of a
//! cancelled or panicked leader resolve `Err` instead of hanging, and a
//! Zipf-skewed replay occupies strictly fewer batch slots than the same
//! trace uncached at equal offered load.

use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use lstm_ae_accel::engine::{ExecMode, PipelineOptions};
use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::server::{
    Backend, CacheConfig, ModelRegistry, QuantBackend, ServerConfig, SubmitError,
};
use lstm_ae_accel::workload::trace::{replay_async, zipf_poisson};
use lstm_ae_accel::workload::{TelemetryGen, Window};

fn wait_for(mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_secs(5);
    while !cond() {
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    true
}

/// Real quantized scoring behind a gate: the worker blocks inside
/// `score_batch` until the test drops the gate sender, making in-flight
/// (coalescible) windows deterministic while scores stay bit-checkable
/// against `score_quant`.
struct GatedQuant {
    inner: QuantBackend,
    gate: Mutex<Receiver<()>>,
}

impl Backend for GatedQuant {
    fn name(&self) -> String {
        "gated-quant".into()
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        let _ = self.gate.lock().unwrap().recv();
        self.inner.score_batch(windows)
    }
}

/// Gate-only backend for accounting tests where the score value is
/// irrelevant: every window scores 0.0 once the gate drops.
struct GatedZero {
    gate: Mutex<Receiver<()>>,
}

impl Backend for GatedZero {
    fn name(&self) -> String {
        "gated-zero".into()
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        let _ = self.gate.lock().unwrap().recv();
        vec![0.0; windows.len()]
    }
}

/// Panics on the marker window — kills its worker mid-batch (same idiom
/// as the orphaned-ticket test in integration_front).
struct PanickingBackend;

impl Backend for PanickingBackend {
    fn name(&self) -> String {
        "panicking".into()
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        if windows.iter().any(|w| w.data[0][0] == 666.0) {
            panic!("injected backend failure (expected by integration_cache)");
        }
        vec![0.0; windows.len()]
    }
}

#[test]
fn cached_paths_are_bit_identical_to_sequential_on_all_paper_topologies() {
    // Four lanes with the default cache on, plus per-model reference
    // scorers rebuilt from the same seeds: the miss path (scored by the
    // lane), the async hit path (served from cache), and the blocking
    // hit path must all return the exact `score_quant` bits.
    let mut registry = ModelRegistry::new();
    let mut refs = Vec::new();
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let seed = 700 + i as u64;
        let backend = Arc::new(QuantBackend::with_options(
            LstmAutoencoder::random(topo.clone(), seed),
            ExecMode::Auto,
            2,
        ));
        let cfg = ServerConfig {
            cache: Some(CacheConfig::default()),
            ..ModelRegistry::paper_lane_config(&topo, 2)
        };
        registry.register(&topo.name, backend, cfg);
        let reference = LstmAutoencoder::random(topo.clone(), seed);
        let gen = TelemetryGen::new(topo.features, 760 + i as u64);
        refs.push((topo.name, reference, gen));
    }
    for (name, reference, gen) in refs.iter_mut() {
        for t in [4usize, 8, 6, 1] {
            let w = gen.benign_window(t);
            let want = reference.score_quant(&w.data).to_bits();
            // Miss: the lane backend scores the window, and the worker
            // populates the cache before replying — so by the time this
            // wait returns, the next submit of `w` is a guaranteed hit.
            let miss = registry
                .submit_async(name, w.clone())
                .expect("admitted")
                .wait()
                .expect("miss completes");
            assert_eq!(miss.score.to_bits(), want, "{name}: miss path must match sequential");
            let hit = registry
                .submit_async(name, w.clone())
                .expect("admitted")
                .wait()
                .expect("cached hit completes");
            assert_eq!(hit.score.to_bits(), want, "{name}: async hit must match sequential");
            let blocking = registry.submit(name, w).expect("admitted").recv().expect("reply");
            assert_eq!(
                blocking.score.to_bits(),
                want,
                "{name}: blocking hit must match sequential"
            );
        }
        let m = registry.lane(name).unwrap().metrics();
        assert_eq!(m.submitted(), 4, "{name}: only the four misses occupy the lane");
        assert_eq!(m.cache_hits(), 8, "{name}: one async + one blocking hit per window");
        assert_eq!(m.coalesced(), 0, "{name}: nothing was in flight at submit time");
    }
    registry.shutdown();
}

#[test]
fn coalesced_followers_score_bit_identical_across_topologies() {
    // Per topology: a gated plug occupies the single worker, a leader
    // window queues behind it, then three async followers and one
    // blocking follower coalesce onto the leader's flight. Dropping the
    // gate must fan the leader's exact score bits out to all five.
    for (i, topo) in Topology::paper_models().into_iter().enumerate() {
        let seed = 720 + i as u64;
        let (gate_tx, gate_rx) = channel::<()>();
        let backend = Arc::new(GatedQuant {
            inner: QuantBackend::with_options(
                LstmAutoencoder::random(topo.clone(), seed),
                ExecMode::Auto,
                2,
            ),
            gate: Mutex::new(gate_rx),
        });
        let mut registry = ModelRegistry::new();
        let cfg = ServerConfig::builder()
            .max_batch(1)
            .max_wait(Duration::from_micros(1))
            .workers(1)
            .queue_capacity(64)
            .threshold(0.05)
            .cache(CacheConfig::default())
            .build();
        registry.register(&topo.name, backend, cfg);
        let lane = registry.lane(&topo.name).unwrap();
        let reference = LstmAutoencoder::random(topo.clone(), seed);
        let mut gen = TelemetryGen::new(topo.features, 820 + i as u64);
        let plug = gen.benign_window(4);
        let w = gen.benign_window(6);
        let want = reference.score_quant(&w.data).to_bits();

        let plug_ticket = registry.submit_async(&topo.name, plug).expect("plug admitted");
        let leader = registry.submit_async(&topo.name, w.clone()).expect("leader admitted");
        let followers: Vec<_> = (0..3)
            .map(|_| registry.submit_async(&topo.name, w.clone()).expect("follower attaches"))
            .collect();
        let blocking_rx = registry.submit(&topo.name, w.clone()).expect("blocking attaches");
        let m = lane.metrics();
        assert_eq!(m.submitted(), 2, "{}: plug + leader only", topo.name);
        assert_eq!(m.coalesced(), 4, "{}: three async + one blocking", topo.name);
        assert_eq!(m.cache_hits(), 0, "{}", topo.name);
        assert_eq!(lane.coalescing_inflight(), 1, "{}: one keyed flight", topo.name);

        drop(gate_tx);
        assert!(plug_ticket.wait().is_ok());
        let got = leader.wait().expect("leader completes").score.to_bits();
        assert_eq!(got, want, "{}: leader must match sequential", topo.name);
        for f in &followers {
            let r = f.wait().expect("follower completes");
            assert_eq!(r.score.to_bits(), want, "{}: follower bits must match", topo.name);
        }
        let b = blocking_rx.recv().expect("blocking follower gets the fanned-out reply");
        assert_eq!(b.score.to_bits(), want, "{}: blocking follower bits must match", topo.name);
        assert_eq!(m.batched_windows(), 2, "{}: coalescing freed four batch slots", topo.name);
        assert_eq!(lane.coalescing_inflight(), 0, "{}", topo.name);
        registry.shutdown();
    }
}

#[test]
fn barrier_coalescing_takes_one_batch_slot_for_n_concurrent_submits() {
    // N threads released by a barrier all submit the same window while
    // the worker is gated: exactly one leads (occupying the only batch
    // slot ever used), the rest coalesce, and everyone gets identical
    // score bits.
    const N: usize = 8;
    let topo = Topology::from_name("F32-D2").unwrap();
    let seed = 730u64;
    let (gate_tx, gate_rx) = channel::<()>();
    let backend = Arc::new(GatedQuant {
        inner: QuantBackend::with_options(
            LstmAutoencoder::random(topo.clone(), seed),
            ExecMode::Auto,
            2,
        ),
        gate: Mutex::new(gate_rx),
    });
    let mut registry = ModelRegistry::new();
    let cfg = ServerConfig::builder()
        .max_batch(1)
        .max_wait(Duration::from_micros(1))
        .workers(1)
        .queue_capacity(64)
        .threshold(0.05)
        .cache(CacheConfig::default())
        .build();
    registry.register(&topo.name, backend, cfg);
    let lane = registry.lane(&topo.name).unwrap();
    let reference = LstmAutoencoder::random(topo.clone(), seed);
    let mut gen = TelemetryGen::new(topo.features, 831);
    let w = gen.benign_window(8);
    let want = reference.score_quant(&w.data).to_bits();

    let barrier = Barrier::new(N);
    let tickets = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for _ in 0..N {
            let wc = w.clone();
            let barrier = &barrier;
            let tickets = &tickets;
            let registry = &registry;
            s.spawn(move || {
                barrier.wait();
                let t = registry.submit_async("F32-D2", wc).expect("admitted or coalesced");
                tickets.lock().unwrap().push(t);
            });
        }
    });
    let tickets = tickets.into_inner().unwrap();
    assert_eq!(tickets.len(), N);
    let m = lane.metrics();
    assert_eq!(m.submitted(), 1, "exactly one leader occupies a batch slot");
    assert_eq!(m.coalesced(), (N - 1) as u64, "everyone else attaches");
    assert_eq!(lane.coalescing_inflight(), 1);

    drop(gate_tx);
    for t in &tickets {
        let r = t.wait().expect("leader and followers all complete");
        assert_eq!(r.score.to_bits(), want, "all N redemptions carry identical bits");
    }
    assert!(wait_for(|| m.completed() == 1));
    assert_eq!(m.batched_windows(), 1, "one slot served all {N} submits");
    assert_eq!(lane.coalescing_inflight(), 0);
    assert!(wait_for(|| lane.async_inflight() == 0));
    registry.shutdown();
}

#[test]
fn admission_accounting_conserves_with_cache_counters() {
    // Every call terminates in exactly one of: submitted (a batch-slot
    // occupancy), shed, rejected_closed, cache_hits, coalesced — and the
    // accepted-work law `submitted == completed + cancelled` is untouched
    // by the cache.
    let (gate_tx, gate_rx) = channel::<()>();
    let backend = Arc::new(GatedZero { gate: Mutex::new(gate_rx) });
    let mut registry = ModelRegistry::new();
    let cfg = ServerConfig::builder()
        .max_batch(1)
        .max_wait(Duration::from_micros(1))
        .workers(1)
        .queue_capacity(2)
        .threshold(1.0)
        .cache(CacheConfig::default())
        .build();
    registry.register("gated", backend, cfg);
    let lane = registry.lane("gated").unwrap();
    let hot = Window { data: vec![vec![7.0f32]], anomaly: None };
    let mut calls = 0u64;
    let mut tickets = Vec::new();
    // Five submits of one window: one leads, four coalesce — none of the
    // four occupies a queue slot, so they cannot shed.
    for _ in 0..5 {
        tickets.push(registry.submit_async("gated", hot.clone()).expect("lead or coalesce"));
        calls += 1;
    }
    assert_eq!(lane.metrics().submitted(), 1);
    assert_eq!(lane.metrics().coalesced(), 4);
    // Distinct windows behind the gated worker until the bounded queue
    // sheds: shed leaders must release their flight (nothing leaks).
    let mut shed = 0u64;
    for i in 0..6 {
        let w = Window { data: vec![vec![100.0 + i as f32]], anomaly: None };
        match registry.submit_async("gated", w) {
            Ok(t) => tickets.push(t),
            Err(SubmitError::Overloaded) => shed += 1,
            Err(e) => panic!("unexpected {e}"),
        }
        calls += 1;
    }
    assert!(shed > 0, "six distinct windows must overflow a 2-deep queue");
    // Accepted leaders (hot + each admitted distinct window) hold live
    // flight entries behind the gate; shed leaders must have released
    // theirs on the spot.
    assert_eq!(lane.coalescing_inflight(), tickets.len() - 4, "shed leaders release flights");

    drop(gate_tx);
    for t in &tickets {
        assert!(t.wait().is_ok(), "accepted and coalesced work all completes");
    }
    // The hot window is now resident: one more call is a pure hit.
    let r = registry
        .submit_async("gated", hot.clone())
        .expect("cached")
        .wait()
        .expect("hit completes");
    calls += 1;
    assert_eq!(r.score, 0.0);
    assert_eq!(lane.metrics().cache_hits(), 1);

    registry.shutdown();
    // Closed-lane rejections flow through the cached admission path's
    // gate pre-check: a closed lane never serves from cache.
    for _ in 0..2 {
        assert!(matches!(
            registry.submit_async("gated", hot.clone()),
            Err(SubmitError::Closed)
        ));
        calls += 1;
    }
    assert!(matches!(registry.submit("gated", hot.clone()), Err(SubmitError::Closed)));
    calls += 1;

    let m = lane.metrics();
    assert_eq!(m.shed(), shed);
    assert_eq!(m.rejected_closed(), 3);
    assert_eq!(
        calls,
        m.submitted() + m.shed() + m.rejected_closed() + m.cache_hits() + m.coalesced(),
        "call-level conservation with the cache counters"
    );
    assert_eq!(m.cancelled(), 0);
    assert_eq!(m.submitted(), m.completed() + m.cancelled(), "accepted-work law unchanged");
}

#[test]
fn followers_on_a_panicked_leader_resolve_closed_not_hang() {
    // The leader's worker dies without replying; its flight entry stays
    // until shutdown's router drain poisons the leader with `Closed`,
    // whose observer must fan the error out: async followers resolve
    // `Err(Closed)`, the blocking follower's channel disconnects, and no
    // router slot or flight entry leaks.
    let mut registry = ModelRegistry::new();
    let cfg = ServerConfig::builder()
        .max_batch(1)
        .max_wait(Duration::from_micros(1))
        .workers(1)
        .queue_capacity(64)
        .threshold(1.0)
        .cache(CacheConfig::default())
        .build();
    registry.register("panicky", Arc::new(PanickingBackend), cfg);
    let lane = registry.lane("panicky").unwrap();
    let poison = Window { data: vec![vec![666.0f32]], anomaly: None };
    let leader = registry.submit_async("panicky", poison.clone()).expect("admitted");
    let follower = registry.submit_async("panicky", poison.clone()).expect("attaches");
    let blocking_rx = registry.submit("panicky", poison.clone()).expect("attaches");
    assert_eq!(lane.metrics().coalesced(), 2);
    assert!(wait_for(|| lane.metrics().worker_panics() == 1), "panic must be counted");
    // Nobody hangs on a bounded wait, nobody resolves early.
    assert!(leader.wait_timeout(Duration::from_millis(100)).is_none());
    assert!(follower.wait_timeout(Duration::from_millis(100)).is_none());
    assert_eq!(lane.coalescing_inflight(), 1);
    registry.shutdown();
    assert_eq!(leader.wait().unwrap_err(), SubmitError::Closed);
    assert_eq!(follower.wait().unwrap_err(), SubmitError::Closed);
    assert!(blocking_rx.recv().is_err(), "blocking follower's sender is dropped on Err");
    assert_eq!(lane.async_inflight(), 0, "no leaked router slots");
    assert_eq!(lane.coalescing_inflight(), 0, "no leaked flight entries");
}

#[test]
fn followers_on_a_cancelled_leader_resolve_cancelled() {
    let (gate_tx, gate_rx) = channel::<()>();
    let backend = Arc::new(GatedZero { gate: Mutex::new(gate_rx) });
    let mut registry = ModelRegistry::new();
    let cfg = ServerConfig::builder()
        .max_batch(1)
        .max_wait(Duration::from_micros(1))
        .workers(1)
        .queue_capacity(64)
        .threshold(1.0)
        .cache(CacheConfig::default())
        .build();
    registry.register("gated", backend, cfg);
    let lane = registry.lane("gated").unwrap();
    let plug = Window { data: vec![vec![1.0f32]], anomaly: None };
    let hot = Window { data: vec![vec![2.0f32]], anomaly: None };
    let plug_ticket = registry.submit_async("gated", plug).expect("admitted");
    let leader = registry.submit_async("gated", hot.clone()).expect("admitted");
    let follower = registry.submit_async("gated", hot.clone()).expect("attaches");
    assert_eq!(lane.metrics().coalesced(), 1);
    // The leader is still queued behind the gated plug, so the cancel
    // wins — and its observer must poison the follower immediately.
    assert!(leader.cancel(), "leader is still queued");
    assert_eq!(leader.wait().unwrap_err(), SubmitError::Cancelled);
    assert_eq!(follower.wait().unwrap_err(), SubmitError::Cancelled);
    assert_eq!(lane.coalescing_inflight(), 0, "cancel released the flight");

    drop(gate_tx);
    assert!(plug_ticket.wait().is_ok());
    let m = lane.metrics();
    assert!(wait_for(|| m.cancelled() == 1), "batcher counts the skipped request");
    assert!(wait_for(|| m.completed() == 1));
    assert_eq!(m.submitted(), 2);
    assert_eq!(m.submitted(), m.completed() + m.cancelled());
    // The cancelled window was never scored, so nothing of it was
    // cached: a resubmit is a fresh miss that completes normally.
    assert!(registry.submit_async("gated", hot.clone()).expect("fresh leader").wait().is_ok());
    assert_eq!(m.cache_hits(), 0);
    assert_eq!(m.submitted(), 3);
    registry.shutdown();
}

#[test]
fn zipf_replay_hits_and_uses_strictly_fewer_batch_slots_than_uncached() {
    // The acceptance bar: the same Zipf-skewed trace through an uncached
    // and a cached paper fleet at equal offered load — the cached fleet
    // must show a nonzero hit+coalesce rate and occupy strictly fewer
    // batch slots, with both fleets conserving and flagging identically.
    let topos = Topology::paper_models();
    let models: Vec<String> = topos.iter().map(|m| m.name.clone()).collect();
    let trace = zipf_poisson(&topos, 41, 4000.0, 600, 4, 32, 1.1);
    let n = trace.len() as u64;

    let uncached = ModelRegistry::paper_fleet(41, ExecMode::Auto, 2);
    let u_stats = replay_async(&uncached, &models, trace.clone());
    let cached = ModelRegistry::paper_fleet_opts(
        41,
        ExecMode::Auto,
        2,
        None,
        PipelineOptions::default(),
        Some(CacheConfig::default()),
    );
    let c_stats = replay_async(&cached, &models, trace);

    // Paper-fleet queues (1024) dwarf the 600-request trace, so nothing
    // sheds and the slot counts below are exact, not racy.
    for stats in [&u_stats, &c_stats] {
        assert_eq!(stats.accepted + stats.shed + stats.rejected, n);
        assert_eq!(stats.shed, 0);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.completed, n);
    }
    assert_eq!(
        u_stats.flagged, c_stats.flagged,
        "bit-identical scoring implies identical anomaly flags"
    );

    let slots = |reg: &ModelRegistry| -> u64 {
        models.iter().map(|m| reg.lane(m).unwrap().metrics().batched_windows()).sum()
    };
    let hits: u64 =
        models.iter().map(|m| cached.lane(m).unwrap().metrics().cache_hits()).sum();
    let coalesced: u64 =
        models.iter().map(|m| cached.lane(m).unwrap().metrics().coalesced()).sum();
    assert_eq!(slots(&uncached), n, "uncached: every request occupies a batch slot");
    assert!(hits + coalesced > 0, "a 32-window/model Zipf pool must repeat");
    assert!(
        slots(&cached) < slots(&uncached),
        "cached fleet must occupy strictly fewer batch slots ({} vs {})",
        slots(&cached),
        slots(&uncached)
    );
    assert_eq!(
        slots(&cached) + hits + coalesced,
        n,
        "every request is exactly one of scored / hit / coalesced"
    );
    for reg in [&uncached, &cached] {
        for m in &models {
            let lm = reg.lane(m).unwrap().metrics();
            assert_eq!(lm.submitted(), lm.completed() + lm.cancelled(), "{m}");
        }
    }
    uncached.shutdown();
    cached.shutdown();
}
