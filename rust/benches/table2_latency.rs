//! Bench E2: regenerate the paper's Table 2 (inference latency, ms) —
//! FPGA dataflow simulation vs paper-calibrated CPU/GPU models, plus a
//! *measured* XLA-CPU column when artifacts are present, and the paper's
//! own numbers inline.
//!
//! ```bash
//! cargo bench --bench table2_latency            # model columns only
//! BENCH_REPS=1000 cargo bench --bench table2_latency   # paper-grade reps
//! ```

use lstm_ae_accel::baselines::cpu as cpu_baseline;
use lstm_ae_accel::report;
use lstm_ae_accel::runtime::Runtime;

fn main() {
    let reps: usize = std::env::var("BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(200);

    let rt = Runtime::open(&Runtime::default_dir()).ok();
    if rt.is_none() {
        println!("(no artifacts — measured XLA-CPU column omitted; run `make artifacts`)\n");
    }
    let measured = rt.map(|rt| {
        move |model: &str, t: usize| -> Option<f64> {
            cpu_baseline::measure(&rt, model, t, 10, reps).ok().map(|m| m.latency_ms.mean)
        }
    });
    match measured {
        Some(f) => print!("{}", report::tables::table2(Some(&f))),
        None => print!("{}", report::tables::table2(None)),
    }

    println!("\nColumns: FPGA(kernel) = Eq-1-exact dataflow simulation @300 MHz;");
    println!("FPGA(+ovh) adds the {:.0} µs PS invocation overhead (DESIGN.md §6);",
             report::tables::PS_INVOCATION_OVERHEAD_MS * 1e3);
    println!("CPU/GPU(model) are least-squares fits of the paper's own columns;");
    println!("CPU(measured XLA) is this machine running the AOT artifact ({reps} reps).");

    // Shape checks — the pass/fail criteria for this experiment.
    println!("\n## Shape checks");
    let mut failed = 0;
    for (name, ok, detail) in report::tables::shape_checks() {
        println!("[{}] {name} {detail}", if ok { "PASS" } else { "FAIL" });
        failed += (!ok) as u32;
    }
    std::process::exit(if failed == 0 { 0 } else { 1 });
}
