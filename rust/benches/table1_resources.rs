//! Bench E1: regenerate the paper's Table 1 (FPGA resource utilization
//! and RH_m), model vs paper, plus residual statistics and the cost of
//! the resource-estimation hot path.
//!
//! ```bash
//! cargo bench --bench table1_resources
//! ```

use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::resources::{estimate, min_fitting_rh_m};
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::model::Topology;
use lstm_ae_accel::report;
use lstm_ae_accel::report::paper_data::TABLE1;
use lstm_ae_accel::util::timer::{bench_auto, black_box};

fn main() {
    println!("{}", report::table1());

    // Residuals vs the paper (DSP/LUT are calibrated; BRAM is structural
    // and expected to deviate on F64 — see resources.rs docs).
    let dev = FpgaDevice::ZCU104;
    println!("## Residuals (model − paper, percentage points)");
    for (name, rh_m, lut_p, ff_p, bram_p, dsp_p) in TABLE1 {
        let topo = Topology::from_name(name).unwrap();
        let pct = estimate(&BalancedConfig::balance(&topo, rh_m)).pct(&dev);
        println!(
            "{name:>16}: LUT {:+6.2}  FF {:+6.2}  BRAM {:+6.2}  DSP {:+6.2}",
            pct.lut - lut_p,
            pct.ff - ff_p,
            pct.bram - bram_p,
            pct.dsp - dsp_p
        );
    }

    // §4.1 procedure timing: smallest fitting RH_m per model.
    println!("\n## RH_m fitting procedure (min fitting RH_m on ZCU104)");
    for topo in Topology::paper_models() {
        let (rh_m, usage) = min_fitting_rh_m(&topo, &dev, 64).expect("fits");
        let pct = usage.pct(&dev);
        println!(
            "{:>16}: RH_m {} (paper {}), mean util {:.1}%",
            topo.name,
            rh_m,
            BalancedConfig::paper_rh_m(&topo.name).unwrap(),
            pct.mean()
        );
    }

    // Hot-path cost: the estimator runs inside design-space sweeps.
    println!("\n## Estimator micro-costs");
    let topo = Topology::from_name("F64-D6").unwrap();
    let r = bench_auto("estimate(F64-D6)", 30, || {
        let cfg = BalancedConfig::balance(&topo, 8);
        black_box(estimate(&cfg));
    });
    println!("{}", r.report());
    let r = bench_auto("min_fitting_rh_m(F64-D6, ZCU104)", 20, || {
        black_box(min_fitting_rh_m(&topo, &FpgaDevice::ZCU104, 64));
    });
    println!("{}", r.report());
}
