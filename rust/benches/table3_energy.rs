//! Bench E3: regenerate the paper's Table 3 (energy per timestep, mJ)
//! from the latency machinery plus the platform power models.
//!
//! ```bash
//! cargo bench --bench table3_energy
//! ```

use lstm_ae_accel::accel::energy;
use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::resources::estimate;
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::model::Topology;
use lstm_ae_accel::report;

fn main() {
    print!("{}", report::table3());

    // The paper's headline energy ratios.
    println!("\n## Headline ratios (ours, from the models above)");
    let dev = FpgaDevice::ZCU104;
    let cpu = lstm_ae_accel::baselines::CalibratedModel::fit(
        lstm_ae_accel::baselines::Platform::XeonGold5218R,
    );
    let gpu =
        lstm_ae_accel::baselines::CalibratedModel::fit(lstm_ae_accel::baselines::Platform::V100);
    let mut max_cpu: (f64, String) = (0.0, String::new());
    let mut max_gpu: (f64, String) = (0.0, String::new());
    let mut min_cpu: (f64, String) = (f64::INFINITY, String::new());
    let mut min_gpu: (f64, String) = (f64::INFINITY, String::new());
    for topo in Topology::paper_models() {
        let cfg = BalancedConfig::paper_config(&topo);
        let p_fpga = energy::fpga_power_w(&estimate(&cfg).pct(&dev), &dev);
        for &t in &report::paper_data::TIMESTEPS {
            let lat = report::tables::fpga_platform_latency_ms(&topo, t);
            let e_f = energy::energy_per_timestep_mj(p_fpga, lat, t);
            let rc = cpu.energy_per_timestep_mj(&topo, t) / e_f;
            let rg = gpu.energy_per_timestep_mj(&topo, t) / e_f;
            let tag = format!("{} T={t}", topo.name);
            if rc > max_cpu.0 {
                max_cpu = (rc, tag.clone());
            }
            if rg > max_gpu.0 {
                max_gpu = (rg, tag.clone());
            }
            if rc < min_cpu.0 {
                min_cpu = (rc, tag.clone());
            }
            if rg < min_gpu.0 {
                min_gpu = (rg, tag);
            }
        }
    }
    println!("energy-per-timestep reduction vs CPU: {:.1}x–{:.1}x  (paper: 151.0x–1722.1x; max at {})",
             min_cpu.0, max_cpu.0, max_cpu.1);
    println!("energy-per-timestep reduction vs GPU: {:.1}x–{:.1}x  (paper: 3.5x–59.3x; max at {})",
             min_gpu.0, max_gpu.0, max_gpu.1);
}
