//! Ablation A1 (§3.3's motivation): balanced dataflow (Eqs 7–8) vs the
//! naive uniform-reuse configuration — latency, utilization, stalls, and
//! the silicon-time product, per paper model.
//!
//! ```bash
//! cargo bench --bench ablation_balancing
//! ```

use lstm_ae_accel::accel::dataflow::DataflowSim;
use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::model::Topology;
use lstm_ae_accel::util::table::Table;

fn main() {
    let t = 64;
    let dev = FpgaDevice::ZCU104;
    let mut table = Table::new(&format!(
        "Ablation A1 — balanced (Eqs 7–8) vs uniform reuse, T = {t}"
    ))
    .header(&[
        "Model",
        "config",
        "mults",
        "cycles",
        "ms",
        "mean util",
        "starved cyc",
        "blocked cyc",
        "cycles×mults",
    ]);
    for topo in Topology::paper_models() {
        let rh_m = BalancedConfig::paper_rh_m(&topo.name).unwrap();
        for (label, cfg) in [
            ("balanced", BalancedConfig::balance(&topo, rh_m)),
            ("uniform", BalancedConfig::uniform(&topo, rh_m)),
        ] {
            let run = DataflowSim::new(&cfg).run_sequence(t);
            let starved: u64 = run.per_module.iter().map(|m| m.starved).sum();
            let blocked: u64 = run.per_module.iter().map(|m| m.blocked).sum();
            table.row(vec![
                topo.name.clone(),
                label.into(),
                cfg.total_multipliers().to_string(),
                run.total_cycles.to_string(),
                format!("{:.4}", run.total_ms(dev.clock_hz)),
                format!("{:.3}", run.mean_utilization()),
                starved.to_string(),
                blocked.to_string(),
                format!("{:.2e}", run.total_cycles as f64 * cfg.total_multipliers() as f64),
            ]);
        }
        table.separator();
    }
    print!("{}", table.render());
    println!("Balanced configs put the multipliers where the bottleneck is: same or");
    println!("fewer multipliers, higher utilization, and a lower cycles×multipliers");
    println!("product than giving every layer identical per-element parallelism.");

    // Sensitivity: utilization as imbalance grows (detuning one layer).
    println!("\n## Sensitivity: detuning the bottleneck layer's RH (F32-D6, T=64)");
    let topo = Topology::from_name("F32-D6").unwrap();
    println!("rh_scale,mean_util,total_cycles");
    for scale in [1u64, 2, 4, 8] {
        let mut cfg = BalancedConfig::balance(&topo, 1);
        let m = cfg.bottleneck;
        // Slow the bottleneck down without rebalancing the others.
        cfg.layers[m].mh = (cfg.layers[m].mh / scale).max(1);
        cfg.layers[m].mx = (cfg.layers[m].mx / scale).max(1);
        let run = DataflowSim::new(&cfg).run_sequence(64);
        println!("{scale},{:.3},{}", run.mean_utilization(), run.total_cycles);
    }
}
