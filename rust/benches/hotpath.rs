//! Microbenchmarks of the hot paths — the §Perf baseline/tracking bench.
//!
//! Covers: the dataflow simulator (events/s), the analytical model, the
//! Q8.24 datapath (cell step, dot product, PWL eval), the temporal-pipeline
//! execution engine vs the sequential scorer on deep models, workload
//! generation, and server throughput through the quant backend.
//!
//! Every result is also written to `BENCH_hotpath.json` next to
//! `Cargo.toml` (name → ns/iter + optional items/s) so the perf
//! trajectory is machine-comparable across PRs; EXPERIMENTS.md §Perf
//! records the interpretation.
//!
//! ```bash
//! cargo bench --bench hotpath
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use lstm_ae_accel::accel::dataflow::DataflowSim;
use lstm_ae_accel::accel::latency::LatencyModel;
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::activations::Pwl;
use lstm_ae_accel::engine::{
    step_session, BatchEngine, ExecMode, PipelineOptions, PipelinePool, SessionState,
    TemporalPipeline,
};
use lstm_ae_accel::fixed::{dot_q, Q8_24};
use lstm_ae_accel::model::lstm::{QuantLstmCell, QuantLstmState, StepScratch};
use lstm_ae_accel::model::{LstmAutoencoder, Topology};
use lstm_ae_accel::net::ShardServer;
use lstm_ae_accel::server::{
    AnomalyServer, AutoscalePolicy, CacheConfig, ModelRegistry, QuantBackend, ServerConfig,
    ShardRouter, ThrottledBackend,
};
use lstm_ae_accel::util::json::Json;
use lstm_ae_accel::util::timer::{bench, bench_auto, black_box, BenchResult};
use lstm_ae_accel::workload::trace::{
    closed_loop_async, closed_loop_blocking, replay_async, rotating_hot_poisson, zipf_poisson,
};
use lstm_ae_accel::workload::TelemetryGen;

/// Accumulates results and flushes them as `BENCH_hotpath.json`.
struct Recorder {
    results: BTreeMap<String, Json>,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder { results: BTreeMap::new() }
    }

    /// Record a timed result; `items_per_iter`, when given, also derives
    /// a throughput (items/s) so cross-PR comparisons survive batch-size
    /// tweaks.
    fn add(&mut self, r: &BenchResult, items_per_iter: Option<f64>) {
        let mut entry = vec![
            ("ns_per_iter".to_string(), Json::num(r.per_iter.mean * 1e9)),
            ("p50_ns".to_string(), Json::num(r.per_iter.p50 * 1e9)),
            ("p95_ns".to_string(), Json::num(r.per_iter.p95 * 1e9)),
            ("iters".to_string(), Json::num(r.iters as f64)),
        ];
        if let Some(items) = items_per_iter {
            entry.push((
                "throughput_per_s".to_string(),
                Json::num(items / r.per_iter.mean),
            ));
        }
        self.results.insert(r.name.clone(), Json::Obj(entry.into_iter().collect()));
    }

    /// Record a raw throughput-only measurement (e.g. the closed-loop
    /// server run, which is not a per-iteration bench).
    fn add_throughput(&mut self, name: &str, items: f64, seconds: f64) {
        let entry: BTreeMap<String, Json> = [
            ("ns_per_iter".to_string(), Json::num(seconds / items * 1e9)),
            ("throughput_per_s".to_string(), Json::num(items / seconds)),
        ]
        .into_iter()
        .collect();
        self.results.insert(name.to_string(), Json::Obj(entry));
    }

    /// Record arbitrary named scalars (e.g. shed counts of the
    /// autoscaler comparison, which is a scenario, not a timing loop).
    fn add_scalars(&mut self, name: &str, pairs: &[(&str, f64)]) {
        let entry: BTreeMap<String, Json> =
            pairs.iter().map(|(k, v)| (k.to_string(), Json::num(*v))).collect();
        self.results.insert(name.to_string(), Json::Obj(entry));
    }

    fn flush(&self) {
        let doc = Json::obj(vec![
            ("schema", Json::str("hotpath/v1")),
            ("bench", Json::str("benches/hotpath.rs")),
            ("results", Json::Obj(self.results.clone())),
        ]);
        let path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hotpath.json");
        match std::fs::write(&path, doc.to_string_pretty()) {
            Ok(()) => println!("\nwrote {}", path.display()),
            Err(e) => eprintln!("\nWARN: could not write {}: {e}", path.display()),
        }
    }
}

fn main() {
    let mut rec = Recorder::new();

    println!("## Simulator & analytical model");
    let topo = Topology::from_name("F64-D6").unwrap();
    let cfg = BalancedConfig::paper_config(&topo);
    let sim = DataflowSim::new(&cfg);
    for t in [64usize, 1024, 16384] {
        let r = bench_auto(&format!("dataflow sim F64-D6 T={t}"), 20, || {
            black_box(sim.run_sequence(black_box(t)).total_cycles);
        });
        let events = (t * 6) as f64; // module-timestep events
        println!(
            "{}   ({:.1} M module-events/s)",
            r.report(),
            events / r.per_iter.mean / 1e6
        );
        rec.add(&r, Some(events));
    }
    let lm = LatencyModel::of(&cfg);
    let r = bench_auto("analytical Eq1 eval", 20, || {
        black_box(lm.acc_lat(black_box(64)));
    });
    println!("{}", r.report());
    rec.add(&r, None);
    let r = bench_auto("balance(F64-D6, 8)", 20, || {
        black_box(BalancedConfig::balance(&topo, 8));
    });
    println!("{}", r.report());
    rec.add(&r, None);

    println!("\n## Q8.24 datapath");
    let pwl = Pwl::tanh();
    let xs: Vec<Q8_24> = (0..1024).map(|i| Q8_24::from_f64(i as f64 * 0.01 - 5.0)).collect();
    let r = bench("pwl tanh eval x1024", 3, 20, 200, || {
        let mut acc = 0i64;
        for &x in &xs {
            acc = acc.wrapping_add(pwl.eval_q(x).0 as i64);
        }
        black_box(acc);
    });
    println!("{}   ({:.1} M evals/s)", r.report(), 1024.0 / r.per_iter.mean / 1e6);
    rec.add(&r, Some(1024.0));

    let a: Vec<Q8_24> = (0..256).map(|i| Q8_24::from_f64((i as f64 * 0.013).sin())).collect();
    let b: Vec<Q8_24> = (0..256).map(|i| Q8_24::from_f64((i as f64 * 0.007).cos())).collect();
    let r = bench("dot_q n=256", 3, 20, 2000, || {
        black_box(dot_q(black_box(&a), black_box(&b)));
    });
    println!("{}   ({:.1} M MAC/s)", r.report(), 256.0 / r.per_iter.mean / 1e6);
    rec.add(&r, Some(256.0));

    let w = lstm_ae_accel::model::weights::LayerWeights::random(
        lstm_ae_accel::model::topology::LayerDims { lx: 64, lh: 64 },
        &mut lstm_ae_accel::util::rng::Xoshiro256::seeded(1),
    );
    let cell = QuantLstmCell::new(&w);
    let state = QuantLstmState::zeros(64);
    let x: Vec<Q8_24> = (0..64).map(|i| Q8_24::from_f64(i as f64 * 0.01)).collect();
    let macs = 4.0 * 64.0 * (64.0 + 64.0);
    let r = bench_auto("quant LSTM cell step 64x64 (alloc)", 20, || {
        black_box(cell.step(black_box(&state), black_box(&x)));
    });
    println!("{}   ({:.1} M MAC/s)", r.report(), macs / r.per_iter.mean / 1e6);
    rec.add(&r, Some(macs));
    // The zero-alloc scratch variant the engine paths run on.
    let mut st = QuantLstmState::zeros(64);
    let mut scratch = StepScratch::new();
    let r = bench_auto("quant LSTM cell step_into 64x64", 20, || {
        cell.step_into(black_box(&mut st), black_box(&x), &mut scratch);
        black_box(st.h[0]);
    });
    println!("{}   ({:.1} M MAC/s)", r.report(), macs / r.per_iter.mean / 1e6);
    rec.add(&r, Some(macs));

    println!("\n## Kernel layout: row-major vs gate-interleaved (bit-identical)");
    // Same cell, same inputs, two weight traversals: the interleaved
    // kernel streams x/h once per output element feeding all four gate
    // dot products; the row-major reference streams them once per gate
    // row. Bit-identity is asserted here before timing and enforced by
    // the property suite; these rows are the CI perf gate's kernel set.
    {
        let mut krng = lstm_ae_accel::util::rng::Xoshiro256::seeded(29);
        for (lx, lh) in [(64usize, 64usize), (64, 16)] {
            let w = lstm_ae_accel::model::weights::LayerWeights::random(
                lstm_ae_accel::model::topology::LayerDims { lx, lh },
                &mut krng,
            );
            let kcell = QuantLstmCell::new(&w);
            let kx: Vec<Q8_24> =
                (0..lx).map(|i| Q8_24::from_f64((i as f64 * 0.013).sin() * 0.5)).collect();
            let kmacs = 4.0 * lh as f64 * (lx + lh) as f64;
            let mut kscratch = StepScratch::new();
            let mut sa = QuantLstmState::zeros(lh);
            let mut sb = QuantLstmState::zeros(lh);
            for _ in 0..8 {
                kcell.step_into(&mut sa, &kx, &mut kscratch);
                kcell.step_into_rowmajor(&mut sb, &kx, &mut kscratch);
            }
            assert_eq!(sa.h, sb.h, "interleaved h != rowmajor h ({lx}x{lh})");
            assert_eq!(sa.c, sb.c, "interleaved c != rowmajor c ({lx}x{lh})");
            let r = bench_auto(&format!("kernel step_into {lx}x{lh} rowmajor"), 20, || {
                kcell.step_into_rowmajor(black_box(&mut sa), black_box(&kx), &mut kscratch);
                black_box(sa.h[0]);
            });
            println!("{}   ({:.1} M MAC/s)", r.report(), kmacs / r.per_iter.mean / 1e6);
            rec.add(&r, Some(kmacs));
            let r = bench_auto(&format!("kernel step_into {lx}x{lh} interleaved"), 20, || {
                kcell.step_into(black_box(&mut sa), black_box(&kx), &mut kscratch);
                black_box(sa.h[0]);
            });
            println!("{}   ({:.1} M MAC/s)", r.report(), kmacs / r.per_iter.mean / 1e6);
            rec.add(&r, Some(kmacs));

            // Batched MMM form of the same layouts: B windows advance
            // together, each weight block streamed once per tile of B.
            const KB: usize = 16;
            let kxb: Vec<Q8_24> =
                (0..KB * lx).map(|i| Q8_24::from_f64((i as f64 * 0.007).cos() * 0.5)).collect();
            let bmacs = KB as f64 * kmacs;
            let mut h1 = vec![Q8_24::ZERO; KB * lh];
            let mut c1 = vec![Q8_24::ZERO; KB * lh];
            let mut h2 = vec![Q8_24::ZERO; KB * lh];
            let mut c2 = vec![Q8_24::ZERO; KB * lh];
            for _ in 0..4 {
                kcell.step_batch_into(KB, &mut h1, &mut c1, &kxb, &mut kscratch);
                kcell.step_batch_into_rowmajor(KB, &mut h2, &mut c2, &kxb, &mut kscratch);
            }
            assert_eq!(h1, h2, "batched interleaved h != rowmajor h ({lx}x{lh})");
            assert_eq!(c1, c2, "batched interleaved c != rowmajor c ({lx}x{lh})");
            let r = bench_auto(
                &format!("kernel step_batch_into {lx}x{lh} B={KB} rowmajor"),
                20,
                || {
                    kcell.step_batch_into_rowmajor(
                        KB,
                        black_box(&mut h1),
                        &mut c1,
                        black_box(&kxb),
                        &mut kscratch,
                    );
                    black_box(h1[0]);
                },
            );
            println!("{}   ({:.1} M MAC/s)", r.report(), bmacs / r.per_iter.mean / 1e6);
            rec.add(&r, Some(bmacs));
            let r = bench_auto(
                &format!("kernel step_batch_into {lx}x{lh} B={KB} interleaved"),
                20,
                || {
                    kcell.step_batch_into(
                        KB,
                        black_box(&mut h1),
                        &mut c1,
                        black_box(&kxb),
                        &mut kscratch,
                    );
                    black_box(h1[0]);
                },
            );
            println!("{}   ({:.1} M MAC/s)", r.report(), bmacs / r.per_iter.mean / 1e6);
            rec.add(&r, Some(bmacs));
        }
    }

    println!("\n## Model forward (bit-accurate FPGA datapath, F32-D2, T=16)");
    let ae = LstmAutoencoder::random(Topology::from_name("F32-D2").unwrap(), 3);
    let mut gen = TelemetryGen::new(32, 5);
    let win = gen.benign_window(16);
    let r = bench_auto("score_quant F32-D2 T=16", 20, || {
        black_box(ae.score_quant(black_box(&win.data)));
    });
    println!("{}", r.report());
    rec.add(&r, Some(1.0));
    let r = bench_auto("score_f32 F32-D2 T=16", 20, || {
        black_box(ae.score_f32(black_box(&win.data)));
    });
    println!("{}", r.report());
    rec.add(&r, None);

    println!("\n## Streaming sessions: O(1) step vs O(T) rescore (F32-D2, W=64)");
    // The stateful-scoring asymptotics: one step_session call advances the
    // carried per-layer state and rescores the trailing ring against a
    // single fresh forward row — O(1) in the stream's history — while the
    // stateless equivalent re-runs the whole window from zero on every
    // sample. Bit-identity of the two paths is enforced by the property
    // suite; these rows only time them (and are deliberately not "kernel "
    // rows — the CI perf gate tracks kernels, these track serving shape).
    {
        let sae =
            Arc::new(LstmAutoencoder::random(Topology::from_name("F32-D2").unwrap(), 23));
        let mut sgen = TelemetryGen::new(32, 31);
        const SW: usize = 64;
        let warm = sgen.benign_window(SW);
        let mut sess = SessionState::new(&sae, SW);
        for row in &warm.data {
            step_session(&sae, &mut sess, row);
        }
        let next = sgen.benign_window(1).data.remove(0);
        let r = bench_auto(&format!("stream step F32-D2 W={SW}"), 20, || {
            black_box(step_session(&sae, &mut sess, black_box(&next)));
        });
        println!("{}   ({:.1} k samples/s)", r.report(), 1.0 / r.per_iter.mean / 1e3);
        rec.add(&r, Some(1.0));
        let r = bench_auto(&format!("stream rescore F32-D2 W={SW}"), 20, || {
            black_box(sae.score_quant(black_box(&warm.data)));
        });
        println!("{}   ({:.1} k windows/s)", r.report(), 1.0 / r.per_iter.mean / 1e3);
        rec.add(&r, Some(1.0));
    }

    println!("\n## Temporal-pipeline engine vs sequential (F64-D6 deep model)");
    // The paper's architectural claim in software: per-layer workers
    // overlapping timesteps (pipelined) and weight-reuse batching (MMM)
    // against the layer-at-a-time sequential scorer. All three produce
    // bit-identical scores (asserted below before timing).
    let deep = Arc::new(LstmAutoencoder::random(
        Topology::from_name("F64-D6").unwrap(),
        17,
    ));
    let mut gen64 = TelemetryGen::new(64, 21);
    const ENGINE_B: usize = 16;
    const ENGINE_T: usize = 64;
    let batch_windows: Vec<_> = (0..ENGINE_B).map(|_| gen64.benign_window(ENGINE_T)).collect();
    let refs: Vec<&[Vec<f32>]> = batch_windows.iter().map(|w| w.data.as_slice()).collect();
    let pipeline = TemporalPipeline::new(deep.clone());
    let batch_engine = BatchEngine::new(deep.clone());
    {
        let seq: Vec<f64> = refs.iter().map(|w| deep.score_quant(w)).collect();
        assert_eq!(seq, pipeline.score_batch(&refs), "pipelined != sequential");
        assert_eq!(seq, batch_engine.score_batch(&refs), "batched != sequential");
    }
    let r = bench_auto(
        &format!("engine F64-D6 T={ENGINE_T} B={ENGINE_B} sequential"),
        20,
        || {
            let s: f64 = refs.iter().map(|w| deep.score_quant(black_box(w))).sum();
            black_box(s);
        },
    );
    println!("{}   ({:.1} windows/s)", r.report(), ENGINE_B as f64 / r.per_iter.mean);
    rec.add(&r, Some(ENGINE_B as f64));
    let r = bench_auto(
        &format!("engine F64-D6 T={ENGINE_T} B={ENGINE_B} pipelined"),
        20,
        || {
            let s: f64 = pipeline.score_batch(black_box(&refs)).iter().sum();
            black_box(s);
        },
    );
    println!("{}   ({:.1} windows/s)", r.report(), ENGINE_B as f64 / r.per_iter.mean);
    rec.add(&r, Some(ENGINE_B as f64));
    let r = bench_auto(
        &format!("engine F64-D6 T={ENGINE_T} B={ENGINE_B} batched"),
        20,
        || {
            let s: f64 = batch_engine.score_batch(black_box(&refs)).iter().sum();
            black_box(s);
        },
    );
    println!("{}   ({:.1} windows/s)", r.report(), ENGINE_B as f64 / r.per_iter.mean);
    rec.add(&r, Some(ENGINE_B as f64));
    // Single-window latency view (the pipeline's home turf).
    let one = &batch_windows[0].data;
    let r = bench_auto("engine F64-D6 T=64 B=1 sequential", 20, || {
        black_box(deep.score_quant(black_box(one)));
    });
    println!("{}", r.report());
    rec.add(&r, Some(1.0));
    let r = bench_auto("engine F64-D6 T=64 B=1 pipelined", 20, || {
        black_box(pipeline.score(black_box(one)));
    });
    println!("{}", r.report());
    rec.add(&r, Some(1.0));
    // Same pipeline with stage workers pinned to neighbouring cores, so
    // the layer-to-layer token handoff stays within adjacent caches.
    // Pinning is best-effort and never changes scores (asserted).
    let pinned = TemporalPipeline::with_options(
        deep.clone(),
        PipelineOptions { pin_base_core: Some(0), ..Default::default() },
    );
    assert_eq!(pipeline.score(one), pinned.score(one), "pinned != unpinned");
    let r = bench_auto("engine F64-D6 T=64 B=1 pipelined pinned", 20, || {
        black_box(pinned.score(black_box(one)));
    });
    println!("{}", r.report());
    rec.add(&r, Some(1.0));

    println!("\n## Engine replica pool (shared vs per-worker pipelines, F64-D6 B=1)");
    // Four closed-loop threads each scoring lone deep-model windows: with
    // one replica every thread serializes on that pipeline's endpoint
    // lock; with four replicas the checkouts spread and the only
    // remaining serialization is within a replica. Scores stay
    // bit-identical either way — the pool changes timing, never results.
    for replicas in [1usize, 4] {
        let pool = Arc::new(PipelinePool::new(deep.clone(), replicas));
        let threads = 4usize;
        let per_thread = 8usize;
        // Warm every replica (rotating checkout visits each once), then
        // take the best of several repetitions so a cold first pass or a
        // scheduling hiccup can't decide the replicas=1 vs =4 comparison.
        for _ in 0..replicas {
            let _ = pool.score(one);
        }
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let start = std::time::Instant::now();
            std::thread::scope(|s| {
                for _ in 0..threads {
                    let pool = pool.clone();
                    s.spawn(move || {
                        for _ in 0..per_thread {
                            black_box(pool.score(black_box(one)));
                        }
                    });
                }
            });
            best = best.min(start.elapsed().as_secs_f64());
        }
        let windows = (threads * per_thread) as f64;
        let name = format!("pool F64-D6 T=64 threads=4 replicas={replicas}");
        println!(
            "{name}: best {:.3} ms → {:.1} windows/s ({} of {} replicas used)",
            best * 1e3,
            windows / best,
            pool.used_replicas(),
            pool.replicas(),
        );
        rec.add_throughput(&name, windows, best);
    }

    println!("\n## Workload generation");
    let r = bench_auto("benign_window T=16 F=32", 20, || {
        black_box(gen.benign_window(16));
    });
    println!("{}", r.report());
    rec.add(&r, None);

    println!("\n## PJRT dispatch (needs artifacts; skipped otherwise)");
    if let Ok(rt) = lstm_ae_accel::runtime::Runtime::open(
        &lstm_ae_accel::runtime::Runtime::default_dir(),
    ) {
        let t = 16usize;
        let f = 32usize;
        let mut gen = TelemetryGen::new(f, 77);
        let one: Vec<f32> = gen.benign_window(t).data.into_iter().flatten().collect();
        let eight: Vec<f32> = (0..8)
            .flat_map(|_| gen.benign_window(t).data.into_iter().flatten().collect::<Vec<_>>())
            .collect();
        let _ = rt.infer("F32-D2", t, &one); // compile outside timing
        let _ = rt.infer_batch("F32-D2", t, 8, &eight);
        let r = bench_auto("pjrt infer F32-D2 T=16 (single)", 20, || {
            black_box(rt.infer("F32-D2", 16, black_box(&one)).unwrap());
        });
        println!("{}   ({:.0} windows/s)", r.report(), 1.0 / r.per_iter.mean);
        rec.add(&r, Some(1.0));
        let r = bench_auto("pjrt infer_batch F32-D2 T=16 B=8", 20, || {
            black_box(rt.infer_batch("F32-D2", 16, 8, black_box(&eight)).unwrap());
        });
        println!("{}   ({:.0} windows/s)", r.report(), 8.0 / r.per_iter.mean);
        rec.add(&r, Some(8.0));
    } else {
        println!("(no artifacts)");
    }

    println!("\n## Server throughput (quant backend, closed loop)");
    let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(
        Topology::from_name("F32-D2").unwrap(),
        9,
    )));
    let srv = AnomalyServer::start(
        backend,
        ServerConfig::builder()
            .max_batch(16)
            .max_wait(std::time::Duration::from_micros(200))
            .workers(4)
            .queue_capacity(1024) // 512 in flight: sized to never shed
            .threshold(0.1)
            .build(),
    );
    let mut gen = TelemetryGen::new(32, 11);
    let windows: Vec<_> = (0..512).map(|_| gen.benign_window(16)).collect();
    let start = std::time::Instant::now();
    let rxs: Vec<_> =
        windows.iter().map(|w| srv.submit(w.clone()).expect("queue sized")).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let dt = start.elapsed().as_secs_f64();
    println!(
        "512 windows in {:.3}s → {:.0} windows/s | {}",
        dt,
        512.0 / dt,
        srv.metrics().report()
    );
    rec.add_throughput("server closed-loop F32-D2 T=16 (512 windows)", 512.0, dt);
    srv.shutdown();

    println!("\n## Async front: closed-loop blocking vs tickets (equal client threads)");
    // The process-edge comparison the async front exists for: at the SAME
    // client-thread count, the blocking driver can hold exactly one
    // request in flight per thread (its thread parks on recv()), while
    // the ticket driver holds 64 per thread through a CompletionSet. The
    // acceptance bar (EXPERIMENTS.md §Perf): ≥ 4× the outstanding count
    // without raising the shed rate — the queue is sized so neither
    // driver sheds, and the `shed` field records it.
    {
        let clients = 4usize;
        let per_client_outstanding = 64usize; // 64× the blocking driver
        let total = 4096usize;
        for asynchronous in [false, true] {
            let mut registry = ModelRegistry::new();
            registry.register(
                "LSTM-AE-F32-D2",
                Arc::new(QuantBackend::new(LstmAutoencoder::random(
                    Topology::from_name("F32-D2").unwrap(),
                    15,
                ))),
                ServerConfig::builder()
                    .max_batch(16)
                    .max_wait(std::time::Duration::from_micros(200))
                    .workers(4)
                    .queue_capacity(1024)
                    .threshold(0.1)
                    .build(),
            );
            let models = vec!["LSTM-AE-F32-D2".to_string()];
            let stats = if asynchronous {
                closed_loop_async(
                    &registry,
                    &models,
                    clients,
                    per_client_outstanding,
                    total,
                    16,
                    19,
                )
            } else {
                closed_loop_blocking(&registry, &models, clients, total, 16, 19)
            };
            let lane_shed = registry.lane("F32-D2").map_or(0, |l| l.metrics().shed());
            let wall = stats.wall.as_secs_f64().max(1e-9);
            let name = format!(
                "front closed-loop F32-D2 T=16 clients=4 {}",
                if asynchronous { "async out=256" } else { "blocking out=4" }
            );
            println!(
                "{name}: {} completed in {wall:.3}s ({:.0}/s) | peak outstanding {} | \
                 shed {lane_shed}",
                stats.completed,
                stats.completed as f64 / wall,
                stats.max_outstanding
            );
            rec.add_scalars(
                &name,
                &[
                    ("outstanding", stats.max_outstanding as f64),
                    ("shed", lane_shed as f64),
                    ("completed", stats.completed as f64),
                    ("throughput_per_s", stats.completed as f64 / wall),
                    ("wall_s", wall),
                ],
            );
            registry.shutdown();
        }
    }

    println!("\n## Autoscaler: static vs adaptive lanes, rotating hot model");
    // Two lanes over a deterministically throttled backend (1 ms floor
    // per singleton batch → 1000 windows/s per worker on any host); all
    // traffic hits one lane at a time and the hot lane rotates. Static:
    // 2 + 2 workers pinned. Autoscaled: same total budget (4), min 1 /
    // max 3 per lane, so threads follow the heat. EXPERIMENTS.md §Perf
    // entry 7 tracks the shed counts these rows record.
    for autoscaled in [false, true] {
        let topos = [
            Topology::from_name("F32-D2").unwrap(),
            Topology::from_name("F64-D2").unwrap(),
        ];
        let policy = autoscaled.then(|| AutoscalePolicy {
            min_workers: 1,
            max_workers: 3,
            up_queue_frac: 0.3,
            up_ticks: 1,
            down_idle_frac: 0.5,
            down_ticks: 2,
            ..Default::default()
        });
        let mut registry = ModelRegistry::new();
        for topo in &topos {
            let mut cfg = ServerConfig::builder()
                .max_batch(1)
                .max_wait(std::time::Duration::from_micros(50))
                .workers(2)
                .queue_capacity(16)
                .threshold(1.0);
            if let Some(p) = policy.clone() {
                cfg = cfg.autoscale(p);
            }
            registry.register(
                &topo.name,
                Arc::new(ThrottledBackend::zeros(std::time::Duration::from_millis(1))),
                cfg.build(),
            );
        }
        if autoscaled {
            registry.start_autoscaler(std::time::Duration::from_millis(10), Some(4));
        }
        let trace = rotating_hot_poisson(&topos, 42, 2400.0, 2880, 4, 0.0, 1.0, 960);
        let start = std::time::Instant::now();
        let mut inflight = Vec::new();
        let mut shed = 0u64;
        for (mi, req) in trace {
            let target = std::time::Duration::from_secs_f64(req.at_s);
            if let Some(sleep) = target.checked_sub(start.elapsed()) {
                std::thread::sleep(sleep);
            }
            match registry.submit(&topos[mi].name, req.window) {
                Ok(rx) => inflight.push(rx),
                Err(_) => shed += 1,
            }
        }
        let accepted = inflight.len();
        for rx in inflight {
            let _ = rx.recv();
        }
        let wall = start.elapsed().as_secs_f64();
        let name = format!(
            "fleet rotating-hot 2400rps budget=4 {}",
            if autoscaled { "autoscaled" } else { "static" }
        );
        println!(
            "{name}: {accepted} completed, {shed} shed in {wall:.2}s ({:.0} completed/s)",
            accepted as f64 / wall
        );
        rec.add_scalars(
            &name,
            &[
                ("shed", shed as f64),
                ("completed", accepted as f64),
                ("throughput_per_s", accepted as f64 / wall),
                ("wall_s", wall),
            ],
        );
        registry.shutdown();
    }

    println!("\n## Shard fabric: in-process registry vs loopback TCP (same async driver)");
    // The wire tax, isolated: the identical closed-loop ticket driver
    // against (a) the registry in-process and (b) the same registry
    // behind a ShardServer on 127.0.0.1 through a ShardRouter — frame
    // encode/decode, two socket hops, and the per-connection
    // reader/writer pair are the only difference between the rows.
    {
        let clients = 4usize;
        let per_client_outstanding = 64usize;
        let total = 4096usize;
        let models = vec!["LSTM-AE-F32-D2".to_string()];
        let mk_registry = || {
            let mut registry = ModelRegistry::new();
            registry.register(
                "LSTM-AE-F32-D2",
                Arc::new(QuantBackend::new(LstmAutoencoder::random(
                    Topology::from_name("F32-D2").unwrap(),
                    15,
                ))),
                ServerConfig::builder()
                    .max_batch(16)
                    .max_wait(std::time::Duration::from_micros(200))
                    .workers(4)
                    .queue_capacity(4096)
                    .threshold(0.1)
                    .build(),
            );
            registry
        };
        for remote in [false, true] {
            let (stats, name) = if remote {
                let server = ShardServer::bind("127.0.0.1:0", Arc::new(mk_registry()))
                    .expect("bind loopback shard");
                let router = ShardRouter::connect(&[server.local_addr().to_string()])
                    .expect("connect loopback shard");
                let stats = closed_loop_async(
                    &router,
                    &models,
                    clients,
                    per_client_outstanding,
                    total,
                    16,
                    19,
                );
                router.shutdown();
                server.shutdown();
                (stats, "shard loopback closed-loop F32-D2 T=16 clients=4 out=256")
            } else {
                let registry = mk_registry();
                let stats = closed_loop_async(
                    &registry,
                    &models,
                    clients,
                    per_client_outstanding,
                    total,
                    16,
                    19,
                );
                registry.shutdown();
                (stats, "shard in-process closed-loop F32-D2 T=16 clients=4 out=256")
            };
            let wall = stats.wall.as_secs_f64().max(1e-9);
            println!(
                "{name}: {} completed in {wall:.3}s ({:.0}/s) | peak outstanding {} | \
                 {} shed retries",
                stats.completed,
                stats.completed as f64 / wall,
                stats.max_outstanding,
                stats.shed_retries
            );
            rec.add_scalars(
                name,
                &[
                    ("completed", stats.completed as f64),
                    ("throughput_per_s", stats.completed as f64 / wall),
                    ("outstanding", stats.max_outstanding as f64),
                    ("shed_retries", stats.shed_retries as f64),
                    ("wall_s", wall),
                ],
            );
        }
    }

    println!("\n## Score cache: Zipf-skewed replay, cold vs cached (same trace)");
    // The single-flight score cache's headline numbers: the identical
    // Zipf(s=1.1) trace through the paper fleet uncached ("cold" — every
    // request occupies a batch slot) and with the default cache on
    // ("zipf" — repeats are served from cache or coalesced onto an
    // in-flight leader). batch_slots is the figure the cache exists to
    // shrink; hit/coalesce counts record how. EXPERIMENTS.md §Perf
    // entry 12 tracks these rows.
    {
        let topos = Topology::paper_models();
        let models: Vec<String> = topos.iter().map(|m| m.name.clone()).collect();
        let trace = zipf_poisson(&topos, 61, 8000.0, 2000, 8, 64, 1.1);
        for cached in [false, true] {
            let registry = if cached {
                ModelRegistry::paper_fleet_opts(
                    61,
                    ExecMode::Auto,
                    2,
                    None,
                    PipelineOptions::default(),
                    Some(CacheConfig::default()),
                )
            } else {
                ModelRegistry::paper_fleet(61, ExecMode::Auto, 2)
            };
            let stats = replay_async(&registry, &models, trace.clone());
            let wall = stats.wall.as_secs_f64().max(1e-9);
            let (mut hits, mut coalesced, mut slots) = (0u64, 0u64, 0u64);
            for m in &models {
                let lm = registry.lane(m).unwrap().metrics();
                hits += lm.cache_hits();
                coalesced += lm.coalesced();
                slots += lm.batched_windows();
            }
            let name = if cached {
                "cache zipf fleet T=8 n=2000 pool=64"
            } else {
                "cache cold fleet T=8 n=2000 pool=64"
            };
            println!(
                "{name}: {} completed in {wall:.3}s ({:.0}/s) | {slots} batch slots | \
                 {hits} hits, {coalesced} coalesced",
                stats.completed,
                stats.completed as f64 / wall
            );
            rec.add_scalars(
                name,
                &[
                    ("completed", stats.completed as f64),
                    ("throughput_per_s", stats.completed as f64 / wall),
                    ("batch_slots", slots as f64),
                    ("cache_hits", hits as f64),
                    ("coalesced", coalesced as f64),
                    ("wall_s", wall),
                ],
            );
            registry.shutdown();
        }
    }

    rec.flush();
}

