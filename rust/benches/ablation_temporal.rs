//! Ablation A2 (§3.4's motivation): temporal-parallel dataflow vs
//! traditional layer-by-layer execution on the *same* per-layer hardware,
//! including the DRAM round-trips layer-by-layer pays for intermediate
//! sequences.
//!
//! ```bash
//! cargo bench --bench ablation_temporal
//! ```

use lstm_ae_accel::accel::dataflow::DataflowSim;
use lstm_ae_accel::accel::layer_by_layer::{run_layer_by_layer, MemModel};
use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::model::Topology;
use lstm_ae_accel::util::table::Table;

fn main() {
    let dev = FpgaDevice::ZCU104;
    let mut table = Table::new("Ablation A2 — dataflow (temporal parallelism) vs layer-by-layer")
        .header(&[
            "Model",
            "T",
            "dataflow ms",
            "layer-by-layer ms",
            "  (compute)",
            "  (DRAM)",
            "speedup",
        ]);
    for topo in Topology::paper_models() {
        let cfg = BalancedConfig::paper_config(&topo);
        for t in [1usize, 6, 16, 64, 256] {
            let df = DataflowSim::new(&cfg).run_sequence(t);
            let lbl = run_layer_by_layer(&cfg, MemModel::default(), t);
            table.row(vec![
                topo.name.clone(),
                t.to_string(),
                format!("{:.4}", df.total_ms(dev.clock_hz)),
                format!("{:.4}", lstm_ae_accel::cycles_to_ms(lbl.total_cycles, dev.clock_hz)),
                format!("{:.4}", lstm_ae_accel::cycles_to_ms(lbl.compute_cycles, dev.clock_hz)),
                format!("{:.4}", lstm_ae_accel::cycles_to_ms(lbl.dram_cycles, dev.clock_hz)),
                format!("x{:.2}", lbl.total_cycles as f64 / df.total_cycles as f64),
            ]);
        }
        table.separator();
    }
    print!("{}", table.render());
    println!("Speedup grows with depth (more layers overlap) and with T (fill cost");
    println!("amortizes) — the §3.4 argument, quantified. At D6/T=256 the dataflow");
    println!("architecture approaches the ideal depth-fold speedup.");
}
