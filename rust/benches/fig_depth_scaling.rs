//! Bench E4: the depth-scalability figure (§4.2 text): latency vs depth
//! at fixed width/sequence length, FPGA vs calibrated CPU/GPU. Prints the
//! series a plot would consume (CSV block at the end).
//!
//! ```bash
//! cargo bench --bench fig_depth_scaling
//! ```

use lstm_ae_accel::accel::dataflow::DataflowSim;
use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::baselines::{CalibratedModel, Platform};
use lstm_ae_accel::model::Topology;
use lstm_ae_accel::report;
use lstm_ae_accel::report::tables::PS_INVOCATION_OVERHEAD_MS;

fn main() {
    print!("{}", report::depth_scaling());

    let cpu = CalibratedModel::fit(Platform::XeonGold5218R);
    let gpu = CalibratedModel::fit(Platform::V100);
    let dev = FpgaDevice::ZCU104;
    println!("\n## CSV (depth, fpga_ms, cpu_ms, gpu_ms) — F64, T=64");
    println!("depth,fpga_ms,cpu_ms,gpu_ms");
    for depth in (2..=10).step_by(2) {
        let Ok(topo) = Topology::new(64, depth) else { continue };
        let cfg = BalancedConfig::balance(&topo, 4);
        let f = PS_INVOCATION_OVERHEAD_MS
            + DataflowSim::new(&cfg).run_sequence(64).total_ms(dev.clock_hz);
        println!(
            "{depth},{f:.5},{:.5},{:.5}",
            cpu.latency_ms(&topo, 64),
            gpu.latency_ms(&topo, 64)
        );
    }

    // The §4.2 claim, asserted (exit code is the pass/fail).
    let d2 = Topology::new(64, 2).unwrap();
    let d6 = Topology::new(64, 6).unwrap();
    let f =
        |t: &Topology| -> f64 {
            PS_INVOCATION_OVERHEAD_MS
                + DataflowSim::new(&BalancedConfig::paper_config(t))
                    .run_sequence(64)
                    .total_ms(dev.clock_hz)
        };
    let fpga_ratio = f(&d6) / f(&d2);
    let cpu_ratio = cpu.latency_ms(&d6, 64) / cpu.latency_ms(&d2, 64);
    let gpu_ratio = gpu.latency_ms(&d6, 64) / gpu.latency_ms(&d2, 64);
    println!("\nD2→D6 ratios: FPGA x{fpga_ratio:.2} (paper ~1.4), CPU x{cpu_ratio:.2} (2.9), GPU x{gpu_ratio:.2} (2.2)");
    assert!(fpga_ratio < gpu_ratio && gpu_ratio < cpu_ratio, "ordering must hold");
    println!("[PASS] FPGA < GPU < CPU depth-scaling ordering");
}
