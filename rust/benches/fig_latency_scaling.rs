//! Bench E5: latency-vs-T scaling series (§4.2's discussion of how RH_m
//! shapes scaling) — FPGA simulation for each paper model over a dense
//! T sweep, with the paper's measured points interleaved for comparison.
//!
//! ```bash
//! cargo bench --bench fig_latency_scaling
//! ```

use lstm_ae_accel::model::Topology;
use lstm_ae_accel::report;
use lstm_ae_accel::report::paper_data;
use lstm_ae_accel::report::tables::{fpga_latency_ms, fpga_platform_latency_ms};

fn main() {
    print!("{}", report::latency_scaling());

    println!("\n## CSV (T, per-model platform-adjusted ms; paper cells where available)");
    print!("T");
    for c in &paper_data::TABLE2 {
        print!(",{}_sim,{}_paper", c.model, c.model);
    }
    println!();
    for &t in &[1usize, 2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128] {
        print!("{t}");
        for c in &paper_data::TABLE2 {
            let topo = Topology::from_name(c.model).unwrap();
            let sim = fpga_platform_latency_ms(&topo, t);
            let paper = paper_data::TIMESTEPS
                .iter()
                .position(|&x| x == t)
                .map(|i| format!("{:.3}", c.fpga[i]))
                .unwrap_or_default();
            print!(",{sim:.5},{paper}");
        }
        println!();
    }

    // Slope analysis: ms per additional timestep in steady state.
    println!("\n## Steady-state slope (µs/timestep)");
    for c in &paper_data::TABLE2 {
        let topo = Topology::from_name(c.model).unwrap();
        let slope_sim = (fpga_latency_ms(&topo, 128) - fpga_latency_ms(&topo, 64)) / 64.0 * 1e3;
        let slope_paper = (c.fpga[5] - c.fpga[4]) / 48.0 * 1e3;
        println!(
            "{:>16}: sim {slope_sim:7.3}  paper {slope_paper:7.3}  (RH_m = {})",
            c.model,
            lstm_ae_accel::accel::reuse::BalancedConfig::paper_rh_m(c.model).unwrap()
        );
    }
    println!("\nThe paper's observation — wider models (RH_m = 4, 8) scale more steeply");
    println!("with T than RH_m = 1 models — falls out of the slope column above.");
}
