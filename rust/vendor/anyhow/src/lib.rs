//! Offline stand-in for the `anyhow` crate, implementing exactly the
//! subset this workspace uses: [`Error`] (a context-chained dynamic
//! error), [`Result`], the [`anyhow!`] / [`bail!`] macros, and the
//! [`Context`] extension trait on `Result`.
//!
//! Semantics mirror the real crate where it matters to callers:
//!
//! - `Display` prints the outermost message; the alternate form (`{:#}`)
//!   prints the whole chain joined by `": "` (what `main.rs` relies on
//!   for `error: {e:#}` reporting).
//! - Any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`, capturing its `source()` chain.
//! - `.context(..)` / `.with_context(..)` push an outer message.
//!
//! Drop-in replaceable by the real vendored `anyhow` when a registry is
//! available — no caller references anything beyond this surface.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. `chain[0]` is the outermost message (what
/// plain `Display` shows); deeper entries are the wrapped causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push an outer context message (what `Context::context` does).
    fn wrap(mut self, ctx: String) -> Error {
        self.chain.insert(0, ctx);
        self
    }

    /// The cause messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket conversion below
// coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding context to fallible results.
pub trait Context<T> {
    /// Wrap the error with an eagerly-evaluated message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-evaluated message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/file")
            .map(|_| ())
            .context("reading config")
    }

    #[test]
    fn context_chain_formats() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        let full = format!("{err:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        assert!(full.len() > "reading config: ".len());
    }

    #[test]
    fn macros_and_bail() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed (got {x})");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        let e = f(0).unwrap_err();
        assert_eq!(format!("{e}"), "zero not allowed (got 0)");
        let e2: Error = anyhow!("plain {}", 42);
        assert_eq!(format!("{e2}"), "plain 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }
}
