//! Offline stub of the `xla` (xla-rs) PJRT API surface used by
//! `lstm_ae_accel::runtime`.
//!
//! The real crate wraps `xla_extension` shared objects that are not part
//! of this offline image. This stub keeps the crate **compiling and
//! testable** with identical signatures: every entry point that would
//! touch PJRT returns an [`Error`] stating the runtime is unavailable.
//! All runtime-dependent code paths in the workspace already handle that
//! gracefully (tests skip when artifacts are missing, benches print
//! "(no artifacts)", the server falls back to the bit-accurate Q8.24
//! backend), so swapping in the real vendored crate re-enables PJRT
//! execution with no source changes.

use std::fmt;

/// Error type mirroring `xla::Error`'s role: printable, `std::error::Error`,
/// convertible into `anyhow::Error` by `?`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "PJRT unavailable: {what} (offline xla stub — vendor the real xla crate \
         and its xla_extension libraries to enable artifact execution)"
    ))
}

/// PJRT client handle. The stub cannot construct one: `cpu()` always
/// errors, which is the single gate keeping every downstream method
/// unreachable in practice.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by `execute`.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module (text interchange).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host literal (tensor) handle.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(err.to_string().contains("PJRT unavailable"));
    }
}
