//! The shard fabric's wire protocol: versioned, length-prefixed binary
//! frames over a byte stream.
//!
//! ```text
//! ┌──────────────┬─────────┬──────────────────────────────┐
//! │ len: u32 LE  │ tag: u8 │ payload (len - 1 bytes)      │
//! └──────────────┴─────────┴──────────────────────────────┘
//!   len counts tag + payload; len == 0 and len > MAX_FRAME_LEN are
//!   rejected before any allocation, so a garbage prefix cannot make the
//!   decoder reserve gigabytes or spin.
//! ```
//!
//! Thirteen frame kinds carry the whole protocol (see [`Frame`]). Tags
//! 0–4 are the window data plane; tags 5–8 are the control plane the
//! shard registry drives membership and health from; tags 9–12 are the
//! streaming-session plane (stateful incremental scoring):
//!
//! | tag | frame        | direction        | payload                        |
//! |-----|--------------|------------------|--------------------------------|
//! | 0   | `Hello`      | both, first      | `version: u16`                 |
//! | 1   | `Submit`     | client → shard   | `id, model, T×F f32 window`    |
//! | 2   | `Response`   | shard → client   | `id, score, flags, latencies`  |
//! | 3   | `Shed`       | shard → client   | `id, reason: u8`               |
//! | 4   | `FleetReport`| both             | `text` (empty = request)       |
//! | 5   | `Join`       | shard → client   | `shard_id: u64, models: u32`   |
//! | 6   | `Leave`      | both             | `reason: str`                  |
//! | 7   | `HealthProbe`| client → shard   | `seq: u64`                     |
//! | 8   | `Heartbeat`  | shard → client   | `seq, load counters, p50/p99`  |
//! | 9   | `StreamOpen` | client → shard   | `stream, model, window: u32`   |
//! | 10  | `StreamSample`| client → shard  | `stream, id, model, F f32 row` |
//! | 11  | `StreamScore`| shard → client   | `stream, id, score, flags`     |
//! | 12  | `StreamClose`| client → shard   | `stream, model`                |
//!
//! Integers and floats are little-endian; strings are `u16` length +
//! UTF-8 bytes; the window is `T: u32, F: u32` then `T·F` `f32` samples
//! row-major. Every decode error is a clean [`WireError`] — malformed
//! input (truncated payloads, unknown tags, oversized or garbage length
//! prefixes, invalid UTF-8) never panics, which the randomized round-trip
//! and rejection tests below pin down.
//!
//! Versioning is a hard gate at the [`Frame::Hello`] handshake: both ends
//! send their [`WIRE_VERSION`] first and refuse mismatches, so a frame is
//! only ever parsed by a peer that speaks the same layout.

use std::io::{Read, Write};

/// Protocol version exchanged in [`Frame::Hello`]; both ends must match.
/// v2 added the control plane (`Join`/`Leave`/`HealthProbe`/`Heartbeat`)
/// and the shard's post-handshake `Join` announcement. v3 added the
/// streaming-session plane
/// (`StreamOpen`/`StreamSample`/`StreamScore`/`StreamClose`).
pub const WIRE_VERSION: u16 = 3;

/// Upper bound on `len` (tag + payload bytes) accepted by the decoder.
/// 16 MiB comfortably holds the largest real frame (a `Submit` carrying a
/// long telemetry window) while rejecting garbage prefixes cheaply.
pub const MAX_FRAME_LEN: usize = 1 << 24;

/// Why a shard turned a submission away (the wire form of
/// [`crate::server::SubmitError`], minus the model name).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The lane's bounded admission queue was full (load shed).
    Overloaded,
    /// The lane (or the whole shard) is shut down.
    Closed,
    /// The shard serves no model by the submitted name.
    UnknownModel,
}

impl ShedReason {
    fn to_byte(self) -> u8 {
        match self {
            ShedReason::Overloaded => 0,
            ShedReason::Closed => 1,
            ShedReason::UnknownModel => 2,
        }
    }

    fn from_byte(b: u8) -> Result<ShedReason, WireError> {
        match b {
            0 => Ok(ShedReason::Overloaded),
            1 => Ok(ShedReason::Closed),
            2 => Ok(ShedReason::UnknownModel),
            _ => Err(WireError::BadPayload("unknown shed reason")),
        }
    }
}

/// One protocol frame. See the module docs for the byte layout.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Handshake: first frame in each direction; carries the sender's
    /// [`WIRE_VERSION`]. A mismatch refuses the connection.
    Hello { version: u16 },
    /// A scoring request: client-chosen `id` (echoed back in the matching
    /// [`Frame::Response`] / [`Frame::Shed`]), model name, and the
    /// telemetry window as `T` rows of `F` samples.
    Submit { id: u64, model: String, window: Vec<Vec<f32>> },
    /// A scored response for `Submit { id, .. }` — the wire form of
    /// [`crate::server::Response`], bit-exact (`score` travels as raw
    /// `f64` bits, so remote scores stay bit-identical to local ones).
    Response { id: u64, score: f64, is_anomaly: bool, queue_us: f64, service_us: f64, e2e_us: f64 },
    /// The shard turned `Submit { id, .. }` away; `reason` says why.
    Shed { id: u64, reason: ShedReason },
    /// Fleet-report exchange: an empty `text` asks the shard for its
    /// rolled-up report; the shard answers with the report text.
    FleetReport { text: String },
    /// Sent by the shard right after the handshake on every connection:
    /// `shard_id` identifies the serving *process instance* (a restarted
    /// shard announces a different id, which is how the registry tells a
    /// rejoin from a reconnect to the same process), `models` is how many
    /// lanes it serves.
    Join { shard_id: u64, models: u32 },
    /// Graceful-drain signal, valid in both directions. Shard → client:
    /// a departure announcement — stop routing new work here; in-flight
    /// requests will still be answered, and the connection stays open
    /// until the client has drained it. Client → shard: a drain
    /// *request* (the fleet autoscaler's retire path) — the shard flips
    /// to leaving and announces `Leave` back on every connection.
    Leave { reason: String },
    /// Health probe (client → shard): `seq` is echoed in the matching
    /// [`Frame::Heartbeat`] so the registry can tell fresh replies from
    /// stale ones.
    HealthProbe { seq: u64 },
    /// Probe reply carrying the shard's load snapshot: requests in flight
    /// across its lanes, sheds since the previous heartbeat on this
    /// connection, and smoothed (EWMA) p50/p99 end-to-end latency in µs.
    /// Floats travel as raw bits like every other f64 on this wire.
    Heartbeat { seq: u64, inflight: u64, shed_delta: u64, p50_us: f64, p99_us: f64 },
    /// Open (or re-open) a stateful streaming session `stream` on the
    /// named model's lane. `window` is the trailing score window in
    /// samples; `0` asks the lane for its configured default. Re-opening
    /// an existing id resets its carried state to zero.
    StreamOpen { stream: u64, model: String, window: u32 },
    /// One telemetry sample for session `stream`: `id` is echoed in the
    /// matching [`Frame::StreamScore`] / [`Frame::Shed`], and the row is
    /// `F` `f32` values (the model's feature width).
    StreamSample { stream: u64, id: u64, model: String, sample: Vec<f32> },
    /// The incremental score after folding `StreamSample { id, .. }` into
    /// session `stream`'s carried state — bit-identical to re-running the
    /// session's full history from zero. `reset` reports that the shard
    /// had lost the session (eviction, restart, failover) and scored this
    /// sample against freshly zeroed state.
    StreamScore { stream: u64, id: u64, score: f64, is_anomaly: bool, reset: bool },
    /// Close session `stream` on the named model's lane and drop its
    /// state. Closing an unknown session is a no-op.
    StreamClose { stream: u64, model: String },
}

/// Decode/IO failure. Every malformed input maps here — the decoder has
/// no panicking paths.
#[derive(Debug)]
pub enum WireError {
    /// Underlying socket/stream error.
    Io(std::io::Error),
    /// The stream ended inside a frame (mid-prefix or mid-payload).
    Truncated,
    /// Length prefix exceeds [`MAX_FRAME_LEN`] (or is zero) — a garbage
    /// or hostile prefix, rejected before any allocation.
    BadLength(usize),
    /// Unknown frame tag byte.
    BadTag(u8),
    /// Payload doesn't decode as the tagged frame (short fields, size
    /// mismatch, bad enum byte, trailing bytes).
    BadPayload(&'static str),
    /// A string field wasn't valid UTF-8.
    BadUtf8,
    /// Handshake version mismatch (reported by the handshake helpers).
    BadVersion { got: u16, want: u16 },
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::Truncated => write!(f, "stream ended inside a frame"),
            WireError::BadLength(n) => {
                write!(f, "frame length {n} outside 1..={MAX_FRAME_LEN}")
            }
            WireError::BadTag(t) => write!(f, "unknown frame tag {t:#04x}"),
            WireError::BadPayload(why) => write!(f, "malformed payload: {why}"),
            WireError::BadUtf8 => write!(f, "string field is not valid UTF-8"),
            WireError::BadVersion { got, want } => {
                write!(f, "wire version mismatch: peer speaks v{got}, this end v{want}")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            WireError::Truncated
        } else {
            WireError::Io(e)
        }
    }
}

// ---- encoding ----------------------------------------------------------

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    assert!(bytes.len() <= u16::MAX as usize, "string field too long for the wire");
    put_u16(buf, bytes.len() as u16);
    buf.extend_from_slice(bytes);
}

impl Frame {
    fn tag(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 0,
            Frame::Submit { .. } => 1,
            Frame::Response { .. } => 2,
            Frame::Shed { .. } => 3,
            Frame::FleetReport { .. } => 4,
            Frame::Join { .. } => 5,
            Frame::Leave { .. } => 6,
            Frame::HealthProbe { .. } => 7,
            Frame::Heartbeat { .. } => 8,
            Frame::StreamOpen { .. } => 9,
            Frame::StreamSample { .. } => 10,
            Frame::StreamScore { .. } => 11,
            Frame::StreamClose { .. } => 12,
        }
    }

    /// Serialize to a complete wire frame (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        if let Frame::Submit { id, model, window } = self {
            return encode_submit(*id, model, window);
        }
        let mut body = Vec::with_capacity(64);
        body.push(self.tag());
        match self {
            Frame::Hello { version } => put_u16(&mut body, *version),
            Frame::Submit { .. } => unreachable!("delegated to encode_submit above"),
            Frame::Response { id, score, is_anomaly, queue_us, service_us, e2e_us } => {
                put_u64(&mut body, *id);
                put_f64(&mut body, *score);
                body.push(u8::from(*is_anomaly));
                put_f64(&mut body, *queue_us);
                put_f64(&mut body, *service_us);
                put_f64(&mut body, *e2e_us);
            }
            Frame::Shed { id, reason } => {
                put_u64(&mut body, *id);
                body.push(reason.to_byte());
            }
            Frame::FleetReport { text } => {
                assert!(text.len() <= u32::MAX as usize);
                put_u32(&mut body, text.len() as u32);
                body.extend_from_slice(text.as_bytes());
            }
            Frame::Join { shard_id, models } => {
                put_u64(&mut body, *shard_id);
                put_u32(&mut body, *models);
            }
            Frame::Leave { reason } => put_str(&mut body, reason),
            Frame::HealthProbe { seq } => put_u64(&mut body, *seq),
            Frame::Heartbeat { seq, inflight, shed_delta, p50_us, p99_us } => {
                put_u64(&mut body, *seq);
                put_u64(&mut body, *inflight);
                put_u64(&mut body, *shed_delta);
                put_f64(&mut body, *p50_us);
                put_f64(&mut body, *p99_us);
            }
            Frame::StreamOpen { stream, model, window } => {
                put_u64(&mut body, *stream);
                put_str(&mut body, model);
                put_u32(&mut body, *window);
            }
            Frame::StreamSample { stream, id, model, sample } => {
                put_u64(&mut body, *stream);
                put_u64(&mut body, *id);
                put_str(&mut body, model);
                put_u32(&mut body, sample.len() as u32);
                for &v in sample {
                    put_u32(&mut body, v.to_bits());
                }
            }
            Frame::StreamScore { stream, id, score, is_anomaly, reset } => {
                put_u64(&mut body, *stream);
                put_u64(&mut body, *id);
                put_f64(&mut body, *score);
                body.push(u8::from(*is_anomaly));
                body.push(u8::from(*reset));
            }
            Frame::StreamClose { stream, model } => {
                put_u64(&mut body, *stream);
                put_str(&mut body, model);
            }
        }
        finish_frame(body)
    }
}

/// Serialize a `Submit` frame directly from borrowed window rows —
/// byte-identical to `Frame::Submit { .. }.encode()`, but the submit hot
/// path ([`crate::net::ShardClient`]) can build it without cloning the
/// window into a `Frame` first.
pub fn encode_submit(id: u64, model: &str, rows: &[Vec<f32>]) -> Vec<u8> {
    let t = rows.len();
    let f = rows.first().map_or(0, Vec::len);
    let mut body = Vec::with_capacity(32 + model.len() + t * f * 4);
    body.push(1u8);
    put_u64(&mut body, id);
    put_str(&mut body, model);
    put_u32(&mut body, t as u32);
    put_u32(&mut body, f as u32);
    for row in rows {
        assert_eq!(row.len(), f, "ragged window rows cannot be framed");
        for &v in row {
            put_u32(&mut body, v.to_bits());
        }
    }
    finish_frame(body)
}

/// Prefix an encoded body (tag + payload) with its length.
fn finish_frame(body: Vec<u8>) -> Vec<u8> {
    debug_assert!(body.len() <= MAX_FRAME_LEN, "encoder produced an oversized frame");
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

// ---- decoding ----------------------------------------------------------

/// Bounds-checked cursor over one frame's payload bytes.
struct Cur<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.off.checked_add(n).ok_or(WireError::BadPayload("length overflow"))?;
        if end > self.buf.len() {
            return Err(WireError::BadPayload("field past end of payload"));
        }
        let s = &self.buf[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String, WireError> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    fn done(&self) -> Result<(), WireError> {
        if self.off == self.buf.len() {
            Ok(())
        } else {
            Err(WireError::BadPayload("trailing bytes after frame"))
        }
    }
}

/// Decode one frame from `tag` + `payload` (the bytes after the length
/// prefix). Rejects anything malformed with a clean [`WireError`].
pub fn decode_frame(tag: u8, payload: &[u8]) -> Result<Frame, WireError> {
    let mut c = Cur { buf: payload, off: 0 };
    let frame = match tag {
        0 => Frame::Hello { version: c.u16()? },
        1 => {
            let id = c.u64()?;
            let model = c.string()?;
            let t = c.u32()? as usize;
            let f = c.u32()? as usize;
            // Zero-width rows would make the sample count 0 for ANY t,
            // letting a ~22-byte frame demand a `t`-row allocation with
            // nothing backing it (t = u32::MAX → a multi-GB reserve and
            // an abort). With f ≥ 1 enforced, t is bounded by the
            // payload length the length-prefix gate already capped.
            if f == 0 && t != 0 {
                return Err(WireError::BadPayload("zero-width window rows"));
            }
            let samples = t.checked_mul(f).ok_or(WireError::BadPayload("window size overflow"))?;
            let need =
                samples.checked_mul(4).ok_or(WireError::BadPayload("window size overflow"))?;
            if need != payload.len() - c.off {
                return Err(WireError::BadPayload("window size disagrees with payload"));
            }
            let mut window = Vec::with_capacity(t);
            for _ in 0..t {
                let mut row = Vec::with_capacity(f);
                for _ in 0..f {
                    row.push(f32::from_bits(c.u32()?));
                }
                window.push(row);
            }
            Frame::Submit { id, model, window }
        }
        2 => Frame::Response {
            id: c.u64()?,
            score: c.f64()?,
            is_anomaly: match c.u8()? {
                0 => false,
                1 => true,
                _ => return Err(WireError::BadPayload("bad bool byte")),
            },
            queue_us: c.f64()?,
            service_us: c.f64()?,
            e2e_us: c.f64()?,
        },
        3 => Frame::Shed { id: c.u64()?, reason: ShedReason::from_byte(c.u8()?)? },
        4 => {
            let n = c.u32()? as usize;
            let bytes = c.take(n)?;
            Frame::FleetReport {
                text: String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)?,
            }
        }
        5 => Frame::Join { shard_id: c.u64()?, models: c.u32()? },
        6 => Frame::Leave { reason: c.string()? },
        7 => Frame::HealthProbe { seq: c.u64()? },
        8 => Frame::Heartbeat {
            seq: c.u64()?,
            inflight: c.u64()?,
            shed_delta: c.u64()?,
            p50_us: c.f64()?,
            p99_us: c.f64()?,
        },
        9 => Frame::StreamOpen { stream: c.u64()?, model: c.string()?, window: c.u32()? },
        10 => {
            let stream = c.u64()?;
            let id = c.u64()?;
            let model = c.string()?;
            let f = c.u32()? as usize;
            // Same allocation guard as Submit: the declared width must
            // agree with the bytes actually present before reserving.
            let need = f.checked_mul(4).ok_or(WireError::BadPayload("sample size overflow"))?;
            if need != payload.len() - c.off {
                return Err(WireError::BadPayload("sample size disagrees with payload"));
            }
            let mut sample = Vec::with_capacity(f);
            for _ in 0..f {
                sample.push(f32::from_bits(c.u32()?));
            }
            Frame::StreamSample { stream, id, model, sample }
        }
        11 => Frame::StreamScore {
            stream: c.u64()?,
            id: c.u64()?,
            score: c.f64()?,
            is_anomaly: match c.u8()? {
                0 => false,
                1 => true,
                _ => return Err(WireError::BadPayload("bad bool byte")),
            },
            reset: match c.u8()? {
                0 => false,
                1 => true,
                _ => return Err(WireError::BadPayload("bad bool byte")),
            },
        },
        12 => Frame::StreamClose { stream: c.u64()?, model: c.string()? },
        other => return Err(WireError::BadTag(other)),
    };
    c.done()?;
    Ok(frame)
}

/// Read one frame from a byte stream. `Ok(None)` is a clean EOF at a
/// frame boundary (the peer closed between frames); an EOF anywhere else
/// is [`WireError::Truncated`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, WireError> {
    let mut prefix = [0u8; 4];
    let mut got = 0usize;
    while got < prefix.len() {
        match r.read(&mut prefix[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(WireError::Truncated),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len == 0 || len > MAX_FRAME_LEN {
        return Err(WireError::BadLength(len));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    decode_frame(body[0], &body[1..]).map(Some)
}

/// Write one frame to a byte stream.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<(), WireError> {
    w.write_all(&frame.encode())?;
    w.flush()?;
    Ok(())
}

/// Run this end's half of the version handshake on a fresh connection:
/// send our [`Frame::Hello`], read the peer's, and refuse a mismatch with
/// [`WireError::BadVersion`]. Symmetric, so both client and server use
/// the same helper (each side writes first, then reads — no deadlock,
/// since a Hello frame is far smaller than any socket buffer).
pub fn handshake(stream: &mut (impl Read + Write)) -> Result<(), WireError> {
    write_frame(stream, &Frame::Hello { version: WIRE_VERSION })?;
    match read_frame(stream)? {
        Some(Frame::Hello { version }) if version == WIRE_VERSION => Ok(()),
        Some(Frame::Hello { version }) => {
            Err(WireError::BadVersion { got: version, want: WIRE_VERSION })
        }
        Some(_) => Err(WireError::BadPayload("peer's first frame was not Hello")),
        None => Err(WireError::Truncated),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn roundtrip(frame: &Frame) -> Frame {
        let bytes = frame.encode();
        let mut cursor = &bytes[..];
        let back = read_frame(&mut cursor).expect("decodes").expect("not EOF");
        assert!(cursor.is_empty(), "decoder must consume the whole frame");
        back
    }

    fn random_frame(rng: &mut Xoshiro256) -> Frame {
        match rng.below(13) {
            0 => Frame::Hello { version: rng.below(u16::MAX as u64 + 1) as u16 },
            1 => {
                let t = rng.below(6) as usize;
                let f = 1 + rng.below(8) as usize;
                let window = (0..t)
                    .map(|_| (0..f).map(|_| rng.uniform(-2.0, 2.0) as f32).collect())
                    .collect();
                let model = format!("LSTM-AE-F{}-D{}", 16 << rng.below(3), rng.below(8));
                Frame::Submit { id: rng.next_u64(), model, window }
            }
            2 => Frame::Response {
                id: rng.next_u64(),
                // Raw bit patterns, including NaN/inf payloads, must
                // survive the wire untouched.
                score: f64::from_bits(rng.next_u64()),
                is_anomaly: rng.next_f64() < 0.5,
                queue_us: rng.uniform(0.0, 1e6),
                service_us: rng.uniform(0.0, 1e6),
                e2e_us: rng.uniform(0.0, 1e6),
            },
            3 => Frame::Shed {
                id: rng.next_u64(),
                reason: [ShedReason::Overloaded, ShedReason::Closed, ShedReason::UnknownModel]
                    [rng.below(3) as usize],
            },
            4 => {
                let n = rng.below(200) as usize;
                let text: String =
                    (0..n).map(|i| char::from(b'a' + ((i as u8) % 26))).collect();
                Frame::FleetReport { text }
            }
            5 => Frame::Join { shard_id: rng.next_u64(), models: rng.below(16) as u32 },
            6 => Frame::Leave {
                reason: ["drain", "restart", ""][rng.below(3) as usize].to_string(),
            },
            7 => Frame::HealthProbe { seq: rng.next_u64() },
            8 => Frame::Heartbeat {
                seq: rng.next_u64(),
                inflight: rng.below(1 << 20),
                shed_delta: rng.below(1 << 20),
                // Raw bit patterns (NaN/inf included) must survive.
                p50_us: f64::from_bits(rng.next_u64()),
                p99_us: f64::from_bits(rng.next_u64()),
            },
            9 => Frame::StreamOpen {
                stream: rng.next_u64(),
                model: format!("LSTM-AE-F{}-D{}", 16 << rng.below(3), rng.below(8)),
                window: rng.below(256) as u32,
            },
            10 => {
                let f = rng.below(9) as usize;
                Frame::StreamSample {
                    stream: rng.next_u64(),
                    id: rng.next_u64(),
                    model: format!("LSTM-AE-F{}-D{}", 16 << rng.below(3), rng.below(8)),
                    sample: (0..f).map(|_| rng.uniform(-2.0, 2.0) as f32).collect(),
                }
            }
            11 => Frame::StreamScore {
                stream: rng.next_u64(),
                id: rng.next_u64(),
                // Raw bit patterns, including NaN/inf, must survive.
                score: f64::from_bits(rng.next_u64()),
                is_anomaly: rng.next_f64() < 0.5,
                reset: rng.next_f64() < 0.5,
            },
            _ => Frame::StreamClose {
                stream: rng.next_u64(),
                model: format!("LSTM-AE-F{}-D{}", 16 << rng.below(3), rng.below(8)),
            },
        }
    }

    /// Frame equality with bitwise float comparison (NaN payloads must
    /// round-trip, and `PartialEq` on f64 would reject them).
    fn frames_bit_equal(a: &Frame, b: &Frame) -> bool {
        match (a, b) {
            (
                Frame::Response { id, score, is_anomaly, queue_us, service_us, e2e_us },
                Frame::Response {
                    id: id2,
                    score: score2,
                    is_anomaly: an2,
                    queue_us: q2,
                    service_us: s2,
                    e2e_us: e2,
                },
            ) => {
                id == id2
                    && score.to_bits() == score2.to_bits()
                    && is_anomaly == an2
                    && queue_us.to_bits() == q2.to_bits()
                    && service_us.to_bits() == s2.to_bits()
                    && e2e_us.to_bits() == e2.to_bits()
            }
            (
                Frame::Heartbeat { seq, inflight, shed_delta, p50_us, p99_us },
                Frame::Heartbeat {
                    seq: seq2,
                    inflight: in2,
                    shed_delta: sd2,
                    p50_us: p50b,
                    p99_us: p99b,
                },
            ) => {
                seq == seq2
                    && inflight == in2
                    && shed_delta == sd2
                    && p50_us.to_bits() == p50b.to_bits()
                    && p99_us.to_bits() == p99b.to_bits()
            }
            (
                Frame::StreamScore { stream, id, score, is_anomaly, reset },
                Frame::StreamScore {
                    stream: st2,
                    id: id2,
                    score: sc2,
                    is_anomaly: an2,
                    reset: rs2,
                },
            ) => {
                stream == st2
                    && id == id2
                    && score.to_bits() == sc2.to_bits()
                    && is_anomaly == an2
                    && reset == rs2
            }
            _ => a == b,
        }
    }

    #[test]
    fn randomized_frames_roundtrip_bit_exactly() {
        let mut rng = Xoshiro256::seeded(0xF0A7);
        for i in 0..500 {
            let frame = random_frame(&mut rng);
            let back = roundtrip(&frame);
            assert!(frames_bit_equal(&frame, &back), "iteration {i}: {frame:?} != {back:?}");
        }
    }

    #[test]
    fn streams_of_frames_decode_in_order() {
        let mut rng = Xoshiro256::seeded(0xBEEF);
        let frames: Vec<Frame> = (0..32).map(|_| random_frame(&mut rng)).collect();
        let mut bytes = Vec::new();
        for f in &frames {
            bytes.extend_from_slice(&f.encode());
        }
        let mut cursor = &bytes[..];
        for want in &frames {
            let got = read_frame(&mut cursor).unwrap().unwrap();
            assert!(frames_bit_equal(want, &got));
        }
        assert!(read_frame(&mut cursor).unwrap().is_none(), "clean EOF at the boundary");
    }

    #[test]
    fn truncated_frames_error_cleanly_at_every_cut() {
        let frame = Frame::Submit {
            id: 7,
            model: "LSTM-AE-F32-D2".into(),
            window: vec![vec![0.5f32; 4]; 3],
        };
        let bytes = frame.encode();
        // Cutting the stream anywhere inside the frame (after byte 0)
        // must yield Truncated/BadPayload — never a panic, never Ok.
        for cut in 1..bytes.len() {
            let mut cursor = &bytes[..cut];
            match read_frame(&mut cursor) {
                Err(WireError::Truncated) | Err(WireError::BadPayload(_)) => {}
                other => panic!("cut at {cut}: want truncation error, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_and_garbage_prefixes_are_rejected_before_allocation() {
        // Length prefix far beyond MAX_FRAME_LEN (e.g. the peer is not
        // speaking this protocol at all): clean BadLength.
        let mut garbage = Vec::new();
        garbage.extend_from_slice(&(u32::MAX).to_le_bytes());
        garbage.extend_from_slice(&[0u8; 64]);
        match read_frame(&mut &garbage[..]) {
            Err(WireError::BadLength(n)) => assert_eq!(n, u32::MAX as usize),
            other => panic!("want BadLength, got {other:?}"),
        }
        // Zero length is equally malformed.
        let zero = 0u32.to_le_bytes();
        assert!(matches!(read_frame(&mut &zero[..]), Err(WireError::BadLength(0))));
        // ASCII noise ("HTTP") decodes as a huge little-endian length.
        let mut http = Vec::from(&b"HTTP/1.1 200 OK\r\n"[..]);
        http.resize(64, 0);
        assert!(matches!(read_frame(&mut &http[..]), Err(WireError::BadLength(_))));
    }

    #[test]
    fn unknown_tags_and_malformed_payloads_are_rejected() {
        assert!(matches!(decode_frame(13, &[]), Err(WireError::BadTag(13))));
        // Hello payload too short.
        assert!(matches!(decode_frame(0, &[1]), Err(WireError::BadPayload(_))));
        // Trailing bytes after a valid Hello.
        assert!(matches!(decode_frame(0, &[1, 0, 99]), Err(WireError::BadPayload(_))));
        // Shed with an unknown reason byte.
        let mut shed = Vec::new();
        shed.extend_from_slice(&7u64.to_le_bytes());
        shed.push(250);
        assert!(matches!(decode_frame(3, &shed), Err(WireError::BadPayload(_))));
        // Submit whose declared window size disagrees with the payload.
        let mut submit = Vec::new();
        submit.extend_from_slice(&1u64.to_le_bytes());
        submit.extend_from_slice(&2u16.to_le_bytes());
        submit.extend_from_slice(b"ab");
        submit.extend_from_slice(&1000u32.to_le_bytes()); // T
        submit.extend_from_slice(&1000u32.to_le_bytes()); // F, but no samples follow
        assert!(matches!(decode_frame(1, &submit), Err(WireError::BadPayload(_))));
        // The zero-width-row hole: T = u32::MAX with F = 0 needs zero
        // sample bytes, so without the guard a ~22-byte frame would
        // demand a multi-gigabyte row allocation (process abort, not an
        // error). Must be a clean rejection.
        let mut zero_f = Vec::new();
        zero_f.extend_from_slice(&1u64.to_le_bytes());
        zero_f.extend_from_slice(&0u16.to_le_bytes()); // empty model name
        zero_f.extend_from_slice(&u32::MAX.to_le_bytes()); // T
        zero_f.extend_from_slice(&0u32.to_le_bytes()); // F
        assert!(matches!(decode_frame(1, &zero_f), Err(WireError::BadPayload(_))));
        // Invalid UTF-8 in a model name.
        let mut bad = Vec::new();
        bad.extend_from_slice(&1u64.to_le_bytes());
        bad.extend_from_slice(&2u16.to_le_bytes());
        bad.extend_from_slice(&[0xFF, 0xFE]);
        bad.extend_from_slice(&0u32.to_le_bytes());
        bad.extend_from_slice(&0u32.to_le_bytes());
        assert!(matches!(decode_frame(1, &bad), Err(WireError::BadUtf8)));
        // Control-plane payloads get the same treatment: short fields and
        // trailing bytes are clean rejections, never panics.
        assert!(matches!(decode_frame(5, &[1, 2, 3]), Err(WireError::BadPayload(_))));
        assert!(matches!(decode_frame(7, &[0; 7]), Err(WireError::BadPayload(_))));
        assert!(matches!(decode_frame(7, &[0; 9]), Err(WireError::BadPayload(_))));
        assert!(matches!(decode_frame(8, &[0; 39]), Err(WireError::BadPayload(_))));
        // Leave with a string length past the payload end.
        let mut leave = Vec::new();
        leave.extend_from_slice(&9u16.to_le_bytes());
        leave.extend_from_slice(b"dr");
        assert!(matches!(decode_frame(6, &leave), Err(WireError::BadPayload(_))));
        // Leave with invalid UTF-8 in the reason.
        let mut bad_leave = Vec::new();
        bad_leave.extend_from_slice(&2u16.to_le_bytes());
        bad_leave.extend_from_slice(&[0xFF, 0xFE]);
        assert!(matches!(decode_frame(6, &bad_leave), Err(WireError::BadUtf8)));
        // Streaming frames: short payloads are clean rejections.
        assert!(matches!(decode_frame(9, &[0; 5]), Err(WireError::BadPayload(_))));
        assert!(matches!(decode_frame(12, &[0; 3]), Err(WireError::BadPayload(_))));
        // StreamSample whose declared width disagrees with the payload.
        let mut sample = Vec::new();
        sample.extend_from_slice(&1u64.to_le_bytes()); // stream
        sample.extend_from_slice(&2u64.to_le_bytes()); // id
        sample.extend_from_slice(&0u16.to_le_bytes()); // empty model name
        sample.extend_from_slice(&1000u32.to_le_bytes()); // F, but no samples
        assert!(matches!(decode_frame(10, &sample), Err(WireError::BadPayload(_))));
        // StreamScore with a non-boolean reset byte.
        let mut score = Vec::new();
        score.extend_from_slice(&1u64.to_le_bytes());
        score.extend_from_slice(&2u64.to_le_bytes());
        score.extend_from_slice(&0u64.to_le_bytes()); // score bits
        score.push(0);
        score.push(7);
        assert!(matches!(decode_frame(11, &score), Err(WireError::BadPayload(_))));
        // Random byte soup across many seeds: errors only, no panics.
        let mut rng = Xoshiro256::seeded(0xD15EA5E);
        for _ in 0..2000 {
            let n = rng.below(40) as usize;
            let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let tag = rng.below(256) as u8;
            let _ = decode_frame(tag, &bytes);
        }
    }

    #[test]
    fn empty_window_submit_roundtrips() {
        let frame = Frame::Submit { id: 0, model: String::new(), window: vec![] };
        assert_eq!(roundtrip(&frame), frame);
    }
}
