//! The client end of the shard fabric: a TCP connection to one
//! [`crate::net::ShardServer`] that implements the same
//! [`Ticket`] surface as a local lane.
//!
//! One reader thread per connection multiplexes every reply — the exact
//! shape of the in-process completion router
//! ([`crate::server::front`]), with the socket standing in for the
//! workers' shared reply channel:
//!
//! ```text
//! caller ── submit_async(model, window) ──► Ticket  (returns immediately)
//!               │ registers slot (id → shared state)
//!               │ writes one Submit frame (writer half, under a lock)
//!               ▼
//!        ┌──────socket──────┐
//!        ▼                  │
//!  [reader thread] ◄── Response{id}/Shed{id} frames
//!    id → slot lookup; resolves the ticket (wait/poll/on_complete all
//!    fire), removes the slot. Connection death poisons every in-flight
//!    slot with Err(Closed) — a caller is never left hanging.
//! ```
//!
//! Remote sheds arrive as `Shed` frames and resolve the ticket to
//! `Err(`[`SubmitError::Overloaded`]`)` — the cross-shard backpressure
//! signal — rather than failing the submit call, because admission
//! happens on the shard, a round-trip away. [`ShardClient::submit_async`]
//! itself only fails when the connection is down (`Err(Closed)`).

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::server::front::TicketShared;
use crate::server::{Response, SubmitError, Ticket};
use crate::workload::Window;

use super::wire::{self, Frame, ShedReason, WireError};

/// One-slot rendezvous for the synchronous fleet-report exchange.
struct ReportSlot {
    text: Mutex<Option<String>>,
    cond: Condvar,
}

/// The shard's post-handshake `Join` announcement: which process
/// instance is on the other end, and how many model lanes it serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JoinInfo {
    /// Identifies the serving *process instance*; a restarted shard
    /// announces a different id.
    pub shard_id: u64,
    /// Lanes the shard serves.
    pub models: u32,
}

/// The latest `Heartbeat` this connection has received (a probe reply;
/// see [`ShardClient::send_probe`]). `seq` echoes the probe that
/// triggered it, so a registry can tell fresh replies from stale ones.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HeartbeatSnapshot {
    pub seq: u64,
    /// Requests in flight across the shard's lanes (its own count, which
    /// includes traffic from other routers — not just ours).
    pub inflight: u64,
    /// Sheds since the previous heartbeat on this connection.
    pub shed_delta: u64,
    /// Shard-side EWMA of p50 e2e latency, µs (0.0 until it completes
    /// anything).
    pub p50_us: f64,
    /// Shard-side EWMA of p99 e2e latency, µs.
    pub p99_us: f64,
}

/// Control-plane state pushed by the shard over this connection,
/// updated by the reader thread and read by the shard registry's health
/// tick.
#[derive(Default)]
struct ControlState {
    joined: Mutex<Option<JoinInfo>>,
    heartbeat: Mutex<Option<HeartbeatSnapshot>>,
    /// Set by a `Leave` frame: the shard asked to drain — stop routing
    /// new work here, let in-flight requests finish.
    draining: AtomicBool,
    /// `StreamScore` frames that arrived with `reset` set: the shard
    /// scored those samples against freshly zeroed session state
    /// (eviction or restart on its side).
    stream_resets: AtomicU64,
}

/// A connection to one shard process, speaking the [`super::wire`]
/// protocol. Submissions return the same [`Ticket`] a local lane issues;
/// completion is delivered by this connection's single reader thread.
pub struct ShardClient {
    addr: String,
    /// Ticket lane name (`shard://<addr>`), shared — no per-submit
    /// allocation.
    lane: Arc<str>,
    /// Writer half of the socket. `None` once the connection is dead or
    /// shut down; writes are serialized by the lock so frames never
    /// interleave.
    writer: Mutex<Option<TcpStream>>,
    /// In-flight submissions: id → ticket slot, resolved by the reader.
    slots: Arc<Mutex<HashMap<u64, Arc<TicketShared>>>>,
    next_id: AtomicU64,
    /// Cleared by the reader thread on EOF/error and by write failures;
    /// a dead client fails every submit fast with `Err(Closed)`.
    alive: Arc<AtomicBool>,
    reader: Mutex<Option<JoinHandle<()>>>,
    report: Arc<ReportSlot>,
    control: Arc<ControlState>,
}

impl ShardClient {
    /// Connect and run the version handshake. Refuses a peer speaking a
    /// different [`super::WIRE_VERSION`] with [`WireError::BadVersion`].
    pub fn connect(addr: &str) -> Result<ShardClient, WireError> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        // Deadline the handshake read: an accepting-but-silent endpoint
        // (wrong port, non-protocol service) must fail fast, not hang
        // connect() forever. Steady-state reads go back to blocking —
        // idle connections are normal there.
        stream.set_read_timeout(Some(Duration::from_secs(5)))?;
        wire::handshake(&mut stream)?;
        stream.set_read_timeout(None)?;
        let read_half = stream.try_clone()?;
        let slots: Arc<Mutex<HashMap<u64, Arc<TicketShared>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let alive = Arc::new(AtomicBool::new(true));
        let report = Arc::new(ReportSlot { text: Mutex::new(None), cond: Condvar::new() });
        let control = Arc::new(ControlState::default());
        let reader = {
            let slots = slots.clone();
            let alive = alive.clone();
            let report = report.clone();
            let control = control.clone();
            std::thread::Builder::new()
                .name(format!("shard-rx:{addr}"))
                .spawn(move || reader_loop(read_half, slots, alive, report, control))
                .expect("spawn shard reader")
        };
        Ok(ShardClient {
            addr: addr.to_string(),
            lane: Arc::from(format!("shard://{addr}")),
            writer: Mutex::new(Some(stream)),
            slots,
            next_id: AtomicU64::new(0),
            alive,
            reader: Mutex::new(Some(reader)),
            report,
            control,
        })
    }

    /// The address this client connected to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Whether the connection is still up. A false here is sticky: a dead
    /// client never comes back (the [`crate::server::ShardRouter`] routes
    /// around it instead).
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    /// Submissions awaiting a remote reply — the load signal the router's
    /// power-of-two-choices pick compares.
    pub fn inflight(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// The shard's `Join` announcement, once the reader has seen it
    /// (arrives right after the handshake, so `None` only in the first
    /// instants of a connection).
    pub fn join_info(&self) -> Option<JoinInfo> {
        *self.control.joined.lock().unwrap()
    }

    /// The latest heartbeat received on this connection, if any.
    pub fn last_heartbeat(&self) -> Option<HeartbeatSnapshot> {
        *self.control.heartbeat.lock().unwrap()
    }

    /// Whether the shard announced a graceful `Leave`: route no new work
    /// here, but let in-flight requests finish — they will be answered.
    pub fn is_draining(&self) -> bool {
        self.control.draining.load(Ordering::Acquire)
    }

    /// Ask the shard to drain and retire: writes a `Leave` frame *to*
    /// the shard (the same tag a shard uses to announce its own
    /// departure — tag 6 is bidirectional). The shard flips to leaving,
    /// re-announces `Leave` on every connection, stops admitting new
    /// work, answers what is in flight, and — when running `fleet serve
    /// --ephemeral` — exits once drained. Fire-and-forget: drain
    /// *completion* is observed through the router's health tick
    /// (Draining → in-flight zero → Dead), not a reply to this call.
    pub fn request_leave(&self, reason: &str) -> Result<(), SubmitError> {
        if !self.is_alive() {
            return Err(SubmitError::Closed);
        }
        if reason.len() > u16::MAX as usize {
            return Err(SubmitError::TooLarge);
        }
        self.write(&Frame::Leave { reason: reason.to_string() })
    }

    /// Send one `HealthProbe { seq }`; the shard answers with a
    /// `Heartbeat` echoing `seq`, which lands in
    /// [`Self::last_heartbeat`]. Fails fast with `Err(Closed)` when the
    /// connection is down — the caller's cue to demote this shard.
    pub fn send_probe(&self, seq: u64) -> Result<(), SubmitError> {
        if !self.is_alive() {
            return Err(SubmitError::Closed);
        }
        self.write(&Frame::HealthProbe { seq })
    }

    /// Submit a window to the remote shard. Returns a [`Ticket`]
    /// immediately; the outcome arrives over the socket:
    ///
    /// - `Ok(Response)` — scored (bit-identical to a local lane);
    /// - `Err(Overloaded)` — the shard's lane shed it (backpressure);
    /// - `Err(UnknownModel)`/`Err(Closed)` — remote rejection, or the
    ///   connection died with the request in flight.
    ///
    /// Fails fast with `Err(Closed)` only when the connection is already
    /// down. Remote tickets are not cancellable
    /// ([`Ticket::cancel`] returns `false`): the queue holding the
    /// request lives in another process.
    ///
    /// Takes the window by reference: the frame is serialized straight
    /// off the borrow, so neither this client nor the
    /// [`crate::server::ShardRouter`] above it ever deep-copies the
    /// `T×F` samples — not even across failover retries.
    pub fn submit_async(&self, model: &str, window: &Window) -> Result<Ticket, SubmitError> {
        if !self.is_alive() {
            return Err(SubmitError::Closed);
        }
        // Pre-flight representability gate: anything the wire cannot
        // carry is rejected per-request, *before* it touches the socket.
        // Without this, the encoded frame would panic the encoder (a
        // model name past the u16 string limit) or trip the server's
        // decoder and take the whole (healthy) connection down (an
        // oversized or zero-width-row window).
        let t = window.data.len();
        let f = window.data.first().map_or(0, Vec::len);
        let need = 1 + 8 + 2 + model.len() + 4 + 4 + t * f * 4;
        if need > wire::MAX_FRAME_LEN
            || model.len() > u16::MAX as usize
            || (f == 0 && t != 0)
            || window.data.iter().any(|row| row.len() != f)
        {
            return Err(SubmitError::TooLarge);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (ticket, shared) = Ticket::raw(id, self.lane.clone());
        self.slots.lock().unwrap().insert(id, shared);
        let bytes = wire::encode_submit(id, model, &window.data);
        if let Err(e) = self.write_bytes(&bytes) {
            // Never issued: retire the slot so nothing waits on it.
            self.slots.lock().unwrap().remove(&id);
            return Err(e);
        }
        // The reader may have died — and poison-drained the slot map —
        // between our liveness check and our insert, leaving this slot
        // behind with nothing to resolve it (a TCP write can still
        // "succeed" into a dead socket's buffer). The slots mutex orders
        // our insert against the drain, so a re-check here closes the
        // hole: if the drain ran first, our slot is still in the map and
        // we retire it; if it ran after, it already poisoned the ticket.
        if !self.is_alive() {
            self.slots.lock().unwrap().remove(&id);
            return Err(SubmitError::Closed);
        }
        Ok(ticket)
    }

    /// Open (or reset) streaming session `stream` on the shard's lane
    /// for `model`. Fire-and-forget on the wire: a failed open surfaces
    /// as a `Shed` on the first sample. `window == 0` asks the lane for
    /// its configured default score window.
    pub fn open_stream(&self, model: &str, stream: u64, window: u32) -> Result<(), SubmitError> {
        if !self.is_alive() {
            return Err(SubmitError::Closed);
        }
        if model.len() > u16::MAX as usize {
            return Err(SubmitError::TooLarge);
        }
        self.write(&Frame::StreamOpen { stream, model: model.to_string(), window })
    }

    /// Feed one sample to streaming session `stream` on the remote
    /// shard. Returns a [`Ticket`] immediately, exactly like
    /// [`Self::submit_async`]; the incremental score arrives as a
    /// `StreamScore` frame (a `reset` flag on it bumps
    /// [`Self::stream_resets`]). Takes the sample by reference so
    /// failover retries in the router never deep-copy it twice.
    pub fn submit_sample(
        &self,
        model: &str,
        stream: u64,
        sample: &[f32],
    ) -> Result<Ticket, SubmitError> {
        if !self.is_alive() {
            return Err(SubmitError::Closed);
        }
        // Same representability pre-flight as submit_async: nothing the
        // wire cannot carry touches the socket.
        let need = 1 + 8 + 8 + 2 + model.len() + 4 + sample.len() * 4;
        if need > wire::MAX_FRAME_LEN || model.len() > u16::MAX as usize {
            return Err(SubmitError::TooLarge);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (ticket, shared) = Ticket::raw(id, self.lane.clone());
        self.slots.lock().unwrap().insert(id, shared);
        let frame = Frame::StreamSample {
            stream,
            id,
            model: model.to_string(),
            sample: sample.to_vec(),
        };
        if let Err(e) = self.write(&frame) {
            self.slots.lock().unwrap().remove(&id);
            return Err(e);
        }
        // Same post-write liveness re-check as submit_async: if the
        // reader died (and poison-drained the map) around our insert,
        // retire the slot so nothing waits forever.
        if !self.is_alive() {
            self.slots.lock().unwrap().remove(&id);
            return Err(SubmitError::Closed);
        }
        Ok(ticket)
    }

    /// Close streaming session `stream` on the shard and drop its state.
    /// Closing an unknown session is a remote no-op.
    pub fn close_stream(&self, model: &str, stream: u64) -> Result<(), SubmitError> {
        if !self.is_alive() {
            return Err(SubmitError::Closed);
        }
        if model.len() > u16::MAX as usize {
            return Err(SubmitError::TooLarge);
        }
        self.write(&Frame::StreamClose { stream, model: model.to_string() })
    }

    /// How many `StreamScore` replies on this connection carried the
    /// `reset` flag — scores computed from freshly zeroed state after
    /// the shard lost the session (eviction or restart).
    pub fn stream_resets(&self) -> u64 {
        self.control.stream_resets.load(Ordering::Relaxed)
    }

    /// Fetch the shard's rolled-up fleet report
    /// ([`crate::server::ModelRegistry::fleet_report`]) over the wire.
    pub fn fleet_report(&self, timeout: Duration) -> Result<String, SubmitError> {
        self.write(&Frame::FleetReport { text: String::new() })?;
        let deadline = Instant::now() + timeout;
        let mut slot = self.report.text.lock().unwrap();
        loop {
            if let Some(text) = slot.take() {
                return Ok(text);
            }
            if !self.is_alive() {
                return Err(SubmitError::Closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(SubmitError::Closed);
            }
            let (g, _) = self.report.cond.wait_timeout(slot, deadline - now).unwrap();
            slot = g;
        }
    }

    fn write(&self, frame: &Frame) -> Result<(), SubmitError> {
        self.write_bytes(&frame.encode())
    }

    fn write_bytes(&self, bytes: &[u8]) -> Result<(), SubmitError> {
        let mut guard = self.writer.lock().unwrap();
        let Some(stream) = guard.as_mut() else {
            return Err(SubmitError::Closed);
        };
        if stream.write_all(bytes).is_err() {
            // Half-dead socket: drop the writer and wake the reader so it
            // poisons every in-flight slot.
            let _ = stream.shutdown(Shutdown::Both);
            *guard = None;
            self.alive.store(false, Ordering::Release);
            return Err(SubmitError::Closed);
        }
        Ok(())
    }

    /// Close the connection and join the reader. In-flight tickets
    /// resolve `Err(Closed)` (the reader's exit drain). Idempotent.
    pub fn shutdown(&self) {
        if let Some(stream) = self.writer.lock().unwrap().take() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        self.alive.store(false, Ordering::Release);
        if let Some(h) = self.reader.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardClient {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn shed_error(reason: ShedReason) -> SubmitError {
    match reason {
        ShedReason::Overloaded => SubmitError::Overloaded,
        ShedReason::Closed => SubmitError::Closed,
        // The shard doesn't echo the name back; the caller holds it.
        ShedReason::UnknownModel => SubmitError::UnknownModel("(remote)".to_string()),
    }
}

fn reader_loop(
    mut stream: TcpStream,
    slots: Arc<Mutex<HashMap<u64, Arc<TicketShared>>>>,
    alive: Arc<AtomicBool>,
    report: Arc<ReportSlot>,
    control: Arc<ControlState>,
) {
    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some(Frame::Response { id, score, is_anomaly, queue_us, service_us, e2e_us })) => {
                let slot = slots.lock().unwrap().remove(&id);
                if let Some(slot) = slot {
                    slot.complete(Ok(Response {
                        id,
                        score,
                        is_anomaly,
                        queue_us,
                        service_us,
                        e2e_us,
                    }));
                }
            }
            Ok(Some(Frame::Shed { id, reason })) => {
                let slot = slots.lock().unwrap().remove(&id);
                if let Some(slot) = slot {
                    slot.complete(Err(shed_error(reason)));
                }
            }
            Ok(Some(Frame::StreamScore { id, score, is_anomaly, reset, .. })) => {
                if reset {
                    control.stream_resets.fetch_add(1, Ordering::Relaxed);
                }
                let slot = slots.lock().unwrap().remove(&id);
                if let Some(slot) = slot {
                    // Stream steps carry no shard-side latency breakdown
                    // on the wire (the frame stays small for the O(1)
                    // path); the timing fields read as zero.
                    slot.complete(Ok(Response {
                        id,
                        score,
                        is_anomaly,
                        queue_us: 0.0,
                        service_us: 0.0,
                        e2e_us: 0.0,
                    }));
                }
            }
            Ok(Some(Frame::FleetReport { text })) => {
                *report.text.lock().unwrap() = Some(text);
                report.cond.notify_all();
            }
            Ok(Some(Frame::Join { shard_id, models })) => {
                *control.joined.lock().unwrap() = Some(JoinInfo { shard_id, models });
            }
            Ok(Some(Frame::Leave { .. })) => {
                // Graceful departure: the connection stays up so in-flight
                // requests drain; the registry stops routing new work.
                control.draining.store(true, Ordering::Release);
            }
            Ok(Some(Frame::Heartbeat { seq, inflight, shed_delta, p50_us, p99_us })) => {
                let mut slot = control.heartbeat.lock().unwrap();
                // Keep the freshest reply by probe sequence — a late
                // reply to an old probe must not overwrite a newer one.
                let fresh = match *slot {
                    Some(h) => seq > h.seq,
                    None => true,
                };
                if fresh {
                    *slot =
                        Some(HeartbeatSnapshot { seq, inflight, shed_delta, p50_us, p99_us });
                }
            }
            // Anything else (clean EOF, truncation, a confused peer)
            // ends the connection.
            Ok(Some(_)) | Ok(None) | Err(_) => break,
        }
    }
    // The connection is gone: fail fast from here on, wake the report
    // waiters, and poison every in-flight ticket so no caller hangs.
    alive.store(false, Ordering::Release);
    let _ = stream.shutdown(Shutdown::Both);
    report.cond.notify_all();
    let orphaned: Vec<Arc<TicketShared>> =
        slots.lock().unwrap().drain().map(|(_, s)| s).collect();
    for slot in orphaned {
        slot.complete(Err(SubmitError::Closed));
    }
}
