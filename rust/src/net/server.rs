//! The shard process: a threaded TCP front over an in-process
//! [`ModelRegistry`], turning [`super::wire`] frames into
//! [`ModelRegistry::submit_async`] calls and routing completions back
//! over the socket.
//!
//! Per connection the server runs exactly two threads — the same
//! one-router-thread discipline as the in-process async front, so a
//! connection carrying thousands of in-flight requests costs two
//! threads, not thousands:
//!
//! ```text
//! [conn reader]  Submit{id} ──► registry.submit_async(model, window)
//!                                 │ Ok(ticket): on_complete moves the
//!                                 │ encoded Response/Shed frame into the
//!                                 │ connection's outbound queue (the
//!                                 │ callback runs on the lane's router
//!                                 │ thread — cheap, just encode + send)
//!                                 │ Err(e): Shed{id} queued directly
//!                                 ▼
//! [conn writer]  drains the outbound queue ──► socket
//! ```
//!
//! Admission stays end-to-end bounded: the lanes' bounded queues shed
//! exactly as they do in-process, and the shed surfaces to the client as
//! a `Shed` frame — [`crate::server::SubmitError::Overloaded`] a hop
//! later. The version handshake refuses mismatched peers before any
//! other frame is parsed.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::server::{ModelRegistry, SubmitError};
use crate::workload::Window;

use super::wire::{self, Frame, ShedReason};

fn shed_reason(e: &SubmitError) -> ShedReason {
    match e {
        SubmitError::Overloaded => ShedReason::Overloaded,
        SubmitError::UnknownModel(_) => ShedReason::UnknownModel,
        // Cancelled and TooLarge can't reach a server-side ticket (one
        // needs Ticket::cancel, the other is a client-side pre-flight);
        // fold them with the teardown-shaped errors for completeness.
        SubmitError::Closed | SubmitError::Cancelled | SubmitError::TooLarge => {
            ShedReason::Closed
        }
    }
}

/// Encoded frames queued per connection for its writer thread. Bounded:
/// a client that submits without reading its socket fills this and gets
/// its connection closed, instead of growing server memory without bound
/// (the shed path takes no lane slot, so this queue is its only bound).
const OUTBOUND_QUEUE_FRAMES: usize = 4096;

/// A live connection: a clone of its socket (so shutdown can unblock the
/// reader) plus the handler thread's join handle. Reaped once the
/// handler finishes, so a long-running shard doesn't accumulate dead
/// fds and handles under connection churn.
type Conn = (TcpStream, JoinHandle<()>);

/// A serving shard: one [`ModelRegistry`] behind a `TcpListener`. Owns
/// the accept loop and every connection's reader/writer thread pair;
/// [`ShardServer::shutdown`] stops the lot (the registry itself belongs
/// to the caller and is not shut down — it may be shared).
pub struct ShardServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Mutex<Option<JoinHandle<()>>>,
    conns: Arc<Mutex<Vec<Conn>>>,
}

impl ShardServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7070"`, port 0 for ephemeral) and
    /// start accepting shard-fabric connections over `registry`.
    pub fn bind(addr: &str, registry: Arc<ModelRegistry>) -> std::io::Result<ShardServer> {
        let listener = TcpListener::bind(addr)?;
        // Nonblocking accept + a short poll keeps shutdown dependency-free
        // (no self-connect tricks); 5 ms of accept latency is noise next
        // to a connection's lifetime.
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<Conn>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name(format!("shard-accept:{addr}"))
                .spawn(move || {
                    accept_loop(listener, registry, stop, conns);
                })
                .expect("spawn accept loop")
        };
        Ok(ShardServer { addr, stop, accept: Mutex::new(Some(accept)), conns })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close every connection, and join all server
    /// threads. In-flight remote requests resolve on their lanes; their
    /// responses are dropped with the closed sockets, and the clients'
    /// reader drains poison the matching tickets with `Err(Closed)` —
    /// exactly the failover signal [`crate::server::ShardRouter`]
    /// re-routes on. Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.lock().unwrap().take() {
            let _ = h.join();
        }
        let mut conns = self.conns.lock().unwrap();
        // Unblock every connection reader first, then join the handlers.
        for (stream, _) in conns.iter() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        for (_, handle) in conns.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Join and drop every connection whose handler already finished, so a
/// long-running shard's fd/handle usage tracks *live* connections, not
/// historical ones.
fn reap_finished(conns: &Mutex<Vec<Conn>>) {
    let mut guard = conns.lock().unwrap();
    let mut live = Vec::with_capacity(guard.len());
    for (stream, handle) in guard.drain(..) {
        if handle.is_finished() {
            let _ = handle.join();
        } else {
            live.push((stream, handle));
        }
    }
    *guard = live;
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<Conn>>>,
) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, peer)) => {
                reap_finished(&conns);
                let _ = stream.set_nodelay(true);
                // The listener is nonblocking; accepted sockets must not
                // inherit that (readers use blocking reads).
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let Ok(clone) = stream.try_clone() else { continue };
                let registry = registry.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("shard-conn:{peer}"))
                    .spawn(move || handle_conn(stream, registry))
                    .expect("spawn connection handler");
                conns.lock().unwrap().push((clone, handle));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                // Transient accept failures are a fact of life on a busy
                // listener (ECONNABORTED from a peer resetting
                // mid-handshake, EMFILE under momentary fd exhaustion).
                // Back off and keep accepting — a permanently broken
                // listener just spins this slow loop until shutdown,
                // which beats silently refusing all future connections
                // while the process looks alive.
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn handle_conn(mut stream: TcpStream, registry: Arc<ModelRegistry>) {
    // Version gate before anything else: a mismatched (or non-protocol)
    // peer is refused — our Hello goes out so the peer can diagnose the
    // mismatch, then the connection closes without parsing another frame.
    // The handshake read is deadlined so a silent peer (a port probe, a
    // client that connected and stalled) cannot park this thread forever;
    // after the handshake the socket returns to blocking reads.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    if wire::handshake(&mut stream).is_err() {
        let _ = stream.shutdown(Shutdown::Both);
        return;
    }
    let _ = stream.set_read_timeout(None);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // Bounded outbound queue: the only per-connection buffer between the
    // lanes and the socket. Overflow means the client is submitting but
    // not reading (the writer is parked on a full TCP buffer); such a
    // connection is killed rather than buffered without bound — the
    // client-side reader then poisons its tickets with Err(Closed).
    let (out_tx, out_rx) = sync_channel::<Vec<u8>>(OUTBOUND_QUEUE_FRAMES);
    // Socket handle shared into completion callbacks so overflow can
    // kill the connection from a lane router thread without blocking it.
    let sock = Arc::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let writer = std::thread::Builder::new()
        .name("shard-tx".to_string())
        .spawn(move || writer_loop(write_half, out_rx))
        .expect("spawn connection writer");

    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some(Frame::Submit { id, model, window })) => {
                let window = Window { data: window, anomaly: None };
                match registry.submit_async(&model, window) {
                    Ok(ticket) => {
                        let otx = out_tx.clone();
                        let sock = sock.clone();
                        // Runs on the lane's completion-router thread:
                        // encode + try_send only — never a blocking send,
                        // which would stall every other completion on the
                        // lane behind one slow connection.
                        ticket.on_complete(move |outcome| {
                            let frame = match outcome {
                                Ok(r) => Frame::Response {
                                    id,
                                    score: r.score,
                                    is_anomaly: r.is_anomaly,
                                    queue_us: r.queue_us,
                                    service_us: r.service_us,
                                    e2e_us: r.e2e_us,
                                },
                                Err(e) => Frame::Shed { id, reason: shed_reason(&e) },
                            };
                            if otx.try_send(frame.encode()).is_err() {
                                // Queue full (or writer gone): the
                                // connection is broken — close it so the
                                // peer's reader fails everything over.
                                let _ = sock.shutdown(Shutdown::Both);
                            }
                        });
                    }
                    Err(e) => {
                        let frame = Frame::Shed { id, reason: shed_reason(&e) };
                        if out_tx.try_send(frame.encode()).is_err() {
                            break;
                        }
                    }
                }
            }
            Ok(Some(Frame::FleetReport { .. })) => {
                let frame = Frame::FleetReport { text: registry.fleet_report() };
                if out_tx.try_send(frame.encode()).is_err() {
                    break;
                }
            }
            // A second Hello, or client-bound frames, are protocol
            // violations; clean EOF and decode errors end the connection
            // the same way.
            Ok(Some(_)) | Ok(None) | Err(_) => break,
        }
    }
    // Let in-flight completions drain: the writer exits once every
    // on_complete clone of out_tx has fired (lanes always resolve
    // accepted tickets) and the channel disconnects.
    drop(out_tx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
}

fn writer_loop(mut stream: TcpStream, rx: Receiver<Vec<u8>>) {
    use std::io::Write;
    while let Ok(buf) = rx.recv() {
        if stream.write_all(&buf).is_err() {
            break;
        }
    }
    // Either every producer is gone (reader exited, completions drained)
    // or the socket died under us; both ways the connection is over —
    // shutting the read half unblocks the reader if it is still parked.
    let _ = stream.shutdown(Shutdown::Both);
}
