//! The shard process: a threaded TCP front over an in-process
//! [`ModelRegistry`], turning [`super::wire`] frames into
//! [`ModelRegistry::submit_async`] calls and routing completions back
//! over the socket.
//!
//! Per connection the server runs exactly two threads — the same
//! one-router-thread discipline as the in-process async front, so a
//! connection carrying thousands of in-flight requests costs two
//! threads, not thousands:
//!
//! ```text
//! [conn reader]  Submit{id} ──► registry.submit_async(model, window)
//!                                 │ Ok(ticket): on_complete moves the
//!                                 │ encoded Response/Shed frame into the
//!                                 │ connection's outbound queue (the
//!                                 │ callback runs on the lane's router
//!                                 │ thread — cheap, just encode + send)
//!                                 │ Err(e): Shed{id} queued directly
//!                                 ▼
//! [conn writer]  drains the outbound queue ──► socket
//! ```
//!
//! Admission stays end-to-end bounded: the lanes' bounded queues shed
//! exactly as they do in-process, and the shed surfaces to the client as
//! a `Shed` frame — [`crate::server::SubmitError::Overloaded`] a hop
//! later. The version handshake refuses mismatched peers before any
//! other frame is parsed.
//!
//! # Control plane
//!
//! Beyond the data plane, every connection speaks the fleet control
//! plane:
//!
//! - right after the handshake the server announces itself with a
//!   `Join` frame (stable per-process `shard_id`, model count), so a
//!   router learns membership without out-of-band configuration;
//! - `HealthProbe { seq }` frames are answered with `Heartbeat` frames
//!   carrying the registry's live load ([`ModelRegistry::fleet_load`]):
//!   in-flight count, shed delta since the previous probe on this
//!   connection, and p50/p99 service-latency EWMAs;
//! - [`ShardServer::announce_leave`] broadcasts a `Leave` frame on every
//!   connection (and to late joiners), telling routers to drain this
//!   shard gracefully: stop routing new work, let in-flight tickets
//!   complete, then close;
//! - a `Leave` frame *received* on a connection is the mirror image — a
//!   drain request from a router (the fleet autoscaler's retire path,
//!   [`crate::server::ShardRouter::retire_shard`]). The shard flips to
//!   leaving exactly as if [`ShardServer::announce_leave`] had been
//!   called locally and re-broadcasts `Leave` to every peer; a process
//!   running `fleet serve --ephemeral` then exits once
//!   [`ShardServer::is_leaving`] is set and its connections drain.
//!
//! # Streaming sessions
//!
//! `StreamOpen`/`StreamSample`/`StreamClose` frames map onto the
//! registry's session surface ([`ModelRegistry::open_stream`] and
//! friends); scores come back as `StreamScore` frames through the same
//! bounded outbound queue. A sample for a session this shard does not
//! know — evicted, or the process restarted and lost its tables — is
//! auto-reopened and scored from freshly zeroed state, with `reset` set
//! in the score frame and the lane's `stream_resets` counter bumped:
//! the state-reset failover semantic routers surface to operators.
//!
//! The listener binds with `SO_REUSEADDR` (on Linux) so a restarted
//! shard can rebind its port immediately instead of waiting out
//! `TIME_WAIT` — a requirement for zero-operator-action rejoin.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::server::{ModelRegistry, SubmitError};
use crate::workload::Window;

use super::wire::{self, Frame, ShedReason};

fn shed_reason(e: &SubmitError) -> ShedReason {
    match e {
        SubmitError::Overloaded => ShedReason::Overloaded,
        SubmitError::UnknownModel(_) => ShedReason::UnknownModel,
        // Cancelled and TooLarge can't reach a server-side ticket (one
        // needs Ticket::cancel, the other is a client-side pre-flight);
        // fold them with the teardown-shaped errors for completeness.
        // UnknownStream lands here only when the auto-reopen retry below
        // also failed — from the client's view the session is gone.
        SubmitError::Closed
        | SubmitError::Cancelled
        | SubmitError::TooLarge
        | SubmitError::UnknownStream(_) => ShedReason::Closed,
    }
}

/// Encoded frames queued per connection for its writer thread. Bounded:
/// a client that submits without reading its socket fills this and gets
/// its connection closed, instead of growing server memory without bound
/// (the shed path takes no lane slot, so this queue is its only bound).
const OUTBOUND_QUEUE_FRAMES: usize = 4096;

/// Smoothing factor for the per-connection p50/p99 latency EWMAs
/// reported in heartbeats. 0.3 tracks a load shift within a few probe
/// ticks without letting one outlier probe swing the routing signal.
const HEARTBEAT_EWMA_ALPHA: f64 = 0.3;

/// Bind a listener with `SO_REUSEADDR` so a restarted shard can rebind
/// its port while the previous process's connections sit in `TIME_WAIT`.
/// Without it a kill→restart cycle fails `EADDRINUSE` for up to a minute
/// — fatal for automatic rejoin. Linux-only (done via direct syscalls:
/// the std listener builder exposes no socket options); elsewhere this
/// falls back to a plain bind.
#[cfg(target_os = "linux")]
mod rebind {
    use std::net::{SocketAddr, TcpListener};
    use std::os::fd::FromRawFd;

    #[repr(C)]
    struct SockaddrIn {
        sin_family: u16,
        sin_port: u16,
        sin_addr: u32,
        sin_zero: [u8; 8],
    }

    const AF_INET: i32 = 2;
    const SOCK_STREAM: i32 = 1;
    const SOCK_CLOEXEC: i32 = 0o2000000;
    const SOL_SOCKET: i32 = 1;
    const SO_REUSEADDR: i32 = 2;

    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(
            fd: i32,
            level: i32,
            name: i32,
            value: *const std::ffi::c_void,
            len: u32,
        ) -> i32;
        fn bind(fd: i32, addr: *const SockaddrIn, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub fn bind_reuseaddr(addr: SocketAddr) -> std::io::Result<TcpListener> {
        let SocketAddr::V4(v4) = addr else {
            // V6 never appears in this fabric's loopback/LAN deployments;
            // keep the raw path narrow and let std handle the rest.
            return TcpListener::bind(addr);
        };
        // SAFETY: plain syscalls over owned values; on every early-return
        // path the fd is closed, on success it is moved into the
        // TcpListener which owns it from then on.
        unsafe {
            let fd = socket(AF_INET, SOCK_STREAM | SOCK_CLOEXEC, 0);
            if fd < 0 {
                return Err(std::io::Error::last_os_error());
            }
            let one: i32 = 1;
            let rc = setsockopt(
                fd,
                SOL_SOCKET,
                SO_REUSEADDR,
                (&one as *const i32).cast(),
                std::mem::size_of::<i32>() as u32,
            );
            if rc < 0 {
                let e = std::io::Error::last_os_error();
                close(fd);
                return Err(e);
            }
            let sa = SockaddrIn {
                sin_family: AF_INET as u16,
                // Both port and address live in network byte order inside
                // sockaddr_in; octets() is already big-endian memory.
                sin_port: v4.port().to_be(),
                sin_addr: u32::from_ne_bytes(v4.ip().octets()),
                sin_zero: [0; 8],
            };
            if bind(fd, &sa, std::mem::size_of::<SockaddrIn>() as u32) < 0 {
                let e = std::io::Error::last_os_error();
                close(fd);
                return Err(e);
            }
            if listen(fd, 128) < 0 {
                let e = std::io::Error::last_os_error();
                close(fd);
                return Err(e);
            }
            Ok(TcpListener::from_raw_fd(fd))
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod rebind {
    use std::net::{SocketAddr, TcpListener};

    pub fn bind_reuseaddr(addr: SocketAddr) -> std::io::Result<TcpListener> {
        TcpListener::bind(addr)
    }
}

/// A process-unique shard identity, minted once per [`ShardServer`].
/// Wall-clock nanos XOR a rotated pid: two shards started the same
/// nanosecond on one host still differ, and a restarted process gets a
/// *new* id — routers use that to tell "same shard came back" (same
/// addr) from "same process never died" (same id).
fn fresh_shard_id() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    nanos ^ u64::from(std::process::id()).rotate_left(32)
}

/// State shared by the accept loop and every connection handler.
struct ServerShared {
    registry: Arc<ModelRegistry>,
    shard_id: u64,
    /// Set by [`ShardServer::announce_leave`]; connections accepted after
    /// the broadcast read this and send `Leave` themselves, so a router
    /// that dials in mid-drain still learns not to route here.
    leaving: AtomicBool,
}

/// A live connection: a clone of its socket (so shutdown can unblock the
/// reader), the handler thread's join handle, and a slot holding the
/// connection's outbound sender while the handler is live — the hook
/// [`ShardServer::announce_leave`] uses to inject `Leave` frames into
/// established connections. Reaped once the handler finishes, so a
/// long-running shard doesn't accumulate dead fds and handles under
/// connection churn.
struct Conn {
    stream: TcpStream,
    handle: JoinHandle<()>,
    out: Arc<Mutex<Option<SyncSender<Vec<u8>>>>>,
}

/// A serving shard: one [`ModelRegistry`] behind a `TcpListener`. Owns
/// the accept loop and every connection's reader/writer thread pair;
/// [`ShardServer::shutdown`] stops the lot (the registry itself belongs
/// to the caller and is not shut down — it may be shared).
pub struct ShardServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    shared: Arc<ServerShared>,
    accept: Mutex<Option<JoinHandle<()>>>,
    conns: Arc<Mutex<Vec<Conn>>>,
}

impl ShardServer {
    /// Bind `addr` (e.g. `"127.0.0.1:7070"`, port 0 for ephemeral) and
    /// start accepting shard-fabric connections over `registry`. The
    /// socket is bound with `SO_REUSEADDR` so a restarted shard rebinds
    /// its old port immediately.
    pub fn bind(addr: &str, registry: Arc<ModelRegistry>) -> std::io::Result<ShardServer> {
        let resolved = addr.to_socket_addrs()?.next().ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidInput, "address resolved to nothing")
        })?;
        let listener = rebind::bind_reuseaddr(resolved)?;
        // Nonblocking accept + a short poll keeps shutdown dependency-free
        // (no self-connect tricks); 5 ms of accept latency is noise next
        // to a connection's lifetime.
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(ServerShared {
            registry,
            shard_id: fresh_shard_id(),
            leaving: AtomicBool::new(false),
        });
        let conns: Arc<Mutex<Vec<Conn>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            let shared = shared.clone();
            std::thread::Builder::new()
                .name(format!("shard-accept:{addr}"))
                .spawn(move || {
                    accept_loop(listener, shared, stop, conns);
                })
                .expect("spawn accept loop")
        };
        Ok(ShardServer { addr, stop, shared, accept: Mutex::new(Some(accept)), conns })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// This process's shard identity, as announced in `Join` frames.
    pub fn shard_id(&self) -> u64 {
        self.shared.shard_id
    }

    /// Broadcast `Leave` on every live connection (and mark the server
    /// so later connections get one too): routers stop sending new work
    /// here, in-flight tickets complete normally, and the operator can
    /// [`ShardServer::shutdown`] once the fleet has drained. Idempotent;
    /// does not itself close anything.
    pub fn announce_leave(&self) {
        self.shared.leaving.store(true, Ordering::Release);
        broadcast_leave(&self.conns);
    }

    /// Whether a drain has been requested — by a local
    /// [`ShardServer::announce_leave`] call or by a `Leave` frame from a
    /// router (the fleet autoscaler's retire signal). An ephemeral shard
    /// polls this to know when to begin its exit.
    pub fn is_leaving(&self) -> bool {
        self.shared.leaving.load(Ordering::Acquire)
    }

    /// Fabric connections whose handler threads are still running. An
    /// ephemeral shard exits once it is leaving *and* this reaches zero
    /// — every router has observed the drain and hung up.
    pub fn live_connections(&self) -> usize {
        self.conns.lock().unwrap().iter().filter(|c| !c.handle.is_finished()).count()
    }

    /// Stop accepting, close every connection, and join all server
    /// threads. In-flight remote requests resolve on their lanes; their
    /// responses are dropped with the closed sockets, and the clients'
    /// reader drains poison the matching tickets with `Err(Closed)` —
    /// exactly the failover signal [`crate::server::ShardRouter`]
    /// re-routes on. Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.lock().unwrap().take() {
            let _ = h.join();
        }
        // Take the list, then join *outside* the lock: a handler
        // mid-way through a Leave re-broadcast needs this same lock to
        // finish, so joining under it would deadlock the shutdown.
        let drained: Vec<Conn> = {
            let mut conns = self.conns.lock().unwrap();
            // Unblock every connection reader first, then join.
            for conn in conns.iter() {
                let _ = conn.stream.shutdown(Shutdown::Both);
            }
            conns.drain(..).collect()
        };
        for conn in drained {
            let _ = conn.handle.join();
        }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Queue one `Leave` frame on every live connection's outbound queue.
/// `try_send`: a connection too backed up to take one control frame is
/// already being killed by the overflow path; never block the caller.
fn broadcast_leave(conns: &Mutex<Vec<Conn>>) {
    let frame = Frame::Leave { reason: "drain".to_string() }.encode();
    let conns = conns.lock().unwrap();
    for conn in conns.iter() {
        if let Some(tx) = conn.out.lock().unwrap().as_ref() {
            let _ = tx.try_send(frame.clone());
        }
    }
}

/// Join and drop every connection whose handler already finished, so a
/// long-running shard's fd/handle usage tracks *live* connections, not
/// historical ones.
fn reap_finished(conns: &Mutex<Vec<Conn>>) {
    let mut guard = conns.lock().unwrap();
    let mut live = Vec::with_capacity(guard.len());
    for conn in guard.drain(..) {
        if conn.handle.is_finished() {
            let _ = conn.handle.join();
        } else {
            live.push(conn);
        }
    }
    *guard = live;
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<ServerShared>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<Conn>>>,
) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, peer)) => {
                reap_finished(&conns);
                let _ = stream.set_nodelay(true);
                // The listener is nonblocking; accepted sockets must not
                // inherit that (readers use blocking reads).
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let Ok(clone) = stream.try_clone() else { continue };
                let out: Arc<Mutex<Option<SyncSender<Vec<u8>>>>> = Arc::new(Mutex::new(None));
                let shared = shared.clone();
                let handle = {
                    let out = out.clone();
                    let conns = conns.clone();
                    std::thread::Builder::new()
                        .name(format!("shard-conn:{peer}"))
                        .spawn(move || handle_conn(stream, shared, out, conns))
                        .expect("spawn connection handler")
                };
                conns.lock().unwrap().push(Conn { stream: clone, handle, out });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                // Transient accept failures are a fact of life on a busy
                // listener (ECONNABORTED from a peer resetting
                // mid-handshake, EMFILE under momentary fd exhaustion).
                // Back off and keep accepting — a permanently broken
                // listener just spins this slow loop until shutdown,
                // which beats silently refusing all future connections
                // while the process looks alive.
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Per-connection heartbeat state: the shed counter snapshot behind the
/// reported deltas, and the latency EWMAs. Per *connection*, not per
/// shard: each router smooths against its own probe cadence.
struct HbState {
    last_shed: u64,
    p50: Option<f64>,
    p99: Option<f64>,
}

impl HbState {
    fn new() -> HbState {
        HbState { last_shed: 0, p50: None, p99: None }
    }

    /// Fold a fresh registry sample into the EWMAs (first sample seeds).
    fn observe(&mut self, p50_us: f64, p99_us: f64) -> (f64, f64) {
        let fold = |prev: Option<f64>, x: f64| match prev {
            Some(p) => p + HEARTBEAT_EWMA_ALPHA * (x - p),
            None => x,
        };
        let p50 = fold(self.p50, p50_us);
        let p99 = fold(self.p99, p99_us);
        self.p50 = Some(p50);
        self.p99 = Some(p99);
        (p50, p99)
    }
}

fn handle_conn(
    mut stream: TcpStream,
    shared: Arc<ServerShared>,
    out_slot: Arc<Mutex<Option<SyncSender<Vec<u8>>>>>,
    conns: Arc<Mutex<Vec<Conn>>>,
) {
    use std::io::Write;
    // Version gate before anything else: a mismatched (or non-protocol)
    // peer is refused — our Hello goes out so the peer can diagnose the
    // mismatch, then the connection closes without parsing another frame.
    // The handshake read is deadlined so a silent peer (a port probe, a
    // client that connected and stalled) cannot park this thread forever;
    // after the handshake the socket returns to blocking reads.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    if wire::handshake(&mut stream).is_err() {
        let _ = stream.shutdown(Shutdown::Both);
        return;
    }
    let _ = stream.set_read_timeout(None);
    // Announce membership before any data-plane traffic. Written directly
    // on the stream — the writer thread doesn't exist yet, so there's no
    // interleaving hazard — making Join the first post-handshake frame a
    // router ever sees from a shard.
    let join = Frame::Join {
        shard_id: shared.shard_id,
        models: shared.registry.len() as u32,
    };
    if stream.write_all(&join.encode()).is_err() {
        let _ = stream.shutdown(Shutdown::Both);
        return;
    }
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // Bounded outbound queue: the only per-connection buffer between the
    // lanes and the socket. Overflow means the client is submitting but
    // not reading (the writer is parked on a full TCP buffer); such a
    // connection is killed rather than buffered without bound — the
    // client-side reader then poisons its tickets with Err(Closed).
    let (out_tx, out_rx) = sync_channel::<Vec<u8>>(OUTBOUND_QUEUE_FRAMES);
    *out_slot.lock().unwrap() = Some(out_tx.clone());
    // Socket handle shared into completion callbacks so overflow can
    // kill the connection from a lane router thread without blocking it.
    let sock = Arc::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let writer = std::thread::Builder::new()
        .name("shard-tx".to_string())
        .spawn(move || writer_loop(write_half, out_rx))
        .expect("spawn connection writer");
    // A connection dialed mid-drain missed the broadcast; tell it now.
    if shared.leaving.load(Ordering::Acquire) {
        let _ = out_tx.try_send(Frame::Leave { reason: "drain".to_string() }.encode());
    }
    let mut hb = HbState::new();

    loop {
        match wire::read_frame(&mut stream) {
            Ok(Some(Frame::Submit { id, model, window })) => {
                let window = Window { data: window, anomaly: None };
                match shared.registry.submit_async(&model, window) {
                    Ok(ticket) => {
                        let otx = out_tx.clone();
                        let sock = sock.clone();
                        // Runs on the lane's completion-router thread:
                        // encode + try_send only — never a blocking send,
                        // which would stall every other completion on the
                        // lane behind one slow connection.
                        ticket.on_complete(move |outcome| {
                            let frame = match outcome {
                                Ok(r) => Frame::Response {
                                    id,
                                    score: r.score,
                                    is_anomaly: r.is_anomaly,
                                    queue_us: r.queue_us,
                                    service_us: r.service_us,
                                    e2e_us: r.e2e_us,
                                },
                                Err(e) => Frame::Shed { id, reason: shed_reason(&e) },
                            };
                            if otx.try_send(frame.encode()).is_err() {
                                // Queue full (or writer gone): the
                                // connection is broken — close it so the
                                // peer's reader fails everything over.
                                let _ = sock.shutdown(Shutdown::Both);
                            }
                        });
                    }
                    Err(e) => {
                        let frame = Frame::Shed { id, reason: shed_reason(&e) };
                        if out_tx.try_send(frame.encode()).is_err() {
                            break;
                        }
                    }
                }
            }
            Ok(Some(Frame::StreamOpen { stream, model, window })) => {
                // Best-effort: an open that fails (unknown model, lane
                // without session support) surfaces on the first sample
                // as a Shed — opens themselves have no reply frame.
                let _ = shared.registry.open_stream(&model, stream, window as usize);
            }
            Ok(Some(Frame::StreamSample { stream, id, model, sample })) => {
                // Unknown session (evicted, or this shard restarted and
                // lost its table): re-open at the lane's default window
                // and retry once, reporting `reset` so the client knows
                // this score came from freshly zeroed state.
                let mut reset = false;
                let submitted = match shared.registry.submit_sample(&model, stream, sample.clone())
                {
                    Err(SubmitError::UnknownStream(_)) => {
                        reset = true;
                        shared
                            .registry
                            .open_stream(&model, stream, 0)
                            .and_then(|()| shared.registry.submit_sample(&model, stream, sample))
                    }
                    other => other,
                };
                match submitted {
                    Ok(ticket) => {
                        if reset {
                            if let Some(lane) = shared.registry.lane(&model) {
                                lane.metrics().on_stream_resets(1);
                            }
                        }
                        let otx = out_tx.clone();
                        let sock = sock.clone();
                        ticket.on_complete(move |outcome| {
                            let frame = match outcome {
                                Ok(r) => Frame::StreamScore {
                                    stream,
                                    id,
                                    score: r.score,
                                    is_anomaly: r.is_anomaly,
                                    reset,
                                },
                                Err(e) => Frame::Shed { id, reason: shed_reason(&e) },
                            };
                            if otx.try_send(frame.encode()).is_err() {
                                let _ = sock.shutdown(Shutdown::Both);
                            }
                        });
                    }
                    Err(e) => {
                        let frame = Frame::Shed { id, reason: shed_reason(&e) };
                        if out_tx.try_send(frame.encode()).is_err() {
                            break;
                        }
                    }
                }
            }
            Ok(Some(Frame::StreamClose { stream, model })) => {
                shared.registry.close_stream(&model, stream);
            }
            Ok(Some(Frame::HealthProbe { seq })) => {
                let load = shared.registry.fleet_load();
                let shed_delta = load.shed.saturating_sub(hb.last_shed);
                hb.last_shed = load.shed;
                let (p50_us, p99_us) = hb.observe(load.p50_us, load.p99_us);
                let frame = Frame::Heartbeat {
                    seq,
                    inflight: load.inflight,
                    shed_delta,
                    p50_us,
                    p99_us,
                };
                if out_tx.try_send(frame.encode()).is_err() {
                    break;
                }
            }
            Ok(Some(Frame::FleetReport { .. })) => {
                let frame = Frame::FleetReport { text: shared.registry.fleet_report() };
                if out_tx.try_send(frame.encode()).is_err() {
                    break;
                }
            }
            Ok(Some(Frame::Leave { .. })) => {
                // A drain request from a router (tag 6 is bidirectional):
                // behave exactly as if announce_leave had been called
                // locally — flip to leaving and re-broadcast on every
                // connection, this one included, so every router (the
                // requester too) observes the drain through the same
                // Leave-frame path. An ephemeral shard then exits once
                // its connections wind down.
                shared.leaving.store(true, Ordering::Release);
                broadcast_leave(&conns);
            }
            // A second Hello, or client-bound frames, are protocol
            // violations; clean EOF and decode errors end the connection
            // the same way.
            Ok(Some(_)) | Ok(None) | Err(_) => break,
        }
    }
    // Unhook from announce_leave before tearing down, so the broadcast
    // never lands on a sender whose writer is gone.
    *out_slot.lock().unwrap() = None;
    // Let in-flight completions drain: the writer exits once every
    // on_complete clone of out_tx has fired (lanes always resolve
    // accepted tickets) and the channel disconnects.
    drop(out_tx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
}

fn writer_loop(mut stream: TcpStream, rx: Receiver<Vec<u8>>) {
    use std::io::Write;
    while let Ok(buf) = rx.recv() {
        if stream.write_all(&buf).is_err() {
            break;
        }
    }
    // Either every producer is gone (reader exited, completions drained)
    // or the socket died under us; both ways the connection is over —
    // shutting the read half unblocks the reader if it is still parked.
    let _ = stream.shutdown(Shutdown::Both);
}
