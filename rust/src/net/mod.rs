//! The network shard fabric: the serving stack's `submit(model, window)`
//! surface stretched across processes and hosts.
//!
//! PR 2–4 built the in-process fabric (lanes → replica pools → async
//! tickets); this module is the next scale step the ROADMAP names —
//! sharding lanes across processes behind the *same* submission surface,
//! with [`crate::server::SubmitError::Overloaded`] reused as the
//! cross-shard backpressure signal:
//!
//! ```text
//!  client process                         shard process (one per host)
//! ┌───────────────────────┐   Submit    ┌────────────────────────────┐
//! │ ShardRouter           │ ──frames──► │ ShardServer (TcpListener)  │
//! │  static model map     │             │  conn reader ─ submit_async│
//! │  + power-of-two picks │ ◄─frames──  │  ticket.on_complete ──►    │
//! │  Ticket (same surface)│  Response/  │  conn writer (one thread)  │
//! └───────────────────────┘  Shed       │  ModelRegistry lanes …     │
//!                                       └────────────────────────────┘
//! ```
//!
//! - [`wire`] — the versioned, length-prefixed frame protocol: the data
//!   plane (`Hello`/`Submit`/`Response`/`Shed`/`FleetReport`) plus the
//!   v2 control plane (`Join`/`Leave`/`HealthProbe`/`Heartbeat`) behind
//!   the fleet's self-healing membership; every malformed byte stream
//!   decodes to a clean error, never a panic.
//! - [`ShardServer`] — a threaded `std::net::TcpListener` front over an
//!   in-process [`crate::server::ModelRegistry`]: each connection gets a
//!   reader thread that drains `Submit` frames into
//!   [`crate::server::ModelRegistry::submit_async`] and one writer
//!   thread that serializes completions back — the same
//!   one-router-thread pattern the async front uses in-process.
//! - [`ShardClient`] — the other end of the socket, implementing the
//!   same [`crate::server::Ticket`] surface: `wait`/`poll`/`on_complete`
//!   work transparently whether the lane is local or remote, and remote
//!   scores stay **bit-identical** (f64 bits travel raw).
//!
//! [`crate::server::ShardRouter`] composes N [`ShardClient`]s into one
//! fleet-wide submission surface with failover; `fleet serve` /
//! `fleet connect` in the CLI play the two roles from one binary. All of
//! it is `std` + the vendored shims — no tokio, no registry deps.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{HeartbeatSnapshot, JoinInfo, ShardClient};
pub use server::ShardServer;
pub use wire::{Frame, ShedReason, WireError, MAX_FRAME_LEN, WIRE_VERSION};
