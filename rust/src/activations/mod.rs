//! Piecewise-Linear (PWL) approximations of sigmoid and tanh, matching the
//! paper's FPGA implementation (§4.1: "Piecewise Linear Approximations for
//! sigmoid and tanh").
//!
//! Scheme (mirrored bit-for-bit in grid layout by
//! `python/compile/kernels/quant.py`):
//! - uniform breakpoints over [-8, 8], `SEGMENTS` segments (default 128,
//!   width 0.125 — a power of two so the index computation is a shift on
//!   the FPGA);
//! - node values `y_k = f(x_k)` quantized to Q8.24;
//! - linear interpolation between nodes;
//! - hard saturation outside the range (σ→{0,1}, tanh→{−1,1} — at |8| the
//!   true functions are within 3.4e-4 of the limits, below the PWL error).
//!
//! Maximum absolute error vs the exact function is ~f''·w²/8: ≈1.2e-4 for
//! sigmoid, ≈1.5e-3 for tanh at width 0.125 (verified by tests below).

use crate::fixed::Q8_24;

/// PWL input range lower bound.
pub const PWL_LO: f64 = -8.0;
/// PWL input range upper bound.
pub const PWL_HI: f64 = 8.0;
/// Default number of linear segments.
pub const SEGMENTS: usize = 128;

/// Which function a table approximates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActKind {
    Sigmoid,
    Tanh,
}

impl ActKind {
    pub fn exact(self, x: f64) -> f64 {
        match self {
            ActKind::Sigmoid => 1.0 / (1.0 + (-x).exp()),
            ActKind::Tanh => x.tanh(),
        }
    }

    fn sat_lo(self) -> f64 {
        match self {
            ActKind::Sigmoid => 0.0,
            ActKind::Tanh => -1.0,
        }
    }

    fn sat_hi(self) -> f64 {
        1.0
    }
}

/// A PWL table: node values quantized to Q8.24.
#[derive(Clone, Debug)]
pub struct Pwl {
    pub kind: ActKind,
    pub segments: usize,
    /// segments + 1 node values on the Q8.24 grid.
    nodes: Vec<Q8_24>,
    lo: f64,
    inv_width: f64,
    sat_lo: Q8_24,
    sat_hi: Q8_24,
    /// Cached quantized range bounds (hot path: one compare each).
    lo_q: Q8_24,
    hi_q: Q8_24,
    /// `Some(s)` when `pos = dx << s` (segments a power of two with the
    /// 16-wide range), else the f64 fallback is used.
    pos_shift: Option<u32>,
}

impl Pwl {
    pub fn new(kind: ActKind, segments: usize) -> Pwl {
        assert!(segments >= 2);
        let lo = PWL_LO;
        let width = (PWL_HI - PWL_LO) / segments as f64;
        let nodes = (0..=segments)
            .map(|k| Q8_24::from_f64(kind.exact(lo + k as f64 * width)))
            .collect();
        // pos = dx · segments / 16: a pure left shift when segments is a
        // power of two ≥ 16 (default 128 ⇒ shift 3).
        let pos_shift = if segments.is_power_of_two() && segments >= 16 {
            Some((segments / 16).trailing_zeros())
        } else {
            None
        };
        Pwl {
            kind,
            segments,
            nodes,
            lo,
            inv_width: 1.0 / width,
            sat_lo: Q8_24::from_f64(kind.sat_lo()),
            sat_hi: Q8_24::from_f64(kind.sat_hi()),
            lo_q: Q8_24::from_f64(lo),
            hi_q: Q8_24::from_f64(PWL_HI),
            pos_shift,
        }
    }

    pub fn sigmoid() -> Pwl {
        Pwl::new(ActKind::Sigmoid, SEGMENTS)
    }

    pub fn tanh() -> Pwl {
        Pwl::new(ActKind::Tanh, SEGMENTS)
    }

    /// Evaluate in f64 on the quantized node table (reference semantics —
    /// what the JAX quantized path computes, modulo f32 rounding).
    pub fn eval_f64(&self, x: f64) -> f64 {
        if x <= self.lo {
            return self.sat_lo.to_f64();
        }
        if x >= PWL_HI {
            return self.sat_hi.to_f64();
        }
        let pos = (x - self.lo) * self.inv_width;
        let k = (pos as usize).min(self.segments - 1);
        let t = pos - k as f64;
        let y0 = self.nodes[k].to_f64();
        let y1 = self.nodes[k + 1].to_f64();
        y0 + (y1 - y0) * t
    }

    /// Evaluate in Q8.24 — the golden-model datapath. Index arithmetic uses
    /// the raw integer directly: with width = 2⁻³ · 2⁰ = 0.125 = 2^(24−3−…)
    /// the segment index is a shift, as on the FPGA.
    #[inline]
    pub fn eval_q(&self, x: Q8_24) -> Q8_24 {
        if x.0 <= self.lo_q.0 {
            return self.sat_lo;
        }
        if x.0 >= self.hi_q.0 {
            return self.sat_hi;
        }
        // pos = (x - lo) / width, in raw units. width = 16/segments is a
        // power of two for the default tables, so pos is a left shift;
        // non-power-of-two segment counts take the f64 fallback.
        let dx = (x.0 as i64) - (self.lo_q.0 as i64); // ≥ 0, scale 2^24
        let (k, t_raw) = match self.pos_shift {
            Some(s) => {
                let pos = dx << s; // raw pos, scale 2^24 ⇒ k = pos >> 24
                let k = (pos >> 24) as usize;
                let t_raw = (pos & ((1 << 24) - 1)) as i32; // frac, Q0.24
                (k.min(self.segments - 1), Q8_24(t_raw))
            }
            None => {
                let pos = (dx as f64 / crate::fixed::SCALE) * self.inv_width;
                let k = (pos as usize).min(self.segments - 1);
                (k, Q8_24::from_f64(pos - k as f64))
            }
        };
        let y0 = self.nodes[k];
        let y1 = self.nodes[k + 1];
        y0.add(y1.sub(y0).mul(t_raw))
    }

    /// Maximum absolute error vs the exact function over a dense grid
    /// (used by tests and the design-space example).
    pub fn max_error(&self, samples: usize) -> f64 {
        (0..=samples)
            .map(|i| {
                let x = PWL_LO - 1.0 + (PWL_HI - PWL_LO + 2.0) * i as f64 / samples as f64;
                let approx = self.eval_f64(x);
                let exact = match self.kind {
                    // Outside the range the saturated value is the reference.
                    _ if x <= PWL_LO => self.sat_lo.to_f64(),
                    _ if x >= PWL_HI => self.sat_hi.to_f64(),
                    k => k.exact(x),
                };
                (approx - exact).abs()
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::props;

    #[test]
    fn sigmoid_error_bound() {
        let p = Pwl::sigmoid();
        let err = p.max_error(100_000);
        assert!(err < 4e-4, "sigmoid PWL max err {err}");
    }

    #[test]
    fn tanh_error_bound() {
        let p = Pwl::tanh();
        let err = p.max_error(100_000);
        assert!(err < 2e-3, "tanh PWL max err {err}");
    }

    #[test]
    fn q_path_matches_f64_path() {
        let ps = [Pwl::sigmoid(), Pwl::tanh()];
        props("pwl_q_vs_f64", 2048, |g| {
            let p = g.choose(&ps);
            let x = g.f64_in(-10.0, 10.0);
            let xq = Q8_24::from_f64(x);
            let yq = p.eval_q(xq).to_f64();
            let yf = p.eval_f64(xq.to_f64());
            // One rounding of the interp product + one of the node values.
            assert!((yq - yf).abs() < 3.0 / crate::fixed::SCALE, "x={x} yq={yq} yf={yf}");
        });
    }

    #[test]
    fn saturation() {
        let s = Pwl::sigmoid();
        assert_eq!(s.eval_q(Q8_24::from_f64(-20.0)), Q8_24::from_f64(0.0));
        assert_eq!(s.eval_q(Q8_24::from_f64(20.0)), Q8_24::from_f64(1.0));
        let t = Pwl::tanh();
        assert_eq!(t.eval_q(Q8_24::from_f64(-20.0)), Q8_24::from_f64(-1.0));
        assert_eq!(t.eval_q(Q8_24::from_f64(20.0)), Q8_24::from_f64(1.0));
    }

    #[test]
    fn monotone_nondecreasing() {
        let ps = [Pwl::sigmoid(), Pwl::tanh()];
        for p in &ps {
            let mut prev = f64::NEG_INFINITY;
            for i in 0..4000 {
                let x = -10.0 + i as f64 * 0.005;
                let y = p.eval_f64(x);
                assert!(y >= prev - 1e-12, "{:?} not monotone at {x}", p.kind);
                prev = y;
            }
        }
    }

    #[test]
    fn odd_symmetry_of_tanh_nodes() {
        // tanh(-x) = -tanh(x) holds on the node grid up to quantization.
        let p = Pwl::tanh();
        props("tanh_odd", 512, |g| {
            let x = g.f64_in(0.0, 8.0);
            let xq = Q8_24::from_f64(x);
            let pos = p.eval_q(xq).to_f64();
            let neg = p.eval_q(Q8_24::from_f64(-xq.to_f64())).to_f64();
            assert!((pos + neg).abs() < 4.0 / crate::fixed::SCALE, "x={x} pos={pos} neg={neg}");
        });
    }

    #[test]
    fn segment_count_convergence() {
        // Error shrinks ~quadratically with segment count.
        let e32 = Pwl::new(ActKind::Tanh, 32).max_error(20_000);
        let e128 = Pwl::new(ActKind::Tanh, 128).max_error(20_000);
        assert!(e32 / e128 > 8.0, "e32={e32} e128={e128}");
    }
}
