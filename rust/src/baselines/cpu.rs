//! Measured CPU baseline: the LSTM-AE artifact executed through PJRT
//! (XLA-CPU) on this machine — the honest sequential-software comparator
//! for the simulated accelerator (paper §4.2's CPU column, with XLA-CPU
//! on local silicon substituting for PyTorch-JIT on a Xeon Gold 5218R;
//! see DESIGN.md §1).

use anyhow::Result;
use std::time::Instant;

use crate::runtime::Runtime;
use crate::util::rng::Xoshiro256;
use crate::util::stats::Summary;

/// A latency measurement of one `(model, T)` artifact.
#[derive(Clone, Debug)]
pub struct CpuMeasurement {
    pub model: String,
    pub t: usize,
    /// Per-inference wall latency (ms) summary.
    pub latency_ms: Summary,
    pub reps: usize,
}

/// Measure mean inference latency over `reps` runs (after `warmup`),
/// mirroring the paper's "average latency over 1000 inferences".
pub fn measure(
    rt: &Runtime,
    model: &str,
    t: usize,
    warmup: usize,
    reps: usize,
) -> Result<CpuMeasurement> {
    let entry = rt
        .manifest()
        .find(model)
        .ok_or_else(|| anyhow::anyhow!("model {model:?} not in manifest"))?;
    let f = entry.features;
    let name = entry.name.clone();
    let mut rng = Xoshiro256::seeded(0xBA5E11);
    let x: Vec<f32> = (0..t * f).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    // Compile outside the timed region (the paper's JIT baselines are
    // likewise timed post-warmup).
    let _ = rt.infer(&name, t, &x)?;
    for _ in 0..warmup {
        let _ = rt.infer(&name, t, &x)?;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let start = Instant::now();
        let out = rt.infer(&name, t, &x)?;
        samples.push(start.elapsed().as_secs_f64() * 1e3);
        std::hint::black_box(out);
    }
    Ok(CpuMeasurement { model: name, t, latency_ms: Summary::of(&samples), reps })
}

/// Quick power estimate for the measured CPU: we cannot meter wall power
/// here, so energy columns for the *measured* baseline use the paper's
/// CPU band (documented substitution); the calibrated model covers the
/// paper's own platform.
pub fn assumed_power_w() -> f64 {
    crate::report::paper_data::PAPER_CPU_POWER_W
}
