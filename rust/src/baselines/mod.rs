//! CPU and GPU baselines (paper §4.2 compares against PyTorch-JIT on a
//! Xeon Gold 5218R and an NVIDIA V100).
//!
//! Two kinds of baseline, per the substitution table in DESIGN.md §1:
//!
//! - [`cpu`] — a **measured** sequential-software baseline: the same
//!   LSTM-AE, AOT-lowered by JAX, executed on *this machine's* CPU
//!   through PJRT (XLA-CPU). Real silicon, real memory hierarchy, honest
//!   wall-clock.
//! - [`calibrated`] — **analytical** models of the paper's own platforms,
//!   least-squares fitted to the 24 published latency cells per platform
//!   (`lat = a + b·N + (c + d·w)·N·T`, w = F/32). These regenerate the
//!   paper's rows so the comparison shape (who wins, crossovers) can be
//!   verified even though we do not own a V100 or a 5218R.

pub mod calibrated;
pub mod cpu;

pub use calibrated::{CalibratedModel, Platform};
