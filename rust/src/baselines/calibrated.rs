//! Analytical latency models of the paper's CPU (Xeon Gold 5218R,
//! PyTorch JIT) and GPU (V100, PyTorch JIT), calibrated by linear least
//! squares against the paper's Table 2.
//!
//! Model form (per platform):
//!
//! ```text
//! lat_ms(N, w, T) = a + b·N + c·N·T + d·w·N·T ,   w = features / 32
//! ```
//!
//! Rationale: the paper's CPU/GPU latencies are dominated by per-layer,
//! per-timestep kernel dispatch (both scale ~linearly in N·T and are
//! nearly width-independent at these sizes — framework overhead, not
//! FLOPs); the affine `a + b·N` term captures fixed launch/sync cost.
//! The fit quality (R² ≥ 0.98 for CPU, ≥ 0.99 for GPU) is asserted by
//! tests, so if the embedded paper data and the model ever disagree the
//! suite fails loudly rather than silently misrepresenting the baseline.

use crate::model::Topology;
use crate::report::paper_data;
use crate::util::linalg::{lstsq, r_squared};

/// Which published platform a calibrated model reproduces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Platform {
    XeonGold5218R,
    V100,
}

impl Platform {
    pub fn label(&self) -> &'static str {
        match self {
            Platform::XeonGold5218R => "CPU (Xeon Gold 5218R, paper-calibrated)",
            Platform::V100 => "GPU (V100, paper-calibrated)",
        }
    }

    pub fn power_w(&self) -> f64 {
        match self {
            Platform::XeonGold5218R => paper_data::PAPER_CPU_POWER_W,
            Platform::V100 => paper_data::PAPER_GPU_POWER_W,
        }
    }
}

/// A calibrated `a + b·N + c·N·T + d·w·N·T` latency model.
#[derive(Clone, Debug)]
pub struct CalibratedModel {
    pub platform: Platform,
    /// β = [a, b, c, d].
    pub beta: [f64; 4],
    /// Goodness of fit on the 24 calibration points.
    pub r2: f64,
}

fn design_row(n: usize, w: f64, t: usize) -> [f64; 4] {
    [1.0, n as f64, n as f64 * t as f64, w * n as f64 * t as f64]
}

impl CalibratedModel {
    /// Fit against the paper's Table 2 column for the platform.
    pub fn fit(platform: Platform) -> CalibratedModel {
        let mut xs = Vec::with_capacity(24 * 4);
        let mut ys = Vec::with_capacity(24);
        for col in &paper_data::TABLE2 {
            let topo = Topology::from_name(col.model).expect("paper model");
            let w = topo.features as f64 / 32.0;
            let lat = match platform {
                Platform::XeonGold5218R => &col.cpu,
                Platform::V100 => &col.gpu,
            };
            for (i, &t) in paper_data::TIMESTEPS.iter().enumerate() {
                xs.extend_from_slice(&design_row(topo.depth, w, t));
                ys.push(lat[i]);
            }
        }
        let beta_v = lstsq(&xs, &ys, 4).expect("calibration fit");
        let beta = [beta_v[0], beta_v[1], beta_v[2], beta_v[3]];
        let pred: Vec<f64> = (0..ys.len())
            .map(|i| {
                (0..4).map(|k| beta[k] * xs[i * 4 + k]).sum::<f64>()
            })
            .collect();
        CalibratedModel { platform, beta, r2: r_squared(&pred, &ys) }
    }

    /// Predicted latency in ms for a topology and sequence length.
    pub fn latency_ms(&self, topo: &Topology, t: usize) -> f64 {
        let w = topo.features as f64 / 32.0;
        let row = design_row(topo.depth, w, t);
        (0..4).map(|k| self.beta[k] * row[k]).sum()
    }

    /// Energy per timestep in mJ via the platform power band.
    pub fn energy_per_timestep_mj(&self, topo: &Topology, t: usize) -> f64 {
        crate::accel::energy::energy_per_timestep_mj(
            self.platform.power_w(),
            self.latency_ms(topo, t),
            t,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_fit_quality() {
        let m = CalibratedModel::fit(Platform::XeonGold5218R);
        assert!(m.r2 > 0.97, "CPU fit R² = {}", m.r2);
    }

    #[test]
    fn gpu_fit_quality() {
        let m = CalibratedModel::fit(Platform::V100);
        assert!(m.r2 > 0.99, "GPU fit R² = {}", m.r2);
    }

    #[test]
    fn predictions_close_to_paper_cells() {
        for platform in [Platform::XeonGold5218R, Platform::V100] {
            let m = CalibratedModel::fit(platform);
            for col in &paper_data::TABLE2 {
                let topo = Topology::from_name(col.model).unwrap();
                let lat = match platform {
                    Platform::XeonGold5218R => &col.cpu,
                    Platform::V100 => &col.gpu,
                };
                for (i, &t) in paper_data::TIMESTEPS.iter().enumerate() {
                    let pred = m.latency_ms(&topo, t);
                    let rel = (pred - lat[i]).abs() / lat[i];
                    assert!(
                        rel < 0.35,
                        "{:?} {} T={t}: pred {pred:.3} vs paper {:.3}",
                        platform,
                        col.model,
                        lat[i]
                    );
                }
            }
        }
    }

    #[test]
    fn gpu_nearly_flat_in_t_cpu_is_not() {
        // The regime the paper describes: GPU latency barely moves with T,
        // CPU grows steeply.
        let topo = Topology::from_name("F32-D6").unwrap();
        let gpu = CalibratedModel::fit(Platform::V100);
        let cpu = CalibratedModel::fit(Platform::XeonGold5218R);
        let gpu_ratio = gpu.latency_ms(&topo, 64) / gpu.latency_ms(&topo, 1);
        let cpu_ratio = cpu.latency_ms(&topo, 64) / cpu.latency_ms(&topo, 1);
        assert!(gpu_ratio < 1.6, "gpu 64/1 ratio {gpu_ratio}");
        assert!(cpu_ratio > 4.0, "cpu 64/1 ratio {cpu_ratio}");
    }

    #[test]
    fn depth_scaling_matches_paper_claim() {
        // D2 → D6 at T=64 on F64: CPU ≈ 2.9x, GPU ≈ 2.2x (§4.2).
        let d2 = Topology::from_name("F64-D2").unwrap();
        let d6 = Topology::from_name("F64-D6").unwrap();
        let cpu = CalibratedModel::fit(Platform::XeonGold5218R);
        let gpu = CalibratedModel::fit(Platform::V100);
        let cpu_scale = cpu.latency_ms(&d6, 64) / cpu.latency_ms(&d2, 64);
        let gpu_scale = gpu.latency_ms(&d6, 64) / gpu.latency_ms(&d2, 64);
        assert!((cpu_scale - 2.9).abs() < 0.35, "cpu {cpu_scale}");
        assert!((gpu_scale - 2.2).abs() < 0.35, "gpu {gpu_scale}");
    }
}
