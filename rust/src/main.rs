//! `lstm-ae-accel` CLI — the L3 coordinator entrypoint.
//!
//! ```text
//! lstm-ae-accel <command> [--flags]
//!
//! Commands:
//!   models                         list the paper's models + topologies
//!   balance   --model F32-D2 --rhm 1     show balanced reuse factors
//!   simulate  --model F32-D2 --timesteps 64 [--rhm N] [--fifo N]
//!   table1 | table2 | table3       regenerate the paper's tables
//!   figures                        depth + latency scaling series
//!   resources --device zcu104|ultra96|pynqz2|alveo  RH_m fitting sweep
//!   infer     --model F32-D2 --timesteps 16        one PJRT inference
//!   measure   --model F32-D2 --timesteps 16 --reps 1000   CPU baseline
//!   serve     --model F32-D2 --timesteps 16 --requests 1000 --rate 2000
//!   fleet     --requests 2000 --rate 4000 [--replicas 2] [--mode auto] [--queue 1024]
//!             serve all four paper topologies concurrently (mixed Poisson traffic)
//!             [--rotate N] shifting trace: the hot model rotates every N requests
//!             ([--hot-frac 0.85] of traffic to the hot lane)
//!             [--autoscale] metrics-driven per-lane scaling
//!             ([--min-workers 1] [--max-workers 6] [--budget N] [--tick-ms 20])
//!             [--async] closed-loop driver through the async ticket front:
//!             a handful of client threads sustain thousands of outstanding
//!             requests ([--clients 4] [--outstanding 1024])
//!             [--pin-cores] pin pipeline stage workers so layer i and i+1
//!             sit on neighbouring cores ([--pin-base N] first core)
//!             [--cache-entries N] per-lane exact-match score cache with
//!             single-flight coalescing (0 = off, the default;
//!             [--cache-bytes B] caps resident key bytes, default 64 MiB)
//!   fleet serve   --bind 127.0.0.1:7070 [--replicas 2] [--mode auto] [--seed 7]
//!             [--autoscale ...] [--report-every-s N] [--pin-cores [--pin-base N]]
//!             [--cache-entries N [--cache-bytes B]]
//!             run this process as a network shard: all four paper topologies
//!             behind the wire protocol, until killed
//!             [--ephemeral] child-process mode for the fleet autoscaler:
//!             exit cleanly once a drain request (`Leave` over the wire)
//!             lands and every connection has wound down
//!             [--streams N --rate-hz R] additionally self-drive N in-process
//!             telemetry sessions at R samples/s each through the lane
//!             session tables (visible in --report-every-s reports)
//!   fleet connect --shards a1:p1,a2:p2 [--requests N] [--rate R] [--timesteps T]
//!             [--seed 7] [--report] drive the Poisson trace across a shard
//!             fleet; exits nonzero on accounting mismatch or lost requests
//!             [--zipf-pool P] draw windows from a Zipf(s=1.1) pool of P benign
//!             windows per model instead of fresh ones — the repeat-heavy
//!             trace that exercises the server-side score cache
//!             [--heartbeat-ms 250] [--suspect-after 3] [--dead-after 6]
//!             [--reconnect-max-backoff 5000] control-plane tuning: probe
//!             cadence, missed-probe demotion thresholds, redial backoff cap
//!             — dead shards are redialed until they rejoin, no flag needed
//!             [--streams N --rate-hz R] additionally drive N streaming
//!             sessions at R samples/s each over the v3 session frames,
//!             sticky-routed per session; prints a "stream resets N" line
//!             (nonzero after a mid-trace shard restart) and gates the exit
//!             code on the stream sample accounting too
//!             [--fleet-autoscale] run the fleet process autoscaler: spawn
//!             ephemeral `fleet serve` children under pressure, drain and
//!             reap them when quiet ([--min-shards 1] [--max-shards 4]
//!             [--fleet-tick-ms 100]); prints a "shard spawns / shard
//!             retires" summary line
//!             [--surge] two-phase trace — a burst at --rate then a long
//!             quiet tail at [--quiet-rate rate/20] — that forces the
//!             autoscaler through both directions in one run
//!   checks                         run the paper-shape checks
//! ```

use std::sync::Arc;

use anyhow::{anyhow, Result};

use lstm_ae_accel::accel::dataflow::{DataflowSim, SimOptions};
use lstm_ae_accel::accel::latency::LatencyModel;
use lstm_ae_accel::accel::platform::FpgaDevice;
use lstm_ae_accel::accel::resources::min_fitting_rh_m;
use lstm_ae_accel::accel::reuse::BalancedConfig;
use lstm_ae_accel::baselines::cpu as cpu_baseline;
use lstm_ae_accel::model::Topology;
use lstm_ae_accel::report;
use lstm_ae_accel::runtime::Runtime;
use lstm_ae_accel::engine::{ExecMode, PipelineOptions};
use lstm_ae_accel::net::{ShardServer, WIRE_VERSION};
use lstm_ae_accel::server::{
    self, AnomalyServer, AutoscalePolicy, Backend, CacheConfig, FleetScalePolicy, FleetScaler,
    ModelRegistry, PjrtBackend, QuantBackend, RouterConfig, ServerConfig, ShardRouter,
    ShardSpawner, SubmitError,
};
use lstm_ae_accel::util::cli::Args;
use lstm_ae_accel::util::table::Table;
use lstm_ae_accel::workload::trace::{
    closed_loop_async, merged_poisson, multi_stream_trace, poisson_trace, replay_fleet,
    replay_streams, rotating_hot_poisson, surge_poisson, zipf_poisson,
};
use lstm_ae_accel::workload::TelemetryGen;
use lstm_ae_accel::model::LstmAutoencoder;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "models" => cmd_models(),
        "balance" => cmd_balance(&args),
        "simulate" => cmd_simulate(&args),
        "table1" => {
            print!("{}", report::table1());
            Ok(())
        }
        "table2" => cmd_table2(&args),
        "table3" => {
            print!("{}", report::table3());
            Ok(())
        }
        "figures" => {
            print!("{}", report::depth_scaling());
            print!("{}", report::latency_scaling());
            Ok(())
        }
        "resources" => cmd_resources(&args),
        "optimize" => cmd_optimize(&args),
        "throughput" => cmd_throughput(&args),
        "infer" => cmd_infer(&args),
        "measure" => cmd_measure(&args),
        "serve" => cmd_serve(&args),
        "fleet" => cmd_fleet(&args),
        "checks" => cmd_checks(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!("lstm-ae-accel — temporal-parallel LSTM-AE accelerator (paper reproduction)");
    println!("commands: models balance simulate table1 table2 table3 figures resources");
    println!("          infer measure serve fleet checks   (see --help strings in main.rs)");
    println!("          fleet serve --bind A:P | fleet connect --shards A:P,...   shard fabric");
}

fn topo_from(args: &Args) -> Result<Topology> {
    Topology::from_name(args.get_or("model", "F32-D2"))
}

/// Engine knobs shared by the fleet roles: `--pin-cores` pins pipeline
/// stage workers (layer i and i+1 on neighbouring cores), `--pin-base N`
/// picks the first core of the assignment (default 0). Pinning is
/// best-effort and never changes scores.
fn engine_options(args: &Args) -> PipelineOptions {
    PipelineOptions {
        pin_base_core: args.has("pin-cores").then(|| args.get_usize("pin-base", 0)),
        ..Default::default()
    }
}

/// Per-lane score-cache knobs shared by the fleet roles: `--cache-entries N`
/// turns on the exact-match cache with single-flight coalescing (0, the
/// default, leaves lanes uncached), `--cache-bytes B` caps resident key
/// bytes (default 64 MiB).
fn cache_options(args: &Args) -> Option<CacheConfig> {
    let entries = args.get_usize("cache-entries", 0);
    (entries > 0).then(|| CacheConfig {
        entries,
        bytes: args.get_usize("cache-bytes", CacheConfig::default().bytes),
    })
}

fn cmd_models() -> Result<()> {
    let mut t = Table::new("Paper models (§4.1)")
        .header(&["Name", "Chain", "RH_m", "Params", "MACs/timestep"]);
    for topo in Topology::paper_models() {
        let rh = BalancedConfig::paper_rh_m(&topo.name).unwrap();
        t.row(vec![
            topo.name.clone(),
            topo.chain().iter().map(|d| d.to_string()).collect::<Vec<_>>().join("→"),
            rh.to_string(),
            topo.param_count().to_string(),
            topo.macs_per_timestep().to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_balance(args: &Args) -> Result<()> {
    let topo = topo_from(args)?;
    let rh_m = args
        .get_u64("rhm", BalancedConfig::paper_rh_m(&topo.name).unwrap_or(1));
    let cfg = BalancedConfig::balance(&topo, rh_m);
    let mut t = Table::new(&format!("Balanced dataflow for {} (RH_m = {rh_m})", topo.name))
        .header(&["Layer", "LX", "LH", "RX", "RH", "MX", "MH", "X_t", "H_t", "Lat_t"]);
    for (i, l) in cfg.layers.iter().enumerate() {
        let tag = if i == cfg.bottleneck { format!("LSTM_{i} (m)") } else { format!("LSTM_{i}") };
        t.row(vec![
            tag,
            l.lx.to_string(),
            l.lh.to_string(),
            format!("{:.2}", l.rx_exact),
            format!("{:.2}", l.rh_exact),
            l.mx.to_string(),
            l.mh.to_string(),
            l.x_t().to_string(),
            l.h_t().to_string(),
            l.lat_t().to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("balance ratio (min/max Lat_t): {:.3}", cfg.balance_ratio());
    println!("total multipliers: {}", cfg.total_multipliers());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let topo = topo_from(args)?;
    let rh_m = args
        .get_u64("rhm", BalancedConfig::paper_rh_m(&topo.name).unwrap_or(1));
    let t = args.get_usize("timesteps", 64);
    let cfg = BalancedConfig::balance(&topo, rh_m);
    let opts = SimOptions {
        fifo_capacity: args.get_usize("fifo", 2),
        reader_cycles_per_t: args.get_u64("reader", 0),
        writer_cycles_per_t: args.get_u64("writer", 0),
    };
    let run = DataflowSim::with_options(&cfg, opts).run_sequence(t);
    let lm = LatencyModel::of(&cfg);
    println!("model {} | T={t} | RH_m={rh_m} | fifo={}", topo.name, opts.fifo_capacity);
    println!(
        "cycles: {} (analytical Eq1: {}) | {:.3} ms @300MHz | steady II {} cyc",
        run.total_cycles,
        lm.acc_lat(t),
        run.total_ms(FpgaDevice::ZCU104.clock_hz),
        run.steady_ii
    );
    let mut tbl = Table::new("Per-module stats")
        .header(&["Module", "service", "busy", "starved", "blocked", "util"]);
    for (i, m) in run.per_module.iter().enumerate() {
        tbl.row(vec![
            format!("LSTM_{i}"),
            m.service.to_string(),
            m.busy.to_string(),
            m.starved.to_string(),
            m.blocked.to_string(),
            format!("{:.3}", m.utilization),
        ]);
    }
    print!("{}", tbl.render());
    println!("mean utilization: {:.3}", run.mean_utilization());
    println!(
        "temporal-parallelism speedup vs layer-by-layer: x{:.2}",
        lm.temporal_speedup(t)
    );
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    if args.has("measured") {
        let rt = Runtime::open(&Runtime::default_dir())?;
        let reps = args.get_usize("reps", 100);
        let f = move |model: &str, t: usize| -> Option<f64> {
            cpu_baseline::measure(&rt, model, t, 5, reps).ok().map(|m| m.latency_ms.mean)
        };
        print!("{}", report::tables::table2(Some(&f)));
    } else {
        print!("{}", report::tables::table2(None));
    }
    Ok(())
}

fn cmd_resources(args: &Args) -> Result<()> {
    let dev = match args.get_or("device", "zcu104") {
        "zcu104" => FpgaDevice::ZCU104,
        "ultra96" => FpgaDevice::ULTRA96,
        "pynqz2" => FpgaDevice::PYNQ_Z2,
        "alveo" => FpgaDevice::ALVEO_U50,
        other => return Err(anyhow!("unknown device {other:?}")),
    };
    let mut t = Table::new(&format!("Minimum fitting RH_m on {}", dev.name))
        .header(&["Model", "RH_m", "LUT%", "FF%", "BRAM%", "DSP%", "Lat_t_m (cyc)"]);
    for topo in Topology::paper_models() {
        match min_fitting_rh_m(&topo, &dev, 256) {
            Some((rh_m, usage)) => {
                let cfg = BalancedConfig::balance(&topo, rh_m);
                let lm = LatencyModel::of(&cfg);
                let p = usage.pct(&dev);
                t.row(vec![
                    topo.name.clone(),
                    rh_m.to_string(),
                    format!("{:.1}", p.lut),
                    format!("{:.1}", p.ff),
                    format!("{:.1}", p.bram),
                    format!("{:.1}", p.dsp),
                    lm.lat_t_m().to_string(),
                ]);
            }
            None => {
                t.row(vec![
                    topo.name.clone(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "does not fit".into(),
                ]);
            }
        }
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    use lstm_ae_accel::accel::optimizer::{optimize, pareto_front, Objective};
    let topo = topo_from(args)?;
    let dev = match args.get_or("device", "zcu104") {
        "zcu104" => FpgaDevice::ZCU104,
        "ultra96" => FpgaDevice::ULTRA96,
        "pynqz2" => FpgaDevice::PYNQ_Z2,
        "alveo" => FpgaDevice::ALVEO_U50,
        other => return Err(anyhow!("unknown device {other:?}")),
    };
    let t = args.get_usize("timesteps", 64);
    let objective = match args.get_or("objective", "latency") {
        "latency" => Objective::Latency,
        "energy" => Objective::Energy,
        "area" => Objective::AreaUnderLatencyBound(args.get_u64("bound-us", 500)),
        other => return Err(anyhow!("unknown objective {other:?}")),
    };
    match optimize(&topo, &dev, t, objective) {
        None => println!("{} does not fit {} at any RH_m", topo.name, dev.name),
        Some(p) => {
            println!(
                "{} on {} (T={t}, {objective:?}): RH_m = {} | {:.4} ms | {:.4} mJ/t | mean util {:.1}%",
                topo.name, dev.name, p.rh_m, p.latency_ms, p.energy_mj_per_t, p.mean_util_pct
            );
        }
    }
    let front = pareto_front(&topo, &dev, t);
    let mut tbl = Table::new("Pareto front (latency vs area)")
        .header(&["RH_m", "latency ms", "mJ/t", "mean util %"]);
    for p in front.iter().take(12) {
        tbl.row(vec![
            p.rh_m.to_string(),
            format!("{:.4}", p.latency_ms),
            format!("{:.4}", p.energy_mj_per_t),
            format!("{:.1}", p.mean_util_pct),
        ]);
    }
    print!("{}", tbl.render());
    Ok(())
}

fn cmd_throughput(args: &Args) -> Result<()> {
    use lstm_ae_accel::accel::multi::{run_batch, steady_throughput};
    let topo = topo_from(args)?;
    let rh_m = args.get_u64("rhm", BalancedConfig::paper_rh_m(&topo.name).unwrap_or(1));
    let t = args.get_usize("timesteps", 16);
    let cfg = BalancedConfig::balance(&topo, rh_m);
    let hz = FpgaDevice::ZCU104.clock_hz;
    let mut tbl = Table::new(&format!(
        "Back-to-back sequence throughput, {} (T={t}, RH_m={rh_m})",
        topo.name
    ))
    .header(&["batch", "total cycles", "seq/s", "vs steady-state"]);
    let steady = steady_throughput(&cfg, t, hz);
    for n in [1usize, 2, 8, 64, 512] {
        let b = run_batch(&cfg, SimOptions::default(), t, n);
        let tp = b.throughput_seq_per_s(hz);
        tbl.row(vec![
            n.to_string(),
            b.total_cycles.to_string(),
            format!("{tp:.0}"),
            format!("{:.1}%", 100.0 * tp / steady),
        ]);
    }
    print!("{}", tbl.render());
    println!("analytical steady state: {steady:.0} seq/s (fill amortizes per batch)");
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let rt = Runtime::open(&Runtime::default_dir())?;
    let model = args.get_or("model", "F32-D2");
    let t = args.get_usize("timesteps", 16);
    let entry = rt
        .manifest()
        .find(model)
        .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?;
    let f = entry.features;
    let mut gen = rt.telemetry_for(model, 42).unwrap_or_else(|_| TelemetryGen::new(f, 42));
    let w = gen.benign_window(t);
    let flat: Vec<f32> = w.data.iter().flatten().copied().collect();
    let out = rt.infer(model, t, &flat)?;
    let mse = flat
        .iter()
        .zip(&out)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / flat.len() as f64;
    println!("platform: {}", rt.platform());
    println!("model {model} T={t}: reconstruction MSE on benign window = {mse:.6}");
    Ok(())
}

fn cmd_measure(args: &Args) -> Result<()> {
    let rt = Runtime::open(&Runtime::default_dir())?;
    let model = args.get_or("model", "F32-D2").to_string();
    let reps = args.get_usize("reps", 1000);
    let ts = args.get_usize_list("timesteps", &[1, 2, 4, 6, 16, 64]);
    let mut t = Table::new(&format!("Measured XLA-CPU latency, {model} ({reps} reps)"))
        .header(&["T", "mean ms", "p50 ms", "p95 ms", "vs FPGA(sim)"]);
    let topo = Topology::from_name(&model)?;
    for steps in ts {
        let m = cpu_baseline::measure(&rt, &model, steps, 10, reps)?;
        let fpga = report::tables::fpga_latency_ms(&topo, steps);
        t.row(vec![
            steps.to_string(),
            format!("{:.3}", m.latency_ms.mean),
            format!("{:.3}", m.latency_ms.p50),
            format!("{:.3}", m.latency_ms.p95),
            format!("x{:.1}", m.latency_ms.mean / fpga),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.get_or("model", "F32-D2").to_string();
    let t = args.get_usize("timesteps", 16);
    let n = args.get_usize("requests", 1000);
    let rate = args.get_f64("rate", 2000.0);
    let anomaly_rate = args.get_f64("anomaly-rate", 0.1);

    // Backend: PJRT artifact if available, else quantized golden model.
    let topo = Topology::from_name(&model)?;
    let (backend, backend_name): (Arc<dyn server::Backend>, String) =
        match PjrtBackend::new(Runtime::default_dir(), &model, t) {
            Ok(b) => {
                let name = b.name();
                (Arc::new(b), name)
            }
            Err(_) => {
                eprintln!("(no artifacts — using quantized golden-model backend)");
                let b = QuantBackend::new(LstmAutoencoder::random(topo.clone(), 7));
                let name = b.name();
                (Arc::new(b), name)
            }
        };

    // Calibrate threshold on benign traffic (training-family telemetry
    // when the spec artifact exists).
    let spec = Runtime::default_dir().join(format!("telemetry_F{}.json", topo.features));
    let mk_gen = |seed: u64| {
        TelemetryGen::from_spec_file(&spec, seed)
            .unwrap_or_else(|_| TelemetryGen::new(topo.features, seed))
    };
    let mut gen = mk_gen(11);
    let benign: Vec<f64> = (0..64)
        .map(|_| {
            let w = gen.benign_window(t);
            backend.score_batch(&[&w])[0]
        })
        .collect();
    let threshold = server::calibrate_threshold(&benign, 0.99);
    let cfg = ServerConfig::builder()
        .max_batch(args.get_usize("max-batch", 8))
        .max_wait(std::time::Duration::from_micros(args.get_u64("max-wait-us", 500)))
        .workers(args.get_usize("workers", 2))
        .queue_capacity(args.get_usize("queue", 1024))
        .threshold(threshold)
        .build();
    println!("backend {backend_name} | threshold {threshold:.6}");

    let srv = AnomalyServer::start(backend, cfg);
    let mut gen = mk_gen(13);
    let trace = poisson_trace(&mut gen, 17, rate, n, t, anomaly_rate);
    let start = std::time::Instant::now();
    let mut inflight = Vec::with_capacity(n);
    let mut shed = 0u64;
    for req in trace {
        let target = std::time::Duration::from_secs_f64(req.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        let is_anomaly = req.window.anomaly.is_some();
        match srv.submit(req.window) {
            Ok(rx) => inflight.push((rx, is_anomaly)),
            Err(SubmitError::Overloaded) => shed += 1,
            Err(e) => return Err(anyhow!("submit: {e}")),
        }
    }
    let mut tp = 0u64;
    let mut fp = 0u64;
    let mut fneg = 0u64;
    let mut tn = 0u64;
    for (rx, truth) in inflight {
        let r = rx.recv().expect("response");
        match (r.is_anomaly, truth) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, true) => fneg += 1,
            (false, false) => tn += 1,
        }
    }
    println!("{}", srv.metrics().report());
    if shed > 0 {
        println!("load shed at admission: {shed} requests (raise --queue or lower --rate)");
    }
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    let recall = tp as f64 / (tp + fneg).max(1) as f64;
    println!(
        "detection: TP {tp} FP {fp} FN {fneg} TN {tn} | precision {precision:.3} recall {recall:.3}"
    );
    srv.shutdown();
    Ok(())
}

/// Serve all four paper topologies concurrently through the multi-model
/// fabric under open-loop Poisson traffic — mixed by default, or a
/// shifting rotating-hot-model trace with `--rotate N` — optionally with
/// the metrics-driven per-lane autoscaler (`--autoscale`), then print
/// the rolled-up fleet report (per-lane counters, shed, latency
/// percentiles, worker/replica counts, scaling decisions).
fn cmd_fleet(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("serve") => return cmd_fleet_serve(args),
        Some("connect") => return cmd_fleet_connect(args),
        Some(other) => return Err(anyhow!("unknown fleet subcommand {other:?}")),
        None => {}
    }
    let t = args.get_usize("timesteps", 16);
    let n = args.get_usize("requests", 2000);
    let rate = args.get_f64("rate", 4000.0);
    let anomaly_rate = args.get_f64("anomaly-rate", 0.1);
    let replicas = args.get_usize("replicas", 2);
    let mode = ExecMode::parse(args.get_or("mode", "auto"))
        .ok_or_else(|| anyhow!("unknown --mode (want auto|sequential|pipelined|batched)"))?;
    let seed = args.get_u64("seed", 7);
    let rotate = args.get_usize("rotate", 0);
    let hot_frac = args.get_f64("hot-frac", 0.85).clamp(0.0, 1.0);
    let autoscale = args.has("autoscale");

    let policy = autoscale.then(|| AutoscalePolicy {
        up_ticks: 1,
        down_ticks: 5,
        ..AutoscalePolicy::bounded(
            args.get_usize("min-workers", 1),
            args.get_usize("max-workers", 6),
        )
    });
    let engine = engine_options(args);
    let cache = cache_options(args);
    let registry =
        ModelRegistry::paper_fleet_opts(seed, mode, replicas, policy, engine, cache.clone());
    let models: Vec<String> = registry.models().map(String::from).collect();
    if let Some(base) = engine.pin_base_core {
        println!("core pinning: pipeline stage workers pinned from core {base} up");
    }
    if let Some(c) = &cache {
        println!(
            "score cache: {} entries / {} MiB per lane, single-flight coalescing on",
            c.entries,
            c.bytes >> 20
        );
    }
    if autoscale {
        let budget = args.get_usize("budget", 0);
        let tick = std::time::Duration::from_millis(args.get_u64("tick-ms", 20));
        let watched =
            registry.start_autoscaler(tick, (budget > 0).then_some(budget));
        println!(
            "autoscaler: {watched} lanes under control (tick {tick:?}{})",
            if budget > 0 { format!(", worker budget {budget}") } else { String::new() }
        );
    }

    if args.has("async") {
        // Closed-loop driver through the async ticket front: each client
        // thread keeps its share of `--outstanding` tickets in flight via
        // a CompletionSet — the blocking surface would need one parked OS
        // thread per outstanding request to do the same.
        let clients = args.get_usize("clients", 4).max(1);
        let outstanding = args.get_usize("outstanding", 1024);
        let per_client = (outstanding / clients).max(1);
        println!(
            "fleet (async closed loop): {n} requests over {} lanes, {clients} client \
             threads × {per_client} outstanding each (T={t}, mode {mode:?})",
            models.len()
        );
        let stats = closed_loop_async(
            &registry,
            &models,
            clients,
            per_client,
            n,
            t,
            seed.wrapping_add(80),
        );
        print!("{}", registry.fleet_report());
        let wall = stats.wall.as_secs_f64().max(1e-9);
        println!(
            "wall {wall:.2}s | {} completed ({:.0}/s) | peak outstanding {} \
             (vs {clients} for the blocking driver) | {} shed retries | {} failed",
            stats.completed,
            stats.completed as f64 / wall,
            stats.max_outstanding,
            stats.shed_retries,
            stats.failed
        );
        registry.shutdown();
        return Ok(());
    }

    // Mixed traffic: one independent Poisson stream per model at rate/N
    // each, merged into a single arrival-ordered schedule. With
    // --rotate N: one global stream whose hot model shifts every N
    // requests (the autoscaling workload). The trace seed derives from
    // --seed too, so different seeds draw different traffic, not just
    // different weights.
    let topos = models
        .iter()
        .map(|m| Topology::from_name(m))
        .collect::<Result<Vec<_>>>()?;
    let merged = if rotate > 0 {
        rotating_hot_poisson(
            &topos,
            seed.wrapping_add(40),
            rate,
            n,
            t,
            anomaly_rate,
            hot_frac,
            rotate,
        )
    } else {
        merged_poisson(&topos, seed.wrapping_add(40), rate, n, t, anomaly_rate)
    };
    println!(
        "fleet: {} requests over {} lanes @ {rate:.0} rps aggregate \
         (T={t}, mode {mode:?}, {replicas} replicas on deep lanes{})",
        merged.len(),
        models.len(),
        if rotate > 0 {
            format!(", hot model rotates every {rotate} requests")
        } else {
            String::new()
        }
    );

    let start = std::time::Instant::now();
    let mut inflight = Vec::with_capacity(merged.len());
    let mut shed = 0u64;
    for (mi, req) in merged {
        let target = std::time::Duration::from_secs_f64(req.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        match registry.submit(&models[mi], req.window) {
            Ok(rx) => inflight.push(rx),
            Err(SubmitError::Overloaded) => shed += 1,
            Err(e) => return Err(anyhow!("submit to {}: {e}", models[mi])),
        }
    }
    for rx in inflight {
        let _ = rx.recv();
    }
    let wall = start.elapsed().as_secs_f64();
    print!("{}", registry.fleet_report());
    println!("wall {wall:.2}s | {shed} shed at admission");
    registry.shutdown();
    Ok(())
}

/// `fleet serve`: run this process as one network shard — the four paper
/// topologies behind the wire protocol on `--bind`, until killed. The CI
/// loopback-soak job runs exactly this against `fleet connect`.
fn cmd_fleet_serve(args: &Args) -> Result<()> {
    let bind = args.get_or("bind", "127.0.0.1:7070");
    let seed = args.get_u64("seed", 7);
    let replicas = args.get_usize("replicas", 2);
    let mode = ExecMode::parse(args.get_or("mode", "auto"))
        .ok_or_else(|| anyhow!("unknown --mode (want auto|sequential|pipelined|batched)"))?;
    let autoscale = args.has("autoscale");
    let policy = autoscale.then(|| AutoscalePolicy {
        up_ticks: 1,
        down_ticks: 5,
        ..AutoscalePolicy::bounded(
            args.get_usize("min-workers", 1),
            args.get_usize("max-workers", 6),
        )
    });
    let engine = engine_options(args);
    let cache = cache_options(args);
    let registry = Arc::new(ModelRegistry::paper_fleet_opts(
        seed,
        mode,
        replicas,
        policy,
        engine,
        cache.clone(),
    ));
    if let Some(base) = engine.pin_base_core {
        println!("core pinning: pipeline stage workers pinned from core {base} up");
    }
    if let Some(c) = &cache {
        println!(
            "score cache: {} entries / {} MiB per lane, single-flight coalescing on",
            c.entries,
            c.bytes >> 20
        );
    }
    if autoscale {
        let budget = args.get_usize("budget", 0);
        let tick = std::time::Duration::from_millis(args.get_u64("tick-ms", 20));
        registry.start_autoscaler(tick, (budget > 0).then_some(budget));
    }
    // --streams N: keep N in-process telemetry sessions stepping against
    // this shard's own lanes, so the session tables (and the fleet
    // report's sessions column) carry load even with no remote clients.
    let streams = args.get_usize("streams", 0);
    if streams > 0 {
        let rate_hz = args.get_f64("rate-hz", 1.0).max(1e-3);
        let reg = registry.clone();
        println!("session self-drive: {streams} streams @ {rate_hz:.1} samples/s each");
        std::thread::spawn(move || {
            let topos = Topology::paper_models();
            let models: Vec<String> = topos.iter().map(|t| t.name.clone()).collect();
            let mut round = 0u64;
            loop {
                let trace = multi_stream_trace(
                    &topos,
                    seed.wrapping_add(60).wrapping_add(round),
                    streams,
                    rate_hz,
                    64,
                    0.05,
                );
                let _ = replay_streams(&*reg, &models, trace, false);
                round += 1;
            }
        });
    }
    let ephemeral = args.has("ephemeral");
    let server = ShardServer::bind(bind, registry.clone())
        .map_err(|e| anyhow!("bind {bind}: {e}"))?;
    println!(
        "fleet shard: serving {} models on {} (wire v{WIRE_VERSION}, seed {seed}, \
         mode {mode:?}, {replicas} replicas on deep lanes) — {}",
        registry.len(),
        server.local_addr(),
        if ephemeral { "ephemeral, exits after drain" } else { "kill to stop" }
    );
    // stdout may be pipe-buffered (the soak job backgrounds this); make
    // the banner visible before parking.
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    let every = args.get_u64("report-every-s", 0);
    // Ephemeral children (the fleet autoscaler's spawn unit) poll for the
    // drain handshake: once a `Leave` drain request lands and the last
    // connection winds down, exit cleanly instead of parking forever.
    let poll = if ephemeral {
        std::time::Duration::from_millis(50)
    } else {
        std::time::Duration::from_secs(if every > 0 { every } else { 3600 })
    };
    let mut last_report = std::time::Instant::now();
    loop {
        std::thread::sleep(poll);
        if every > 0 && last_report.elapsed() >= std::time::Duration::from_secs(every) {
            print!("{}", registry.fleet_report());
            let _ = std::io::stdout().flush();
            last_report = std::time::Instant::now();
        }
        if ephemeral && server.is_leaving() && server.live_connections() == 0 {
            println!("ephemeral shard on {} drained — exiting", server.local_addr());
            let _ = std::io::stdout().flush();
            server.shutdown();
            registry.shutdown();
            return Ok(());
        }
    }
}

/// `fleet connect`: drive the mixed Poisson trace across a shard fleet
/// through a [`ShardRouter`], then enforce the conservation law the CI
/// soak gates on — every offered request terminates in exactly one of
/// completed / shed / rejected_closed, and nothing is lost.
fn cmd_fleet_connect(args: &Args) -> Result<()> {
    let shards: Vec<String> = args
        .get_or("shards", "127.0.0.1:7070")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let n = args.get_usize("requests", 2000);
    let rate = args.get_f64("rate", 4000.0);
    let timesteps = args.get_usize("timesteps", 16);
    let anomaly_rate = args.get_f64("anomaly-rate", 0.1);
    let seed = args.get_u64("seed", 7);
    let suspect_after = args.get_u64("suspect-after", 3).clamp(1, u32::MAX as u64) as u32;
    // Clamp instead of panicking on dead-after < suspect-after.
    let dead_after =
        args.get_u64("dead-after", 6).clamp(u64::from(suspect_after), u32::MAX as u64) as u32;
    let cfg = RouterConfig::builder()
        .heartbeat_ms(args.get_u64("heartbeat-ms", 250).max(1))
        .suspect_after(suspect_after)
        .dead_after(dead_after)
        .reconnect_max_backoff_ms(args.get_u64("reconnect-max-backoff", 5000).max(1))
        .build();
    let router = Arc::new(
        ShardRouter::connect_with(&shards, cfg).map_err(|e| anyhow!("connect {shards:?}: {e}"))?,
    );
    // --fleet-autoscale: the fleet process autoscaler — spawn ephemeral
    // `fleet serve` children of this very binary under pressure, drain
    // and reap them when quiet, bounded to [--min-shards, --max-shards].
    // The children inherit --seed so their model weights (and thus
    // scores) are bit-identical to the static fleet's.
    let floor = args.get_usize("min-shards", router.len().max(1));
    let scaler = if args.has("fleet-autoscale") {
        let policy = FleetScalePolicy::bounded(floor, args.get_usize("max-shards", floor.max(4)));
        let tick = std::time::Duration::from_millis(args.get_u64("fleet-tick-ms", 100).max(1));
        let exe = std::env::current_exe().map_err(|e| anyhow!("current_exe: {e}"))?;
        let spawner = ShardSpawner::new(
            exe,
            vec!["fleet".into(), "serve".into(), "--seed".into(), seed.to_string()],
        );
        println!(
            "fleet autoscaler: {}..={} shards, tick {tick:?}",
            policy.min_shards, policy.max_shards
        );
        Some(FleetScaler::start(router.clone(), spawner, policy, tick))
    } else {
        None
    };
    let topos = Topology::paper_models();
    let models: Vec<String> = topos.iter().map(|m| m.name.clone()).collect();
    // --zipf-pool P swaps the fresh-window Poisson mix for a repeat-heavy
    // trace: windows drawn Zipf(s=1.1) from a pool of P benign windows per
    // model. Arrival times stay Poisson, so offered load is comparable —
    // only the window population changes, which is exactly what the
    // server-side score cache keys on.
    let zipf_pool = args.get_usize("zipf-pool", 0);
    // --surge swaps in the two-phase trace: a burst at --rate, then a
    // long quiet tail at --quiet-rate. Pressure then sustained quiet is
    // exactly the shape that forces the fleet autoscaler through both a
    // spawn and a retire within one run.
    let surge = args.has("surge");
    let merged = if surge {
        let quiet_rate = args.get_f64("quiet-rate", (rate / 20.0).max(1.0));
        let n_surge = (n * 3 / 4).max(1);
        let n_quiet = (n - n_surge).max(1);
        surge_poisson(
            &topos,
            seed.wrapping_add(40),
            rate,
            quiet_rate,
            n_surge,
            n_quiet,
            timesteps,
        )
    } else if zipf_pool > 0 {
        zipf_poisson(&topos, seed.wrapping_add(40), rate, n, timesteps, zipf_pool, 1.1)
    } else {
        merged_poisson(&topos, seed.wrapping_add(40), rate, n, timesteps, anomaly_rate)
    };
    println!(
        "fleet connect: {} requests over {} models @ {rate:.0} rps aggregate, \
         T={timesteps}, {} shard(s){}",
        merged.len(),
        models.len(),
        router.len(),
        if surge {
            ", surge-then-quiet trace".to_string()
        } else if zipf_pool > 0 {
            format!(", zipf pool {zipf_pool}/model (s=1.1)")
        } else {
            String::new()
        }
    );
    // --streams N rides the same fleet concurrently: N sticky-routed
    // sessions stepping at --rate-hz samples/s each, sized to span the
    // window trace so a mid-trace shard kill hits both planes.
    let streams = args.get_usize("streams", 0);
    let stream_rate = args.get_f64("rate-hz", 1.0).max(1e-3);
    let strace = (streams > 0).then(|| {
        let span_s = n as f64 / rate.max(1.0);
        let per = ((span_s * stream_rate).ceil() as usize).clamp(4, 4096);
        multi_stream_trace(&topos, seed.wrapping_add(60), streams, stream_rate, per, anomaly_rate)
    });
    if streams > 0 {
        println!("streams: {streams} sessions @ {stream_rate:.1} samples/s each, same fleet");
    }
    let (stats, sstats) = std::thread::scope(|sc| {
        let sh = strace.map(|tr| {
            let router = &*router;
            let models = &models;
            sc.spawn(move || replay_streams(router, models, tr, true))
        });
        let stats = replay_fleet(&*router, &models, merged, true);
        (stats, sh.map(|h| h.join().expect("stream driver panicked")))
    });
    // With the autoscaler on, give the quiet tail time to drain the
    // fleet back to the floor before stopping the controller — the
    // "shard retires" count and the live-shard gauge below are what the
    // CI autoscale leg greps for.
    if let Some(scaler) = &scaler {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while router.live_shards() > floor && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        scaler.stop();
        let m = router.metrics();
        println!(
            "fleet scaler: {} shard spawns, {} shard retires | {} live at exit (floor {floor})",
            m.shard_spawns(),
            m.shard_retires(),
            router.live_shards(),
        );
    }
    let wall = stats.wall.as_secs_f64().max(1e-9);
    println!(
        "wall {wall:.2}s | offered {} | completed {} ({:.0}/s) | {} flagged | shed {} | \
         rejected_closed {} | retried after shard loss {} | peak outstanding {} | \
         shard failovers {} | {} of {} shards live",
        stats.offered,
        stats.completed,
        stats.completed as f64 / wall,
        stats.flagged,
        stats.shed,
        stats.rejected_closed,
        stats.retried_closed,
        stats.max_outstanding,
        router.metrics().shard_failovers(),
        router.live_shards(),
        router.len()
    );
    let m = router.metrics();
    // The "reconnects N (attempts M)" shape is what the CI chaos soak
    // greps for as proof the restarted shard rejoined through backoff.
    println!(
        "control plane: {} probes, {} heartbeats | suspects {} | deaths {} | \
         reconnects {} (attempts {})",
        m.health_probes(),
        m.heartbeats(),
        m.shard_suspects(),
        m.shard_deaths(),
        m.shard_reconnects(),
        m.shard_reconnect_attempts(),
    );
    for i in 0..router.len() {
        println!(
            "  shard {} [{}] gen {} inflight {}",
            router.shard_addr(i),
            router.shard_state(i),
            router.shard_generation(i),
            router.shard_inflight(i),
        );
    }
    if let Some(s) = &sstats {
        // Driver-side reopens plus fleet-side resets (failover re-routes
        // and shard-local auto-reopens) — "stream resets N" is the
        // greppable proof a kill −9 cost sessions their carried state.
        let total_resets = s.resets + router.stream_resets();
        println!(
            "streams: opened {} closed {} | samples offered {} completed {} shed {} \
             rejected_closed {} | stream resets {total_resets}",
            s.opened,
            s.closed,
            s.fleet.offered,
            s.fleet.completed,
            s.fleet.shed,
            s.fleet.rejected_closed,
        );
    }
    if args.has("report") {
        print!("{}", router.fleet_report());
    }
    router.shutdown();
    if !stats.conserves() {
        return Err(anyhow!(
            "accounting mismatch: offered {} != completed {} + shed {} + rejected_closed {}",
            stats.offered,
            stats.completed,
            stats.shed,
            stats.rejected_closed
        ));
    }
    if stats.completed == 0 {
        return Err(anyhow!("no request completed — is the shard fleet up?"));
    }
    if stats.rejected_closed > 0 && !args.has("allow-loss") {
        return Err(anyhow!(
            "{} requests lost to closed shards (pass --allow-loss to tolerate)",
            stats.rejected_closed
        ));
    }
    // Stream samples join the admission accounting: the same conservation
    // law and loss gate apply to the session plane.
    if let Some(s) = &sstats {
        if !s.fleet.conserves() {
            return Err(anyhow!(
                "stream accounting mismatch: offered {} != completed {} + shed {} + \
                 rejected_closed {}",
                s.fleet.offered,
                s.fleet.completed,
                s.fleet.shed,
                s.fleet.rejected_closed
            ));
        }
        if s.fleet.rejected_closed > 0 && !args.has("allow-loss") {
            return Err(anyhow!(
                "{} stream samples lost to closed shards (pass --allow-loss to tolerate)",
                s.fleet.rejected_closed
            ));
        }
    }
    Ok(())
}

fn cmd_checks() -> Result<()> {
    let mut failed = 0;
    for (name, ok, detail) in report::tables::shape_checks() {
        println!("[{}] {name} {detail}", if ok { "PASS" } else { "FAIL" });
        if !ok {
            failed += 1;
        }
    }
    if failed > 0 {
        Err(anyhow!("{failed} shape checks failed"))
    } else {
        Ok(())
    }
}
