//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust request path.
//!
//! Python never runs at inference time — `make artifacts` lowers the JAX
//! LSTM-AE (with trained weights baked in as HLO constants) to
//! `artifacts/<model>_T<t>.hlo.txt`; this module compiles each module
//! once on the PJRT CPU client and caches the executable.
//!
//! Interchange is HLO **text** (not serialized `HloModuleProto`): jax ≥
//! 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see `/opt/xla-example/README.md`).

pub mod artifact;

pub use artifact::{ArtifactEntry, Manifest};

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A compiled-executable cache over the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`) and create the
    /// PJRT CPU client.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("load manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), cache: Mutex::new(HashMap::new()) })
    }

    /// The conventional artifact directory for this repo.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the artifact for `model` at sequence
    /// length `t`.
    pub fn executable(
        &self,
        model: &str,
        t: usize,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let entry = self
            .manifest
            .find(model)
            .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?;
        let file = entry
            .hlo_for_t(t)
            .ok_or_else(|| anyhow!("model {model:?} has no artifact for T={t}"))?;
        let key = format!("{}/T{t}", entry.name);
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compile {path:?}"))?,
        );
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Run one inference: `x` is row-major `[t][features]` flattened;
    /// returns the reconstruction with the same layout. The artifact is
    /// lowered with `return_tuple=True`, so the result is a 1-tuple.
    pub fn infer(&self, model: &str, t: usize, x: &[f32]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(model)
            .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?;
        let f = entry.features;
        if x.len() != t * f {
            return Err(anyhow!("input length {} != T({t})·F({f})", x.len()));
        }
        let exe = self.executable(model, t)?;
        let lit = xla::Literal::vec1(x).reshape(&[t as i64, f as i64])?;
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Compile (or fetch) the batched serving executable for `(model, t, b)`.
    fn batched_executable(
        &self,
        model: &str,
        t: usize,
        b: usize,
    ) -> Result<Option<std::sync::Arc<xla::PjRtLoadedExecutable>>> {
        let entry = self
            .manifest
            .find(model)
            .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?;
        let Some(file) = entry.hlo_for_batch(t, b) else {
            return Ok(None);
        };
        let key = format!("{}/T{t}/B{b}", entry.name);
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(Some(exe.clone()));
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let exe = std::sync::Arc::new(
            self.client
                .compile(&xla::XlaComputation::from_proto(&proto))
                .with_context(|| format!("compile {path:?}"))?,
        );
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(Some(exe))
    }

    /// Run a batch of `b` independent windows: input `[b][t][f]` flattened;
    /// output has the same layout. Uses vmap-lowered batched artifacts
    /// when available (greedy largest-chunk decomposition), falling back
    /// to per-window dispatch — one PJRT execute per chunk instead of per
    /// window (§Perf iteration 4).
    pub fn infer_batch(&self, model: &str, t: usize, b: usize, x: &[f32]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(model)
            .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?;
        let f = entry.features;
        if x.len() != b * t * f {
            return Err(anyhow!("input length {} != B({b})·T({t})·F({f})", x.len()));
        }
        let name = entry.name.clone();
        let sizes = entry.batch_sizes(t);
        let window = t * f;
        let mut out = Vec::with_capacity(x.len());
        let mut i = 0usize;
        'outer: while i < b {
            let remaining = b - i;
            for &chunk in &sizes {
                if chunk <= remaining {
                    if let Some(exe) = self.batched_executable(&name, t, chunk)? {
                        let slice = &x[i * window..(i + chunk) * window];
                        let lit = xla::Literal::vec1(slice).reshape(&[
                            chunk as i64,
                            t as i64,
                            f as i64,
                        ])?;
                        let result =
                            exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
                        out.extend(result.to_tuple1()?.to_vec::<f32>()?);
                        i += chunk;
                        continue 'outer;
                    }
                }
            }
            // Fallback: single-window artifact.
            out.extend(self.infer(&name, t, &x[i * window..(i + 1) * window])?);
            i += 1;
        }
        Ok(out)
    }

    /// Telemetry generator matching the family `model` was trained on
    /// (reads the spec exported by `aot.py`). `seed` drives only
    /// noise/anomaly draws.
    pub fn telemetry_for(
        &self,
        model: &str,
        seed: u64,
    ) -> Result<crate::workload::TelemetryGen> {
        let entry = self
            .manifest
            .find(model)
            .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?;
        let file = entry
            .telemetry
            .as_ref()
            .ok_or_else(|| anyhow!("model {model:?} has no telemetry spec"))?;
        crate::workload::TelemetryGen::from_spec_file(&self.dir.join(file), seed)
    }

    /// All `(model, t)` pairs available.
    pub fn available(&self) -> Vec<(String, usize)> {
        let mut v = Vec::new();
        for e in &self.manifest.models {
            for &t in &e.timesteps {
                v.push((e.name.clone(), t));
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifact-dependent tests live in `rust/tests/integration_runtime.rs`
    /// (they need `make artifacts`). Here: error paths that need no files.
    #[test]
    fn open_missing_dir_fails_cleanly() {
        let Err(err) = Runtime::open(Path::new("/nonexistent/artifacts")) else {
            panic!("expected error");
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("manifest"), "{msg}");
    }
}
