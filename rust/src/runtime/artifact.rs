//! Artifact manifest: the contract between `python/compile/aot.py` (writer)
//! and the Rust runtime (reader).
//!
//! `artifacts/manifest.json` layout:
//!
//! ```json
//! {
//!   "version": 1,
//!   "quant": {"word": 32, "frac_bits": 24},
//!   "models": [
//!     {
//!       "name": "LSTM-AE-F32-D2",
//!       "features": 32,
//!       "depth": 2,
//!       "layers": [32, 16, 32],
//!       "weights": "weights_LSTM-AE-F32-D2.bin",
//!       "timesteps": [1, 2, 4, 6, 16, 64],
//!       "hlo": {"1": "LSTM-AE-F32-D2_T1.hlo.txt", ...},
//!       "train_loss": 0.0012
//!     }, ...
//!   ]
//! }
//! ```

use anyhow::{anyhow, Context, Result};
use std::path::Path;

use crate::util::json::Json;

/// One model's artifact set.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub features: usize,
    pub depth: usize,
    /// Feature chain (depth + 1 entries).
    pub layers: Vec<usize>,
    /// Weights file (relative to the artifact dir).
    pub weights: String,
    /// Sequence lengths with a compiled artifact.
    pub timesteps: Vec<usize>,
    /// T → HLO text file.
    hlo: Vec<(usize, String)>,
    /// Batched serving artifacts: (serving T, batch size → file).
    batch_t: Option<usize>,
    hlo_batch: Vec<(usize, String)>,
    /// Telemetry family spec file (training distribution), if exported.
    pub telemetry: Option<String>,
    /// Final training loss recorded by train.py (for provenance).
    pub train_loss: Option<f64>,
}

impl ArtifactEntry {
    pub fn hlo_for_t(&self, t: usize) -> Option<&str> {
        self.hlo.iter().find(|(tt, _)| *tt == t).map(|(_, f)| f.as_str())
    }

    /// Batched serving artifact for exactly `(t, b)`, if lowered.
    pub fn hlo_for_batch(&self, t: usize, b: usize) -> Option<&str> {
        if self.batch_t != Some(t) {
            return None;
        }
        self.hlo_batch.iter().find(|(bb, _)| *bb == b).map(|(_, f)| f.as_str())
    }

    /// Batch sizes available at the serving T, largest first.
    pub fn batch_sizes(&self, t: usize) -> Vec<usize> {
        if self.batch_t != Some(t) {
            return Vec::new();
        }
        let mut v: Vec<usize> = self.hlo_batch.iter().map(|(b, _)| *b).collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: u64,
    pub models: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::parse(&text).with_context(|| format!("parse {path:?}"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let version =
            v.get("version").and_then(Json::as_u64).ok_or_else(|| anyhow!("missing version"))?;
        let models = v
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing models[]"))?
            .iter()
            .map(parse_entry)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { version, models })
    }

    pub fn find(&self, model: &str) -> Option<&ArtifactEntry> {
        // Accept both full and short names.
        let full = if model.starts_with("LSTM-AE-") {
            model.to_string()
        } else {
            format!("LSTM-AE-{model}")
        };
        self.models.iter().find(|e| e.name == full || e.name == model)
    }
}

fn parse_entry(v: &Json) -> Result<ArtifactEntry> {
    let get_str = |k: &str| -> Result<String> {
        Ok(v.get(k)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("model entry missing {k:?}"))?
            .to_string())
    };
    let name = get_str("name")?;
    let features =
        v.get("features").and_then(Json::as_usize).ok_or_else(|| anyhow!("missing features"))?;
    let depth =
        v.get("depth").and_then(Json::as_usize).ok_or_else(|| anyhow!("missing depth"))?;
    let layers = v
        .get("layers")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing layers"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad layer size")))
        .collect::<Result<Vec<_>>>()?;
    let weights = get_str("weights")?;
    let timesteps = v
        .get("timesteps")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing timesteps"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad timestep")))
        .collect::<Result<Vec<_>>>()?;
    let hlo_obj =
        v.get("hlo").and_then(Json::as_obj).ok_or_else(|| anyhow!("missing hlo map"))?;
    let mut hlo = Vec::new();
    for (k, f) in hlo_obj {
        let t: usize = k.parse().map_err(|_| anyhow!("bad hlo key {k:?}"))?;
        hlo.push((t, f.as_str().ok_or_else(|| anyhow!("bad hlo file"))?.to_string()));
    }
    hlo.sort_by_key(|(t, _)| *t);
    for t in &timesteps {
        if !hlo.iter().any(|(tt, _)| tt == t) {
            return Err(anyhow!("model {name}: timestep {t} listed but no hlo file"));
        }
    }
    let telemetry = v.get("telemetry").and_then(Json::as_str).map(|s| s.to_string());
    let train_loss = v.get("train_loss").and_then(Json::as_f64);
    let (batch_t, hlo_batch) = match v.get("hlo_batch") {
        None => (None, Vec::new()),
        Some(hb) => {
            let t = hb.get("t").and_then(Json::as_usize);
            let mut sizes = Vec::new();
            if let Some(m) = hb.get("sizes").and_then(Json::as_obj) {
                for (k, f) in m {
                    let b: usize = k.parse().map_err(|_| anyhow!("bad batch key {k:?}"))?;
                    sizes.push((
                        b,
                        f.as_str().ok_or_else(|| anyhow!("bad batch file"))?.to_string(),
                    ));
                }
            }
            (t, sizes)
        }
    };
    Ok(ArtifactEntry {
        name,
        features,
        depth,
        layers,
        weights,
        timesteps,
        hlo,
        batch_t,
        hlo_batch,
        telemetry,
        train_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "quant": {"word": 32, "frac_bits": 24},
        "models": [
            {
                "name": "LSTM-AE-F32-D2",
                "features": 32,
                "depth": 2,
                "layers": [32, 16, 32],
                "weights": "weights_LSTM-AE-F32-D2.bin",
                "timesteps": [1, 64],
                "hlo": {"1": "LSTM-AE-F32-D2_T1.hlo.txt", "64": "LSTM-AE-F32-D2_T64.hlo.txt"},
                "train_loss": 0.0012
            }
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        let e = m.find("F32-D2").unwrap();
        assert_eq!(e.features, 32);
        assert_eq!(e.hlo_for_t(64), Some("LSTM-AE-F32-D2_T64.hlo.txt"));
        assert_eq!(e.hlo_for_t(2), None);
        assert_eq!(e.train_loss, Some(0.0012));
        assert!(m.find("LSTM-AE-F32-D2").is_some());
        assert!(m.find("F64-D6").is_none());
    }

    #[test]
    fn rejects_inconsistent_timesteps() {
        let bad = SAMPLE.replace(r#""timesteps": [1, 64]"#, r#""timesteps": [1, 2, 64]"#);
        let err = Manifest::parse(&bad).unwrap_err();
        assert!(format!("{err}").contains("timestep 2"));
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = SAMPLE.replace(r#""features": 32,"#, "");
        assert!(Manifest::parse(&bad).is_err());
    }
}
