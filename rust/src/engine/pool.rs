//! Engine replica pool: N independent [`TemporalPipeline`]s over one
//! model, checked out per batch so concurrent server workers stop
//! serializing on a single pipeline's endpoint lock.
//!
//! A [`TemporalPipeline`] keeps its feed/drain endpoints under one mutex —
//! correct for a single caller, but a worker pool scoring deep
//! single-window batches through `ExecMode::Auto` would serialize there,
//! idling every core but one while per-layer threads of one replica do
//! all the work. The pool owns `replicas` fully independent pipelines
//! (each with its own per-layer worker threads and FIFOs) and hands one
//! out per checkout: least-loaded wins, with a rotating scan start so
//! back-to-back checkouts spread across replicas even without
//! concurrency.
//!
//! Every replica runs the same quantized cells in the same order, so
//! scores are bit-identical regardless of which replica serves a batch —
//! the pool changes timing, never results (the same function/timing
//! independence the hardware dataflow guarantees).
//!
//! The pool is resizable at runtime ([`PipelinePool::set_replicas`], the
//! autoscaler's replica knob): growth spawns fresh replicas under the
//! pool's write lock; shrinkage truncates the slot list, and a removed
//! replica's per-layer threads wind down as soon as the last in-flight
//! checkout holding it drops — checkouts hold an `Arc` to their slot, so
//! resizing never invalidates work already dispatched.

use std::ops::Deref;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use super::pipeline::{PipelineOptions, TemporalPipeline};
use crate::model::LstmAutoencoder;

struct Slot {
    pipe: TemporalPipeline,
    /// Checkouts currently holding this replica.
    inflight: AtomicUsize,
    /// Total checkouts ever served (observability; lets tests assert the
    /// hot path really spreads across replicas).
    uses: AtomicU64,
}

impl Slot {
    fn fresh(ae: Arc<LstmAutoencoder>, opts: PipelineOptions) -> Arc<Slot> {
        Arc::new(Slot {
            pipe: TemporalPipeline::with_options(ae, opts),
            inflight: AtomicUsize::new(0),
            uses: AtomicU64::new(0),
        })
    }
}

/// A pool of interchangeable [`TemporalPipeline`] replicas over one
/// model, resizable at runtime.
pub struct PipelinePool {
    /// The model every replica executes (kept so growth can build more).
    ae: Arc<LstmAutoencoder>,
    opts: PipelineOptions,
    /// Current replica set. Checkout takes the read lock; resizing takes
    /// the write lock, so a resize waits out in-progress checkouts (the
    /// scan, not the scoring — scoring happens after the lock drops).
    slots: RwLock<Vec<Arc<Slot>>>,
    /// Rotating scan start for checkout, so equal-load ties resolve
    /// round-robin instead of always picking replica 0.
    cursor: AtomicUsize,
}

/// A checked-out replica; derefs to the pipeline and returns the replica
/// to the pool (decrements its load) on drop. Holds its slot by `Arc`,
/// so a replica removed by [`PipelinePool::set_replicas`] mid-checkout
/// stays alive (and correct) until this handle drops.
pub struct PooledPipeline {
    slot: Arc<Slot>,
}

impl Deref for PooledPipeline {
    type Target = TemporalPipeline;

    fn deref(&self) -> &TemporalPipeline {
        &self.slot.pipe
    }
}

impl Drop for PooledPipeline {
    fn drop(&mut self) {
        self.slot.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

impl PipelinePool {
    /// Pool of `replicas` pipelines (≥ 1) with default options.
    pub fn new(ae: Arc<LstmAutoencoder>, replicas: usize) -> PipelinePool {
        Self::with_options(ae, replicas, PipelineOptions::default())
    }

    /// Pool with an explicit inter-layer FIFO capacity per replica.
    pub fn with_capacity(
        ae: Arc<LstmAutoencoder>,
        replicas: usize,
        fifo_capacity: usize,
    ) -> PipelinePool {
        Self::with_options(ae, replicas, PipelineOptions { fifo_capacity, ..Default::default() })
    }

    /// Pool with full [`PipelineOptions`] per replica. When pinning is
    /// on, replica *r*'s layers start at core `base + r·depth`, so
    /// replicas tile across the core set instead of stacking every
    /// replica's layer 0 on the same core (assignments wrap modulo the
    /// online core count inside the pipeline).
    pub fn with_options(
        ae: Arc<LstmAutoencoder>,
        replicas: usize,
        opts: PipelineOptions,
    ) -> PipelinePool {
        let pool =
            PipelinePool { ae, opts, slots: RwLock::new(Vec::new()), cursor: AtomicUsize::new(0) };
        {
            let mut slots = pool.slots.write().unwrap();
            for r in 0..replicas.max(1) {
                slots.push(Slot::fresh(pool.ae.clone(), pool.replica_opts(r)));
            }
        }
        pool
    }

    /// Options for replica index `r`: pin bases tile by model depth.
    fn replica_opts(&self, r: usize) -> PipelineOptions {
        PipelineOptions {
            pin_base_core: self
                .opts
                .pin_base_core
                .map(|base| base + r * self.ae.topo.depth),
            ..self.opts
        }
    }

    /// The model every replica executes.
    pub fn model(&self) -> &LstmAutoencoder {
        &self.ae
    }

    /// Number of replicas currently in the pool.
    pub fn replicas(&self) -> usize {
        self.slots.read().unwrap().len()
    }

    /// How many of the current replicas have served at least one
    /// checkout.
    pub fn used_replicas(&self) -> usize {
        self.slots
            .read()
            .unwrap()
            .iter()
            .filter(|s| s.uses.load(Ordering::Relaxed) > 0)
            .count()
    }

    /// Resize the pool to `replicas` pipelines (clamped to ≥ 1), the
    /// autoscaler's replica knob. Growth spawns fresh replicas; shrinkage
    /// drops slots from the scan — replicas still held by in-flight
    /// checkouts finish their work and wind down when released. Returns
    /// the new size.
    pub fn set_replicas(&self, replicas: usize) -> usize {
        let want = replicas.max(1);
        let mut slots = self.slots.write().unwrap();
        while slots.len() < want {
            let r = slots.len();
            slots.push(Slot::fresh(self.ae.clone(), self.replica_opts(r)));
        }
        slots.truncate(want);
        slots.len()
    }

    /// Check out the least-loaded replica (rotating scan start breaks
    /// ties round-robin). The load accounting is advisory — a stale read
    /// picks a busier replica, which costs latency, never correctness.
    pub fn checkout(&self) -> PooledPipeline {
        let slots = self.slots.read().unwrap();
        let n = slots.len();
        let start = self.cursor.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_load = usize::MAX;
        for k in 0..n {
            let i = (start + k) % n;
            let load = slots[i].inflight.load(Ordering::Relaxed);
            if load < best_load {
                best = i;
                best_load = load;
                if load == 0 {
                    break;
                }
            }
        }
        let slot = slots[best].clone();
        slot.inflight.fetch_add(1, Ordering::Relaxed);
        slot.uses.fetch_add(1, Ordering::Relaxed);
        PooledPipeline { slot }
    }

    /// Score one window on a checked-out replica — bit-identical to
    /// [`LstmAutoencoder::score_quant`].
    pub fn score(&self, x: &[Vec<f32>]) -> f64 {
        self.checkout().score(x)
    }

    /// Score a batch back-to-back on one checked-out replica.
    pub fn score_batch(&self, windows: &[&[Vec<f32>]]) -> Vec<f64> {
        self.checkout().score_batch(windows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;
    use crate::util::rng::Xoshiro256;

    fn window(t: usize, f: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut r = Xoshiro256::seeded(seed);
        (0..t).map(|_| (0..f).map(|_| r.uniform(-1.0, 1.0) as f32).collect()).collect()
    }

    #[test]
    fn replicas_are_bit_identical_to_sequential() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 3));
        let pool = PipelinePool::new(ae.clone(), 3);
        let x = window(9, 64, 7);
        let want = ae.score_quant(&x).to_bits();
        // Enough checkouts to cycle through every replica.
        for _ in 0..6 {
            assert_eq!(pool.score(&x).to_bits(), want);
        }
        assert_eq!(pool.used_replicas(), 3, "rotating checkout visits all replicas");
    }

    #[test]
    fn sequential_checkouts_rotate_across_replicas() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let pool = PipelinePool::new(Arc::new(LstmAutoencoder::random(topo, 1)), 2);
        let x = window(2, 32, 1);
        let _ = pool.score(&x);
        let _ = pool.score(&x);
        // Even with zero concurrency the cursor spreads load: two calls
        // must not pile onto one replica.
        assert_eq!(pool.used_replicas(), 2);
    }

    #[test]
    fn checkout_prefers_idle_replicas() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let pool = PipelinePool::new(Arc::new(LstmAutoencoder::random(topo, 2)), 2);
        let a = pool.checkout();
        let b = pool.checkout();
        // With one replica held, the second checkout must take the other.
        assert!(!std::ptr::eq(&*a as *const _, &*b as *const _));
        drop(a);
        drop(b);
        let c = pool.checkout();
        drop(c);
        assert_eq!(pool.used_replicas(), 2);
    }

    #[test]
    fn concurrent_scoring_stays_correct_and_uses_multiple_replicas() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 5));
        let pool = Arc::new(PipelinePool::new(ae.clone(), 4));
        let wins: Vec<Vec<Vec<f32>>> = (0..4).map(|i| window(6, 64, 20 + i)).collect();
        let want: Vec<u64> = wins.iter().map(|w| ae.score_quant(w).to_bits()).collect();
        let mut handles = Vec::new();
        for tid in 0..4usize {
            let pool = pool.clone();
            let wins = wins.clone();
            let want = want.clone();
            handles.push(std::thread::spawn(move || {
                for rep in 0..8 {
                    let i = (tid + rep) % wins.len();
                    assert_eq!(pool.score(&wins[i]).to_bits(), want[i]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(pool.used_replicas() >= 2, "used {}", pool.used_replicas());
    }

    #[test]
    fn resize_preserves_bit_identity_and_inflight_checkouts() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 11));
        let pool = PipelinePool::new(ae.clone(), 2);
        let x = window(5, 64, 3);
        let want = ae.score_quant(&x).to_bits();

        // Hold a checkout across a shrink: the held replica must stay
        // alive and bit-exact even after it leaves the scan. (The first
        // checkout lands on slot 0 and is released; the second lands on
        // slot 1 — exactly the slot the truncate below removes.)
        drop(pool.checkout());
        let held = pool.checkout();
        assert_eq!(pool.set_replicas(1), 1);
        assert_eq!(held.score(&x).to_bits(), want, "held replica survives shrink");
        drop(held);
        assert_eq!(pool.score(&x).to_bits(), want);

        // Grow: fresh replicas run the same cells, same results.
        assert_eq!(pool.set_replicas(3), 3);
        assert_eq!(pool.replicas(), 3);
        for _ in 0..6 {
            assert_eq!(pool.score(&x).to_bits(), want);
        }
        assert_eq!(pool.used_replicas(), 3, "rotation reaches the grown replicas");

        // Shrink clamps at one — a pool never goes empty.
        assert_eq!(pool.set_replicas(0), 1);
        assert_eq!(pool.score(&x).to_bits(), want);
    }

    #[test]
    fn zero_replicas_clamps_to_one() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let pool = PipelinePool::new(Arc::new(LstmAutoencoder::random(topo, 9)), 0);
        assert_eq!(pool.replicas(), 1);
        let x = window(3, 32, 2);
        assert!(pool.score(&x).is_finite());
    }
}
