//! Temporal-pipeline execution engine — the paper's §3.1 dataflow,
//! realized in software for the serving hot path.
//!
//! The accelerator architecture is `DataReader → LSTM_0 → … → LSTM_{N−1}
//! → DataWriter`, every arrow a bounded FIFO of timestep-vector tokens,
//! and every module always running: in steady state module *i* processes
//! timestep *t − i* while its neighbours work on adjacent timesteps.
//! [`crate::accel::dataflow`] simulates that structure cycle-accurately;
//! this module **executes** it in wall-clock terms:
//!
//! ```text
//!  caller (DataReader)      worker threads, one per LSTM layer      caller (DataWriter)
//!  quantize x_t  ──sync_channel──► LSTM_0 ──sync_channel──► … ──channel──► collect h_t
//!                  (bounded FIFO)           (bounded FIFO)    (drain side)
//! ```
//!
//! Three execution paths, all **bit-identical** to
//! [`crate::model::LstmAutoencoder::forward_quant`] (property-tested across random
//! topologies, seeds, and sequence lengths):
//!
//! - [`forward_in_place`] — the sequential scratch path: layer-at-a-time
//!   over the sequence like the original scorer, but with zero per-step
//!   allocation ([`QuantLstmCell::step_into`] + the thread-local
//!   [`ScratchArena`] and in-place row reuse). This is what
//!   `forward_quant` and `DataflowSim::run_with_data` now run on.
//! - [`TemporalPipeline`] — one worker thread per LSTM layer connected by
//!   bounded SPSC channels (`std::sync::mpsc::sync_channel`), so layer
//!   *i* processes timestep *t* while layer *i+1* processes *t−1*. Wins
//!   on deep models (F32-D6/F64-D6), where per-layer work is large enough
//!   to amortize the channel hop; windows fed back-to-back keep every
//!   layer busy across window boundaries (no drain between windows).
//! - [`BatchEngine`] — the MVM → MMM restructure for throughput scoring:
//!   all `B` same-length windows advance together and each weight matrix
//!   row is streamed once per timestep across the whole batch
//!   ([`QuantLstmCell::step_batch_into`]), converting the matrix-vector
//!   products into matrix-matrix products with `B`-fold weight reuse.
//!
//! ## How the server picks a path
//!
//! [`crate::server::QuantBackend`] defaults to [`ExecMode::Auto`]:
//! batches of `B > 1` windows go to the [`BatchEngine`] (grouped by
//! sequence length — batched stepping requires uniform `T`, with
//! singleton length-groups of deep models routed through the pipeline);
//! single windows go to the [`TemporalPipeline`] when the model is deep
//! (`depth ≥ PIPELINE_MIN_DEPTH`), else to the sequential scratch path
//! (shallow models don't amortize the per-token channel hop). The other
//! modes pin one path for deterministic routing. The engine-vs-sequential
//! comparison in `benches/hotpath.rs` (tracked in `BENCH_hotpath.json`
//! and EXPERIMENTS.md §Perf) pins paths one level lower, driving
//! [`TemporalPipeline`] and [`BatchEngine`] directly against the
//! sequential scorer.
//!
//! Note the regime split this encodes: `B == 1` reaches the backend only
//! when the batcher found nothing to coalesce — light load, where
//! per-request latency is the objective and the pipeline's layer overlap
//! shortens it. Under heavy load the batcher forms `B > 1` batches and
//! Auto switches to the batched kernel, whose weight reuse maximizes
//! throughput. When several server workers do score single windows
//! concurrently (many independent lanes, `max_batch == 1` operators),
//! they no longer serialize on one pipeline's endpoint lock: the backend
//! checks replicas out of a [`PipelinePool`] — N independent pipelines
//! over the same cells, least-loaded first — so the only remaining
//! serialization is within one replica, by construction.

pub mod batch;
pub mod pipeline;
pub mod pool;
pub mod session;

pub use batch::BatchEngine;
pub use pipeline::{PipelineOptions, TemporalPipeline};
pub use pool::{PipelinePool, PooledPipeline};
pub use session::{step_session, step_sessions_batch, SessionState};

use crate::fixed::Q8_24;
use crate::model::lstm::{with_thread_arena, QuantLstmCell, ScratchArena};

/// Minimum model depth at which [`ExecMode::Auto`] routes single-window
/// scoring through the [`TemporalPipeline`]: with fewer layers the
/// pipeline has too few stages for the channel-hop overhead to pay off.
pub const PIPELINE_MIN_DEPTH: usize = 4;

/// Which execution path [`crate::server::QuantBackend`] uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// `B > 1` → batched, deep single windows → pipelined, else
    /// sequential (see module docs).
    Auto,
    /// Layer-at-a-time scratch path for every window (the pre-engine
    /// behaviour, kept as the comparison baseline).
    Sequential,
    /// Per-layer worker pipeline for every request.
    Pipelined,
    /// Batched MMM kernel for every request (single windows degenerate
    /// to the sequential path — a batch of one has no weight reuse).
    Batched,
}

impl ExecMode {
    /// Parse an operator-facing mode name (CLI `--mode` flag). Accepts
    /// the canonical names plus common short forms; `None` on anything
    /// else so callers can report the valid set.
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(ExecMode::Auto),
            "sequential" | "seq" => Some(ExecMode::Sequential),
            "pipelined" | "pipeline" | "pipe" => Some(ExecMode::Pipelined),
            "batched" | "batch" => Some(ExecMode::Batched),
            _ => None,
        }
    }
}

/// Quantize a `[T][F]` window onto the Q8.24 grid — the DataReader
/// boundary of every engine path.
pub fn quantize_window(x: &[Vec<f32>]) -> Vec<Vec<Q8_24>> {
    x.iter().map(|row| row.iter().map(|&v| Q8_24::from_f32(v)).collect()).collect()
}

/// Dequantize a `[T][F]` quantized sequence back to f32 — the DataWriter
/// boundary.
pub fn dequantize_window(seq: Vec<Vec<Q8_24>>) -> Vec<Vec<f32>> {
    seq.into_iter().map(|row| row.iter().map(|q| q.to_f32()).collect()).collect()
}

/// Stream a quantized `[T][·]` sequence through the layer stack **in
/// place** with zero per-step allocation: one state and one scratch are
/// reused across all timesteps and layers, and each row's buffer is
/// rewritten with the layer's hidden output (row capacity is `F` from
/// the input and every layer width in the chain is ≤ `F`, so rewrites
/// never reallocate). Bit-identical to the original
/// layer-at-a-time/step-at-a-time scorer — same per-element arithmetic
/// in the same order.
pub fn forward_in_place(cells: &[QuantLstmCell], seq: &mut [Vec<Q8_24>]) {
    with_thread_arena(|arena| forward_in_place_with(cells, seq, arena));
}

/// [`forward_in_place`] with a caller-owned [`ScratchArena`] — for workers
/// (pipeline stages, benches) that hold their own arena instead of going
/// through the thread-local one.
pub fn forward_in_place_with(
    cells: &[QuantLstmCell],
    seq: &mut [Vec<Q8_24>],
    arena: &mut ScratchArena,
) {
    for cell in cells {
        arena.state.reset(cell.w.dims.lh);
        for xt in seq.iter_mut() {
            cell.step_into(&mut arena.state, xt, &mut arena.step);
            xt.clear();
            xt.extend_from_slice(&arena.state.h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LstmAutoencoder, Topology};
    use crate::util::rng::Xoshiro256;

    fn window(t: usize, f: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut r = Xoshiro256::seeded(seed);
        (0..t).map(|_| (0..f).map(|_| r.uniform(-1.0, 1.0) as f32).collect()).collect()
    }

    #[test]
    fn in_place_path_matches_step_by_step_reference() {
        // Reference: the original allocating recurrence, written out
        // longhand so this test does not depend on forward_quant's
        // implementation (which itself now calls forward_in_place).
        let topo = Topology::from_name("F32-D6").unwrap();
        let ae = LstmAutoencoder::random(topo, 42);
        let x = window(7, 32, 43);
        let mut seq = quantize_window(&x);
        forward_in_place(ae.quant_cells(), &mut seq);

        let mut want = quantize_window(&x);
        for cell in ae.quant_cells() {
            let mut state = QuantLstmState::zeros(cell.w.dims.lh);
            let mut out = Vec::new();
            for xt in &want {
                state = cell.step(&state, xt);
                out.push(state.h.clone());
            }
            want = out;
        }
        assert_eq!(seq, want);
    }

    #[test]
    fn quantize_dequantize_roundtrip_on_grid() {
        let x = window(3, 8, 7);
        let q = quantize_window(&x);
        let back = dequantize_window(q.clone());
        // Dequantized values must re-quantize to the same grid points.
        assert_eq!(quantize_window(&back), q);
    }

    #[test]
    fn empty_sequence_is_a_no_op() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo, 1);
        let mut seq: Vec<Vec<Q8_24>> = Vec::new();
        forward_in_place(ae.quant_cells(), &mut seq);
        assert!(seq.is_empty());
    }
}
