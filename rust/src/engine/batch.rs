//! Batched MMM execution: `B` same-length windows advance through the
//! layer stack together, so each weight matrix streams through the cache
//! **once per timestep** instead of once per window — the MVM → MMM
//! restructure of the serving throughput path (see [`super`] module docs
//! for when the server picks this over the pipeline).
//!
//! Memory discipline: all working storage — the two flat `[T][B][width]`
//! double buffers, the two flat `[B][LH]` state planes, and the kernel
//! pre-activation scratch — lives in a [`ScratchArena`] (the caller's, or
//! the thread-local one), so repeated batches on one worker thread do
//! zero steady-state allocation. All per-window arithmetic is
//! [`crate::model::lstm::QuantLstmCell::step_batch_into`], which is bit-identical to the
//! sequential cell step, so batched scores equal
//! [`LstmAutoencoder::score_quant`] exactly.

use std::sync::Arc;

use crate::fixed::Q8_24;
use crate::model::lstm::{with_thread_arena, ScratchArena};
use crate::model::LstmAutoencoder;

/// Batched scorer over one model. Cheap to construct (shares the model's
/// quantized cells via `Arc`); holds no threads and no state between
/// calls, so it is freely shared across server workers.
pub struct BatchEngine {
    ae: Arc<LstmAutoencoder>,
}

impl BatchEngine {
    pub fn new(ae: Arc<LstmAutoencoder>) -> BatchEngine {
        BatchEngine { ae }
    }

    /// The model this engine executes.
    pub fn model(&self) -> &LstmAutoencoder {
        &self.ae
    }

    /// Forward a batch of windows that all share the same sequence
    /// length `T` (asserted). Returns per-window reconstructions,
    /// bit-identical to running [`LstmAutoencoder::forward_quant`] on
    /// each window alone. Callers with mixed lengths group by `T` first
    /// (`QuantBackend` does).
    pub fn forward_batch(&self, windows: &[&[Vec<f32>]]) -> Vec<Vec<Vec<f32>>> {
        with_thread_arena(|arena| self.forward_batch_with(windows, arena))
    }

    /// [`Self::forward_batch`] with a caller-owned [`ScratchArena`]: the
    /// engine borrows `arena.cur`/`arena.next` as the `[T][B][width]`
    /// double buffer, `arena.h`/`arena.c` as the state planes, and
    /// `arena.step` for the kernel pre-activations. The `h`/`c` planes
    /// are semantically re-zeroed per layer (initial LSTM state); the
    /// double buffers are write-before-read and only grow.
    pub fn forward_batch_with(
        &self,
        windows: &[&[Vec<f32>]],
        arena: &mut ScratchArena,
    ) -> Vec<Vec<Vec<f32>>> {
        let b = windows.len();
        if b == 0 {
            return Vec::new();
        }
        let t = windows[0].len();
        for w in windows {
            assert_eq!(w.len(), t, "batched windows must share T");
        }
        let f = self.ae.topo.features;
        if t == 0 {
            return vec![Vec::new(); b];
        }
        // Quantize into the flat [T][B][F] input buffer (timestep-major,
        // window-minor: one timestep's batch is contiguous for the MMM).
        arena.cur.clear();
        arena.cur.reserve(t * b * f);
        for ts in 0..t {
            for w in windows {
                let row = &w[ts];
                assert_eq!(row.len(), f, "window feature width matches the model");
                arena.cur.extend(row.iter().map(|&v| Q8_24::from_f32(v)));
            }
        }
        for cell in self.ae.quant_cells() {
            let lx = cell.w.dims.lx;
            let lh = cell.w.dims.lh;
            arena.h.clear();
            arena.h.resize(b * lh, Q8_24::ZERO);
            arena.c.clear();
            arena.c.resize(b * lh, Q8_24::ZERO);
            // Output buffer is fully overwritten timestep by timestep
            // below, so no clear() — resize only adjusts the length.
            arena.next.resize(t * b * lh, Q8_24::ZERO);
            for ts in 0..t {
                let x = &arena.cur[ts * b * lx..(ts + 1) * b * lx];
                cell.step_batch_into(b, &mut arena.h, &mut arena.c, x, &mut arena.step);
                arena.next[ts * b * lh..(ts + 1) * b * lh].copy_from_slice(&arena.h);
            }
            std::mem::swap(&mut arena.cur, &mut arena.next);
        }
        // Last layer's width is the feature width (topology invariant);
        // scatter back to [B][T][F] and dequantize.
        let cur = &arena.cur;
        (0..b)
            .map(|wi| {
                (0..t)
                    .map(|ts| {
                        cur[(ts * b + wi) * f..(ts * b + wi + 1) * f]
                            .iter()
                            .map(|q| q.to_f32())
                            .collect()
                    })
                    .collect()
            })
            .collect()
    }

    /// Batched anomaly scores — bit-identical to
    /// [`LstmAutoencoder::score_quant`] per window.
    pub fn score_batch(&self, windows: &[&[Vec<f32>]]) -> Vec<f64> {
        let recons = self.forward_batch(windows);
        windows.iter().zip(&recons).map(|(w, r)| LstmAutoencoder::mse(w, r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;
    use crate::util::prop::props;
    use crate::util::rng::Xoshiro256;

    fn window(t: usize, f: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut r = Xoshiro256::seeded(seed);
        (0..t).map(|_| (0..f).map(|_| r.uniform(-1.0, 1.0) as f32).collect()).collect()
    }

    #[test]
    fn batch_matches_per_window_forward() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 11));
        let eng = BatchEngine::new(ae.clone());
        let wins: Vec<Vec<Vec<f32>>> = (0..5).map(|i| window(9, 64, 100 + i)).collect();
        let refs: Vec<&[Vec<f32>]> = wins.iter().map(|w| w.as_slice()).collect();
        let got = eng.forward_batch(&refs);
        for (i, w) in wins.iter().enumerate() {
            assert_eq!(got[i], ae.forward_quant(w), "window {i}");
        }
    }

    #[test]
    fn batch_of_one_and_t_of_one() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 2));
        let eng = BatchEngine::new(ae.clone());
        let w = window(1, 32, 3);
        let got = eng.forward_batch(&[&w]);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], ae.forward_quant(&w));
    }

    #[test]
    fn empty_batch_is_empty() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let eng = BatchEngine::new(Arc::new(LstmAutoencoder::random(topo, 1)));
        assert!(eng.forward_batch(&[]).is_empty());
        assert!(eng.score_batch(&[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "share T")]
    fn mixed_lengths_rejected() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let eng = BatchEngine::new(Arc::new(LstmAutoencoder::random(topo, 1)));
        let a = window(4, 32, 1);
        let b = window(5, 32, 2);
        eng.forward_batch(&[&a, &b]);
    }

    #[test]
    fn scores_bit_identical_to_sequential() {
        props("batch_scores", 16, |g| {
            let f = 1usize << g.usize_in(3, 5);
            let d = 2 * g.usize_in(1, 3);
            let Ok(topo) = Topology::new(f, d) else { return };
            let ae = Arc::new(LstmAutoencoder::random(topo, g.case as u64 + 40));
            let eng = BatchEngine::new(ae.clone());
            let t = g.usize_in(1, 10);
            let b = g.usize_in(1, 6);
            let wins: Vec<Vec<Vec<f32>>> = (0..b)
                .map(|_| (0..t).map(|_| g.vec_f32(f, -1.5, 1.5)).collect())
                .collect();
            let refs: Vec<&[Vec<f32>]> = wins.iter().map(|w| w.as_slice()).collect();
            let scores = eng.score_batch(&refs);
            for (i, w) in wins.iter().enumerate() {
                assert_eq!(scores[i].to_bits(), ae.score_quant(w).to_bits(), "window {i}");
            }
        });
    }
}
