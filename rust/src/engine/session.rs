//! Streaming sessions: incremental scoring with carried LSTM state.
//!
//! Every other engine path consumes a complete `[T][F]` window and runs
//! all `T` timesteps from zero state. A **session** instead carries the
//! per-layer quantized h/c state across calls, so each arriving sample
//! advances the whole layer stack by exactly one timestep — the
//! O(T) → O(1) per-sample restructure of the serving hot path, and the
//! software analog of the paper's always-resident recurrent datapath
//! (the accelerator never re-fills its pipeline between timesteps of a
//! live feed; neither does a session).
//!
//! ```text
//!             sample x_k (one [F] row)
//!                  │ quantize (Q8.24)
//!                  ▼
//!   ┌─ LSTM_0 ────┐  h/c carried    ┌─ LSTM_1 ────┐        ┌─ LSTM_{N−1} ┐
//!   │ step_into   │ ──────────────► │ step_into   │ ─ … ─► │ step_into   │
//!   │ (state[0])  │  from step k−1  │ (state[1])  │        │ (state[N−1])│
//!   └─────────────┘                 └─────────────┘        └──────┬──────┘
//!                                                                 │ dequantize
//!                      ring of the last W (input, recon) rows ◄───┘
//!                      score = flat MSE over the ring
//! ```
//!
//! # Bit-identity contract
//!
//! The step path is **bit-identical** to re-running the session's entire
//! sample history through [`crate::model::LstmAutoencoder::forward_quant`]
//! from zero state: the per-timestep arithmetic is
//! [`QuantLstmCell::step_into`] either way, and traversal order does not
//! matter for integer recurrences whose layer-`i` output at timestep `t`
//! depends only on inputs `0..=t` (the property
//! `incremental_scores_match_full_rescore_on_all_paper_topologies`
//! pins this down, window by window). The session score after `k` steps
//! equals the flat-order MSE over the **last `min(k, W)`** (input,
//! reconstruction) row pairs — exactly what an `ExecMode::Sequential`
//! re-run of the full history followed by a trailing-window MSE produces,
//! down to f64 association order (the ring stores rows, never
//! pre-reduced per-row partials, precisely so the accumulation order
//! matches [`LstmAutoencoder::mse`]).
//!
//! The batched entry [`step_sessions_batch`] advances `B` distinct
//! sessions of one model together through
//! [`QuantLstmCell::step_batch_into`] — per-session results are
//! bit-identical to `B` separate [`step_session`] calls (the kernel-level
//! property `step_batch_into_bit_identical_per_window` lifts directly).

use std::collections::VecDeque;

use crate::fixed::Q8_24;
use crate::model::lstm::{with_thread_arena, QuantLstmCell, QuantLstmState};
use crate::model::LstmAutoencoder;

/// Carried state of one stream session: per-layer quantized h/c planes
/// plus the sliding ring of recent (input, reconstruction) rows the
/// score is computed over.
///
/// Snapshot semantics: the layer states are exactly the
/// [`QuantLstmState`]s a sequential forward pass over the session's full
/// sample history would hold after its last timestep, so a session can
/// be advanced by any mix of [`step_session`] and [`step_sessions_batch`]
/// calls without ever diverging from the full re-run.
#[derive(Clone, Debug)]
pub struct SessionState {
    /// One carried h/c state per LSTM layer, in stack order.
    layers: Vec<QuantLstmState>,
    /// The last ≤ `window` (input row, reconstruction row) pairs, oldest
    /// first — f32 rows, so the score recomputes with the exact flat
    /// element order of [`LstmAutoencoder::mse`].
    ring: VecDeque<(Vec<f32>, Vec<f32>)>,
    /// Sliding-window length `W` the score covers.
    window: usize,
    /// Samples consumed since open (or the last [`Self::reset`]).
    steps: u64,
}

impl SessionState {
    /// A fresh session over `ae`'s layer stack scoring a sliding window
    /// of `window` samples (clamped to ≥ 1). All-zero state: the first
    /// `step` behaves exactly like timestep 0 of a cold window.
    pub fn new(ae: &LstmAutoencoder, window: usize) -> SessionState {
        SessionState {
            layers: ae
                .quant_cells()
                .iter()
                .map(|cell| QuantLstmState::zeros(cell.w.dims.lh))
                .collect(),
            ring: VecDeque::with_capacity(window.max(1)),
            window: window.max(1),
            steps: 0,
        }
    }

    /// Zero every layer state and drop the ring — the documented
    /// **failover reset semantic**: a session reopened on another shard
    /// (or re-created after eviction) starts cold, exactly as if newly
    /// opened, and its next scores are those of a fresh stream.
    pub fn reset(&mut self) {
        for st in &mut self.layers {
            let lh = st.h.len();
            st.reset(lh);
        }
        self.ring.clear();
        self.steps = 0;
    }

    /// Samples consumed since open or the last [`Self::reset`].
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// The sliding-window length `W` the score covers.
    pub fn window(&self) -> usize {
        self.window
    }

    /// The current score: MSE over the ring's ≤ `W` (input,
    /// reconstruction) row pairs, oldest first, accumulated in the exact
    /// flat element order of [`LstmAutoencoder::mse`] (one f64
    /// accumulator across all elements — never per-row partials, which
    /// would change f64 association and break bit-identity with the
    /// full-window re-run). Zero while no sample has arrived.
    pub fn score(&self) -> f64 {
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for (x, r) in &self.ring {
            for (&u, &v) in x.iter().zip(r) {
                let d = (u - v) as f64;
                sum += d * d;
                n += 1;
            }
        }
        sum / n.max(1) as f64
    }

    fn push_pair(&mut self, input: Vec<f32>, recon: Vec<f32>) {
        self.ring.push_back((input, recon));
        if self.ring.len() > self.window {
            self.ring.pop_front();
        }
        self.steps += 1;
    }
}

/// Advance one session by one sample and return the updated sliding
/// score — the O(1)-per-sample sequential path.
///
/// `sample` must be one `[F]` row at `ae`'s feature width. The sample is
/// quantized at the DataReader boundary, stepped through every layer
/// with [`QuantLstmCell::step_into`] against the session's carried
/// state, and the last layer's hidden row (the reconstruction, as in
/// every other path) is dequantized into the scoring ring.
pub fn step_session(ae: &LstmAutoencoder, state: &mut SessionState, sample: &[f32]) -> f64 {
    let cells = ae.quant_cells();
    assert_eq!(state.layers.len(), cells.len(), "session state is for a different model");
    assert_eq!(sample.len(), ae.topo.features, "sample width must match the model");
    let recon = with_thread_arena(|arena| {
        arena.cur.clear();
        arena.cur.extend(sample.iter().map(|&v| Q8_24::from_f32(v)));
        for (cell, st) in cells.iter().zip(state.layers.iter_mut()) {
            cell.step_into(st, &arena.cur, &mut arena.step);
            arena.cur.clear();
            arena.cur.extend_from_slice(&st.h);
        }
        state.layers.last().expect("at least one layer").h_f32()
    });
    state.push_pair(sample.to_vec(), recon);
    state.score()
}

/// Advance `B` **distinct** sessions of one model by one sample each and
/// return their updated sliding scores — the batched path the server's
/// batcher groups same-lane session steps into.
///
/// Layer by layer, the sessions' carried h/c rows are gathered into the
/// `[B][LH]` planes [`QuantLstmCell::step_batch_into`] expects, stepped
/// once (each weight row streamed once across all `B` sessions — the
/// same MVM → MMM weight reuse as the window batch engine), and
/// scattered back. Per-session results are bit-identical to `B`
/// separate [`step_session`] calls.
///
/// Callers must pass pairwise-distinct sessions (aliasing is impossible
/// through `&mut`) belonging to the same `ae`; `states` and `samples`
/// must be equal-length. Empty input is a no-op.
pub fn step_sessions_batch(
    ae: &LstmAutoencoder,
    states: &mut [&mut SessionState],
    samples: &[&[f32]],
) -> Vec<f64> {
    let b = states.len();
    assert_eq!(b, samples.len(), "one sample per session");
    if b == 0 {
        return Vec::new();
    }
    if b == 1 {
        return vec![step_session(ae, states[0], samples[0])];
    }
    let cells = ae.quant_cells();
    for st in states.iter() {
        assert_eq!(st.layers.len(), cells.len(), "session state is for a different model");
    }
    let recons: Vec<Vec<f32>> = with_thread_arena(|arena| {
        // x plane, `[B][F]` row-major at the input boundary.
        arena.cur.clear();
        for s in samples {
            assert_eq!(s.len(), ae.topo.features, "sample width must match the model");
            arena.cur.extend(s.iter().map(|&v| Q8_24::from_f32(v)));
        }
        for (li, cell) in cells.iter().enumerate() {
            let lh = cell.w.dims.lh;
            // Gather carried h/c into `[B][LH]` planes…
            arena.h.clear();
            arena.c.clear();
            for st in states.iter() {
                arena.h.extend_from_slice(&st.layers[li].h);
                arena.c.extend_from_slice(&st.layers[li].c);
            }
            cell.step_batch_into(b, &mut arena.h, &mut arena.c, &arena.cur, &mut arena.step);
            // …scatter the advanced state back…
            for (wi, st) in states.iter_mut().enumerate() {
                st.layers[li].h.copy_from_slice(&arena.h[wi * lh..(wi + 1) * lh]);
                st.layers[li].c.copy_from_slice(&arena.c[wi * lh..(wi + 1) * lh]);
            }
            // …and the h plane becomes the next layer's x plane.
            arena.cur.clear();
            arena.cur.extend_from_slice(&arena.h);
        }
        states.iter().map(|st| st.layers.last().expect("at least one layer").h_f32()).collect()
    });
    states
        .iter_mut()
        .zip(samples.iter().zip(recons))
        .map(|(st, (s, recon))| {
            st.push_pair(s.to_vec(), recon);
            st.score()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;
    use crate::util::rng::Xoshiro256;

    fn samples(n: usize, f: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut r = Xoshiro256::seeded(seed);
        (0..n).map(|_| (0..f).map(|_| r.uniform(-1.0, 1.0) as f32).collect()).collect()
    }

    /// The full-rescore reference: run the entire `k`-sample history
    /// through the zero-state sequential path and take the flat MSE over
    /// the trailing `min(k, w)` rows — the O(T) baseline a session's
    /// O(1) step must reproduce bit for bit.
    fn rescore_reference(ae: &LstmAutoencoder, history: &[Vec<f32>], w: usize) -> f64 {
        let recon = ae.forward_quant(history);
        let tail = history.len().saturating_sub(w);
        LstmAutoencoder::mse(&history[tail..], &recon[tail..])
    }

    #[test]
    fn incremental_scores_match_full_rescore_on_all_paper_topologies() {
        for topo in Topology::paper_models() {
            let f = topo.features;
            let ae = LstmAutoencoder::random(topo.clone(), 42);
            let w = 6;
            let mut sess = SessionState::new(&ae, w);
            let hist = samples(2 * w + 3, f, 0xD0 + f as u64);
            for k in 0..hist.len() {
                let score = step_session(&ae, &mut sess, &hist[k]);
                let want = rescore_reference(&ae, &hist[..=k], w);
                assert_eq!(
                    score.to_bits(),
                    want.to_bits(),
                    "{}: step {k} diverged from the full rescore",
                    ae.topo.name
                );
                assert_eq!(score.to_bits(), sess.score().to_bits());
            }
            assert_eq!(sess.steps(), hist.len() as u64);
        }
    }

    #[test]
    fn batched_stepping_is_bit_identical_to_sequential_stepping() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let f = topo.features;
        let ae = LstmAutoencoder::random(topo, 7);
        let b = 5;
        let mut solo: Vec<SessionState> =
            (0..b).map(|_| SessionState::new(&ae, 4)).collect();
        let mut grouped: Vec<SessionState> =
            (0..b).map(|_| SessionState::new(&ae, 4)).collect();
        for step in 0..9 {
            let rows: Vec<Vec<f32>> =
                (0..b).map(|i| samples(1, f, 100 * step + i as u64).remove(0)).collect();
            let solo_scores: Vec<f64> = solo
                .iter_mut()
                .zip(&rows)
                .map(|(st, row)| step_session(&ae, st, row))
                .collect();
            let mut refs: Vec<&mut SessionState> = grouped.iter_mut().collect();
            let row_refs: Vec<&[f32]> = rows.iter().map(Vec::as_slice).collect();
            let grouped_scores = step_sessions_batch(&ae, &mut refs, &row_refs);
            for (i, (a, g)) in solo_scores.iter().zip(&grouped_scores).enumerate() {
                assert_eq!(a.to_bits(), g.to_bits(), "session {i} at step {step}");
            }
        }
        for (a, g) in solo.iter().zip(&grouped) {
            assert_eq!(a.layers.len(), g.layers.len());
            for (la, lg) in a.layers.iter().zip(&g.layers) {
                assert_eq!(la.h, lg.h);
                assert_eq!(la.c, lg.c);
            }
        }
    }

    #[test]
    fn reset_restores_a_cold_session() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo, 3);
        let rows = samples(5, 32, 11);
        let mut warm = SessionState::new(&ae, 3);
        for row in &rows {
            step_session(&ae, &mut warm, row);
        }
        warm.reset();
        assert_eq!(warm.steps(), 0);
        assert_eq!(warm.score().to_bits(), 0.0f64.to_bits());
        let mut cold = SessionState::new(&ae, 3);
        for row in &rows {
            let a = step_session(&ae, &mut warm, row);
            let b = step_session(&ae, &mut cold, row);
            assert_eq!(a.to_bits(), b.to_bits(), "reset must reproduce a fresh session");
        }
    }

    #[test]
    fn empty_and_singleton_batches_are_handled() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo, 9);
        assert!(step_sessions_batch(&ae, &mut [], &[]).is_empty());
        let row = samples(1, 32, 1).remove(0);
        let mut a = SessionState::new(&ae, 2);
        let mut b = SessionState::new(&ae, 2);
        let got = step_sessions_batch(&ae, &mut [&mut a], &[&row]);
        let want = step_session(&ae, &mut b, &row);
        assert_eq!(got[0].to_bits(), want.to_bits());
    }
}
