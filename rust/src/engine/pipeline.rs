//! The temporal pipeline: one worker thread per LSTM layer, bounded SPSC
//! channels between them — the software realization of the paper's §3.1
//! module graph (see [`super`] for the architecture diagram).
//!
//! Protocol on every channel, in order per window:
//! `Begin(T)` (reset layer state, forwarded downstream), then `T` ×
//! `Step(x_t)` (compute `h_t`, forward it), with `Stop` propagated once
//! at teardown. Because each worker consumes tokens in FIFO order and
//! the arithmetic per token is [`QuantLstmCell::step_into`], the output
//! is bit-identical to the sequential scorer regardless of thread
//! scheduling — timing and function are independent, exactly as in the
//! hardware dataflow.
//!
//! Deadlock freedom: the inter-layer channels are bounded (the FIFOs),
//! but the final hop into the collector is unbounded, so every worker's
//! send eventually succeeds and the feeding caller always makes
//! progress even when it enqueues an entire batch before collecting.

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::fixed::Q8_24;
use crate::model::lstm::{QuantLstmCell, QuantLstmState, StepScratch};
use crate::model::LstmAutoencoder;
use crate::util::affinity;

/// Default capacity, in timestep tokens, of each inter-layer FIFO.
/// Mirrors the simulator's `SimOptions::fifo_capacity` role; a little
/// deeper than the hardware's 2 to absorb OS scheduling jitter.
pub const DEFAULT_FIFO_CAPACITY: usize = 8;

/// Cap on recycled timestep-vector buffers kept in the endpoint free
/// list; drained tokens beyond this just deallocate. Sized to hold a
/// large batch's worth of tokens without letting a one-off huge batch
/// pin memory forever.
const TOKEN_POOL_MAX: usize = 4096;

/// Construction-time knobs for a [`TemporalPipeline`] (and, via the
/// replica pool and `QuantBackend`, for the whole serving stack).
#[derive(Clone, Copy, Debug)]
pub struct PipelineOptions {
    /// Capacity, in timestep tokens, of each inter-layer FIFO (≥ 1;
    /// clamped at construction).
    pub fifo_capacity: usize,
    /// `Some(base)` pins the worker thread of layer *i* to core
    /// `(base + i) % available_cores()`, so adjacent stages sit on
    /// neighbouring cores and the layer *i* → *i+1* token handoff stops
    /// bouncing cache lines across the package. Pinning is best-effort
    /// (see [`affinity::pin_to_core`]): a refused pin runs unpinned, and
    /// results are bit-identical either way.
    pub pin_base_core: Option<usize>,
}

impl Default for PipelineOptions {
    fn default() -> PipelineOptions {
        PipelineOptions { fifo_capacity: DEFAULT_FIFO_CAPACITY, pin_base_core: None }
    }
}

enum Token {
    /// A new window of `T` timesteps begins: reset layer state.
    Begin(usize),
    /// One timestep vector.
    Step(Vec<Q8_24>),
    /// Teardown; forwarded downstream so the whole chain unwinds.
    Stop,
}

/// A worker's downstream edge: bounded FIFO between layers, unbounded
/// into the collector.
enum Downstream {
    Fifo(SyncSender<Token>),
    Sink(Sender<Token>),
}

impl Downstream {
    fn send(&self, tok: Token) -> Result<(), ()> {
        match self {
            Downstream::Fifo(tx) => tx.send(tok).map_err(|_| ()),
            Downstream::Sink(tx) => tx.send(tok).map_err(|_| ()),
        }
    }
}

/// The caller-side endpoints (DataReader feed + DataWriter drain). Held
/// under one lock so concurrent `forward_*` calls serialize per window
/// batch while the layer workers themselves stay concurrent.
struct Io {
    tx: SyncSender<Token>,
    rx: Receiver<Token>,
    /// Free list of recycled timestep-vector buffers: drained `Step`
    /// tokens land here and the next feed pops them instead of
    /// allocating — in steady-state serving, feeding a window costs zero
    /// allocations once the pool has warmed up. Buffers carry stale
    /// contents; the feed path clears before filling (write-before-read
    /// at the token boundary).
    spare: Vec<Vec<Q8_24>>,
}

/// A running per-layer worker pipeline over one model's quantized cells.
///
/// Construction spawns `depth` threads; they live until the pipeline is
/// dropped. `forward_batch` feeds windows back-to-back, so consecutive
/// windows overlap inside the pipe the same way consecutive timesteps
/// do — the serving analog of the accelerator never draining between
/// sequences.
pub struct TemporalPipeline {
    ae: Arc<LstmAutoencoder>,
    io: Mutex<Io>,
    workers: Vec<JoinHandle<()>>,
}

impl TemporalPipeline {
    pub fn new(ae: Arc<LstmAutoencoder>) -> TemporalPipeline {
        Self::with_options(ae, PipelineOptions::default())
    }

    /// Build with an explicit inter-layer FIFO capacity (≥ 1).
    pub fn with_capacity(ae: Arc<LstmAutoencoder>, fifo_capacity: usize) -> TemporalPipeline {
        Self::with_options(ae, PipelineOptions { fifo_capacity, ..Default::default() })
    }

    /// Build with full [`PipelineOptions`] (FIFO capacity + stage core
    /// pinning).
    pub fn with_options(ae: Arc<LstmAutoencoder>, opts: PipelineOptions) -> TemporalPipeline {
        let cap = opts.fifo_capacity.max(1);
        let depth = ae.topo.depth;
        assert!(depth >= 1, "pipeline needs at least one layer");
        let (in_tx, in_rx) = sync_channel::<Token>(cap);
        let (sink_tx, sink_rx) = channel::<Token>();
        let mut workers = Vec::with_capacity(depth);
        let mut rx_opt = Some(in_rx);
        for layer in 0..depth {
            let rx = rx_opt.take().expect("one receiver per layer");
            let down = if layer + 1 == depth {
                Downstream::Sink(sink_tx.clone())
            } else {
                let (tx, next_rx) = sync_channel::<Token>(cap);
                rx_opt = Some(next_rx);
                Downstream::Fifo(tx)
            };
            let ae_ref = ae.clone();
            let pin = opts.pin_base_core.map(|base| (base + layer) % affinity::available_cores());
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lstm-pipe-{layer}"))
                    .spawn(move || {
                        if let Some(core) = pin {
                            // Best-effort: a refused pin runs unpinned.
                            let _ = affinity::pin_to_core(core);
                        }
                        worker_loop(&ae_ref, layer, rx, down)
                    })
                    .expect("spawn pipeline worker"),
            );
        }
        drop(sink_tx); // the last worker holds the only remaining clone
        TemporalPipeline {
            ae,
            io: Mutex::new(Io { tx: in_tx, rx: sink_rx, spare: Vec::new() }),
            workers,
        }
    }

    /// The model this pipeline executes.
    pub fn model(&self) -> &LstmAutoencoder {
        &self.ae
    }

    /// Run one window through the pipeline; bit-identical to
    /// [`LstmAutoencoder::forward_quant`].
    pub fn forward_quant(&self, x: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.forward_batch(&[x]).pop().expect("one window in, one out")
    }

    /// Run a batch of windows back-to-back through the pipeline (windows
    /// may have different lengths). Feeding is decoupled from collection
    /// by the unbounded drain channel, so the whole batch is enqueued
    /// first and window *k+1* streams in while *k* is still in flight.
    ///
    /// Panics on malformed input (row width ≠ model feature width) —
    /// checked *before* anything is fed or the endpoint lock is taken, so
    /// a bad window kills only the calling thread and the shared pipeline
    /// stays healthy for every other caller.
    pub fn forward_batch(&self, windows: &[&[Vec<f32>]]) -> Vec<Vec<Vec<f32>>> {
        let f = self.ae.topo.features;
        for (wi, w) in windows.iter().enumerate() {
            for row in w.iter() {
                assert_eq!(row.len(), f, "window {wi} feature width matches the model");
            }
        }
        let mut io = self.io.lock().expect("pipeline lock");
        for w in windows {
            io.tx.send(Token::Begin(w.len())).expect("pipeline alive");
            for row in w.iter() {
                // Recycle a drained token buffer when one is spare
                // (stale contents are cleared before the refill).
                let mut xq = io.spare.pop().unwrap_or_default();
                xq.clear();
                xq.extend(row.iter().map(|&v| Q8_24::from_f32(v)));
                io.tx.send(Token::Step(xq)).expect("pipeline alive");
            }
        }
        let mut out = Vec::with_capacity(windows.len());
        for _ in windows {
            let t = match io.rx.recv().expect("pipeline alive") {
                Token::Begin(t) => t,
                _ => unreachable!("protocol: Begin precedes steps"),
            };
            let mut recon = Vec::with_capacity(t);
            for _ in 0..t {
                match io.rx.recv().expect("pipeline alive") {
                    Token::Step(h) => {
                        recon.push(h.iter().map(|q| q.to_f32()).collect());
                        if io.spare.len() < TOKEN_POOL_MAX {
                            io.spare.push(h);
                        }
                    }
                    _ => unreachable!("protocol: {t} steps follow Begin"),
                }
            }
            out.push(recon);
        }
        out
    }

    /// Anomaly score (reconstruction MSE) of one window through the
    /// pipeline — bit-identical to [`LstmAutoencoder::score_quant`].
    pub fn score(&self, x: &[Vec<f32>]) -> f64 {
        LstmAutoencoder::mse(x, &self.forward_quant(x))
    }

    /// Scores for a batch of windows, pipelined back-to-back.
    pub fn score_batch(&self, windows: &[&[Vec<f32>]]) -> Vec<f64> {
        let recons = self.forward_batch(windows);
        windows.iter().zip(&recons).map(|(w, r)| LstmAutoencoder::mse(w, r)).collect()
    }
}

impl Drop for TemporalPipeline {
    fn drop(&mut self) {
        // Recover the endpoints even from a poisoned lock so teardown
        // always reaches the workers.
        let io = match self.io.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        let _ = io.tx.send(Token::Stop);
        drop(io);
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(ae: &LstmAutoencoder, layer: usize, rx: Receiver<Token>, down: Downstream) {
    let cell: &QuantLstmCell = &ae.quant_cells()[layer];
    let lh = cell.w.dims.lh;
    let mut state = QuantLstmState::zeros(lh);
    let mut scratch = StepScratch::new();
    while let Ok(tok) = rx.recv() {
        let out = match tok {
            Token::Begin(t) => {
                state.reset(lh);
                Token::Begin(t)
            }
            Token::Step(mut x) => {
                cell.step_into(&mut state, &x, &mut scratch);
                // Reuse the incoming token's buffer for the outgoing h:
                // its capacity settles at max(lx, lh) after a few hops,
                // so steady-state tokens cross the whole chain with zero
                // allocation (the endpoint free list recycles them back
                // into the feed).
                x.clear();
                x.extend_from_slice(&state.h);
                Token::Step(x)
            }
            Token::Stop => {
                let _ = down.send(Token::Stop);
                return;
            }
        };
        if down.send(out).is_err() {
            return;
        }
    }
    // Upstream hung up without an explicit Stop (teardown race): make
    // sure downstream unwinds too.
    let _ = down.send(Token::Stop);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;
    use crate::util::prop::props;
    use crate::util::rng::Xoshiro256;

    fn window(t: usize, f: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut r = Xoshiro256::seeded(seed);
        (0..t).map(|_| (0..f).map(|_| r.uniform(-1.0, 1.0) as f32).collect()).collect()
    }

    #[test]
    fn matches_forward_quant_on_deep_model() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 5));
        let pipe = TemporalPipeline::new(ae.clone());
        for t in [1usize, 2, 9, 33] {
            let x = window(t, 64, t as u64 + 10);
            assert_eq!(pipe.forward_quant(&x), ae.forward_quant(&x), "T={t}");
        }
    }

    #[test]
    fn back_to_back_windows_do_not_leak_state() {
        // Scoring the same window twice with a different window between
        // must give identical results — Begin resets every layer.
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 9));
        let pipe = TemporalPipeline::new(ae.clone());
        let a = window(6, 32, 1);
        let b = window(4, 32, 2);
        let refs: Vec<&[Vec<f32>]> = vec![&a, &b, &a];
        let out = pipe.forward_batch(&refs);
        assert_eq!(out[0], out[2]);
        assert_eq!(out[0], ae.forward_quant(&a));
        assert_eq!(out[1], ae.forward_quant(&b));
    }

    #[test]
    fn variable_length_batches_collect_in_order() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 3));
        let pipe = TemporalPipeline::new(ae.clone());
        let wins: Vec<Vec<Vec<f32>>> =
            (0..5).map(|i| window(1 + i, 32, 50 + i as u64)).collect();
        let refs: Vec<&[Vec<f32>]> = wins.iter().map(|w| w.as_slice()).collect();
        let out = pipe.forward_batch(&refs);
        for (i, w) in wins.iter().enumerate() {
            assert_eq!(out[i].len(), w.len());
            assert_eq!(out[i], ae.forward_quant(w), "window {i}");
        }
    }

    #[test]
    fn long_sequence_exceeding_fifo_depth_completes() {
        // T far beyond total FIFO capacity: the unbounded drain prevents
        // feed/collect deadlock.
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 4));
        let pipe = TemporalPipeline::with_capacity(ae.clone(), 1);
        let x = window(200, 32, 77);
        assert_eq!(pipe.forward_quant(&x), ae.forward_quant(&x));
    }

    #[test]
    fn malformed_window_does_not_poison_the_pipeline() {
        // A wrong-width window must panic only its caller; the shared
        // pipeline keeps serving other callers (no poisoned lock, no
        // broken token protocol).
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 6));
        let pipe = Arc::new(TemporalPipeline::new(ae.clone()));
        let bad = window(3, 31, 1); // 31 features instead of 32
        let p2 = pipe.clone();
        let joined = std::thread::spawn(move || p2.forward_quant(&bad)).join();
        assert!(joined.is_err(), "malformed window must panic its caller");
        let good = window(4, 32, 2);
        assert_eq!(pipe.forward_quant(&good), ae.forward_quant(&good));
    }

    #[test]
    fn pinned_pipeline_bit_identical_to_unpinned() {
        // Pinning changes placement, never results — and on targets where
        // pinning is unavailable it silently degrades to unpinned.
        let topo = Topology::from_name("F64-D6").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 8));
        let pinned = TemporalPipeline::with_options(
            ae.clone(),
            PipelineOptions { pin_base_core: Some(0), ..Default::default() },
        );
        for t in [1usize, 7, 33] {
            let x = window(t, 64, 80 + t as u64);
            assert_eq!(pinned.forward_quant(&x), ae.forward_quant(&x), "T={t}");
        }
    }

    #[test]
    fn token_recycling_keeps_batches_bit_identical() {
        // Run enough back-to-back batches that the endpoint free list is
        // exercised (drain refills it, feed drains it) and make sure
        // recycled buffers never leak stale timesteps.
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = Arc::new(LstmAutoencoder::random(topo, 13));
        let pipe = TemporalPipeline::new(ae.clone());
        for round in 0..4u64 {
            let wins: Vec<Vec<Vec<f32>>> =
                (0..3).map(|i| window(5 + i, 32, 300 + 10 * round + i as u64)).collect();
            let refs: Vec<&[Vec<f32>]> = wins.iter().map(|w| w.as_slice()).collect();
            let out = pipe.forward_batch(&refs);
            for (i, w) in wins.iter().enumerate() {
                assert_eq!(out[i], ae.forward_quant(w), "round {round} window {i}");
            }
        }
    }

    #[test]
    fn scores_match_sequential_scorer_bitwise() {
        props("pipeline_scores", 12, |g| {
            let f = 1usize << g.usize_in(3, 5);
            let d = 2 * g.usize_in(1, 3);
            let Ok(topo) = Topology::new(f, d) else { return };
            let ae = Arc::new(LstmAutoencoder::random(topo, g.case as u64));
            let pipe = TemporalPipeline::new(ae.clone());
            let t = g.usize_in(1, 12);
            let x: Vec<Vec<f32>> =
                (0..t).map(|_| g.vec_f32(f, -1.5, 1.5)).collect();
            assert_eq!(pipe.score(&x).to_bits(), ae.score_quant(&x).to_bits());
        });
    }
}
