//! Runtime fixed-point format descriptor. The datapath type is Q8.24
//! ([`super::Q8_24`]); this descriptor exists so the resource model and
//! the accuracy-vs-precision sweep (`examples/design_space.rs`) can
//! reason about alternative word lengths the way an HLS `ap_fixed<W,I>`
//! template parameter would.

/// `Q{int_bits}.{frac_bits}` signed fixed point in a `word_bits` word
/// (word_bits = 1 sign + int_bits + frac_bits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QFormat {
    pub word_bits: u32,
    pub frac_bits: u32,
}

impl QFormat {
    /// The paper's datapath format (§4.1): 32-bit, 24 fractional.
    pub const PAPER: QFormat = QFormat { word_bits: 32, frac_bits: 24 };

    pub fn new(word_bits: u32, frac_bits: u32) -> QFormat {
        assert!(word_bits >= 2 && word_bits <= 64, "word_bits {word_bits}");
        assert!(frac_bits < word_bits, "frac {frac_bits} must leave a sign bit");
        QFormat { word_bits, frac_bits }
    }

    pub fn int_bits(&self) -> u32 {
        self.word_bits - 1 - self.frac_bits
    }

    /// Quantization step 2^-frac.
    pub fn step(&self) -> f64 {
        (2.0f64).powi(-(self.frac_bits as i32))
    }

    /// Largest representable value.
    pub fn max_value(&self) -> f64 {
        let max_raw = (1i128 << (self.word_bits - 1)) - 1;
        max_raw as f64 * self.step()
    }

    /// Most negative representable value.
    pub fn min_value(&self) -> f64 {
        -((1i128 << (self.word_bits - 1)) as f64) * self.step()
    }

    /// Quantize with round-to-nearest + saturation (reference semantics for
    /// arbitrary formats; the Q8.24 fast path lives in `Q8_24`).
    pub fn quantize(&self, v: f64) -> f64 {
        let scaled = (v / self.step()).round();
        let max_raw = ((1i128 << (self.word_bits - 1)) - 1) as f64;
        let min_raw = -((1i128 << (self.word_bits - 1)) as f64);
        scaled.clamp(min_raw, max_raw) * self.step()
    }

    /// Mean squared quantization error of a sample (accuracy sweeps).
    pub fn mse(&self, xs: &[f64]) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        xs.iter().map(|&x| (x - self.quantize(x)).powi(2)).sum::<f64>() / xs.len() as f64
    }
}

impl std::fmt::Display for QFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Q{}.{}", self.int_bits(), self.frac_bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::Q8_24;
    use crate::util::prop::props;

    #[test]
    fn paper_format_bounds() {
        let q = QFormat::PAPER;
        assert_eq!(q.int_bits(), 7);
        assert_eq!(format!("{q}"), "Q7.24");
        assert!((q.max_value() - (128.0 - q.step())).abs() < 1e-12);
        assert_eq!(q.min_value(), -128.0);
    }

    #[test]
    fn quantize_agrees_with_q8_24() {
        props("qformat_vs_q824", 512, |g| {
            let v = g.f64_in(-200.0, 200.0);
            let a = QFormat::PAPER.quantize(v);
            let b = Q8_24::from_f64(v).to_f64();
            assert!((a - b).abs() < 1e-12, "v={v} a={a} b={b}");
        });
    }

    #[test]
    fn narrower_formats_have_larger_error() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64) * 0.001357 - 0.5).collect();
        let e16 = QFormat::new(16, 12).mse(&xs);
        let e32 = QFormat::PAPER.mse(&xs);
        assert!(e16 > e32 * 100.0, "e16={e16} e32={e32}");
    }

    #[test]
    fn idempotent() {
        props("quant_idem", 256, |g| {
            let q = QFormat::new(18, 12);
            let v = g.f64_in(-30.0, 30.0);
            let once = q.quantize(v);
            assert_eq!(once, q.quantize(once));
        });
    }
}
