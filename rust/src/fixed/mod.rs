//! Fixed-point arithmetic matching the paper's FPGA datapath: 32-bit
//! words with 24 fractional bits (Q8.24, §4.1), saturating, with
//! round-to-nearest-even on precision-losing operations.
//!
//! Two layers:
//! - [`QFormat`] — a runtime format descriptor (word length, fractional
//!   bits) used by the resource model and accuracy sweeps.
//! - [`Q8_24`] — the concrete datapath type used by the golden model:
//!   value = raw / 2²⁴, raw: i32, range [−128, 128 − 2⁻²⁴].
//!
//! Multiplication widens to i64 (as DSP48 cascades do), then rounds and
//! saturates back. The Pallas kernel's quantized variant emulates the same
//! grid in f32 — every representable Q8.24 value with |v| < 2⁷ has ≤ 31
//! significant bits, so the *grid* is shared even though f32 rounds values
//! with > 24 significant mantissa bits; the python/rust agreement test
//! bounds that representation error explicitly.

pub mod qformat;

pub use qformat::QFormat;

/// Number of fractional bits in the paper's datapath.
pub const FRAC_BITS: u32 = 24;
/// 2^24 as f64, the quantization step reciprocal.
pub const SCALE: f64 = (1u64 << FRAC_BITS) as f64;

/// A Q8.24 fixed-point number: i32 raw, 24 fractional bits, saturating.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Q8_24(pub i32);

impl Q8_24 {
    pub const ZERO: Q8_24 = Q8_24(0);
    pub const ONE: Q8_24 = Q8_24(1 << FRAC_BITS);
    pub const MAX: Q8_24 = Q8_24(i32::MAX);
    pub const MIN: Q8_24 = Q8_24(i32::MIN);

    /// Quantize an f64 with round-to-nearest(-even at .5 via `round_ties_even`
    /// is unstable; we use round-half-away which matches `jnp.round`'s
    /// behaviour only at exact .5 raws — the agreement test avoids exact
    /// ties by construction) and saturation.
    #[inline]
    pub fn from_f64(v: f64) -> Q8_24 {
        let scaled = v * SCALE;
        if scaled >= i32::MAX as f64 {
            Q8_24::MAX
        } else if scaled <= i32::MIN as f64 {
            Q8_24::MIN
        } else {
            Q8_24(scaled.round() as i32)
        }
    }

    #[inline]
    pub fn from_f32(v: f32) -> Q8_24 {
        Self::from_f64(v as f64)
    }

    #[inline]
    pub fn to_f64(self) -> f64 {
        self.0 as f64 / SCALE
    }

    #[inline]
    pub fn to_f32(self) -> f32 {
        self.to_f64() as f32
    }

    /// Saturating addition — FPGA adders in the datapath saturate rather
    /// than wrap so anomalies cannot alias into benign reconstructions.
    #[inline]
    pub fn add(self, rhs: Q8_24) -> Q8_24 {
        Q8_24(self.0.saturating_add(rhs.0))
    }

    #[inline]
    pub fn sub(self, rhs: Q8_24) -> Q8_24 {
        Q8_24(self.0.saturating_sub(rhs.0))
    }

    /// Saturating multiply: widen to i64, round-to-nearest (half away from
    /// zero), shift back, saturate. Mirrors a DSP48E2 27×24 multiply with
    /// post-adder rounding.
    #[inline]
    pub fn mul(self, rhs: Q8_24) -> Q8_24 {
        let wide = self.0 as i64 * rhs.0 as i64;
        Q8_24(clamp_i64(round_shift(wide)))
    }

    /// Fused multiply-accumulate into a wide i64 accumulator (raw scale
    /// 2^48). The MVM units accumulate wide and round once per dot product,
    /// exactly like the HLS implementation keeps the DSP cascade wide.
    #[inline]
    pub fn mac_wide(acc: i64, a: Q8_24, b: Q8_24) -> i64 {
        acc.saturating_add(a.0 as i64 * b.0 as i64)
    }

    /// Collapse a wide accumulator (scale 2^48) back to Q8.24.
    #[inline]
    pub fn from_wide(acc: i64) -> Q8_24 {
        Q8_24(clamp_i64(round_shift(acc)))
    }

    /// Round an f64 onto the Q8.24 grid and return it as f64 — what the
    /// quantized JAX path computes. Useful for tolerance reasoning.
    pub fn quantize_f64(v: f64) -> f64 {
        Self::from_f64(v).to_f64()
    }
}

/// Round-to-nearest, half away from zero, of `v / 2^FRAC_BITS`.
/// (An arithmetic right shift alone is floor division, which would bias
/// negative values downward — e.g. round(−1.4) must be −1, not −2.)
#[inline]
fn round_shift(v: i64) -> i64 {
    let half = 1i64 << (FRAC_BITS - 1);
    if v >= 0 {
        (v + half) >> FRAC_BITS
    } else {
        -((-v + half) >> FRAC_BITS)
    }
}

#[inline]
fn clamp_i64(v: i64) -> i32 {
    if v > i32::MAX as i64 {
        i32::MAX
    } else if v < i32::MIN as i64 {
        i32::MIN
    } else {
        v as i32
    }
}

/// Dot product in the wide-accumulator discipline used by the MVM units.
pub fn dot_q(a: &[Q8_24], b: &[Q8_24]) -> Q8_24 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i64;
    for (&x, &w) in a.iter().zip(b) {
        acc = Q8_24::mac_wide(acc, x, w);
    }
    Q8_24::from_wide(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::props;

    #[test]
    fn roundtrip_exact_on_grid() {
        for raw in [0i32, 1, -1, 1 << 24, -(1 << 24), 12345678, i32::MAX, i32::MIN] {
            let q = Q8_24(raw);
            assert_eq!(Q8_24::from_f64(q.to_f64()), q, "raw={raw}");
        }
    }

    #[test]
    fn quantization_error_bounded() {
        props("quant_err", 512, |g| {
            let v = g.f64_in(-100.0, 100.0);
            let q = Q8_24::from_f64(v).to_f64();
            assert!((q - v).abs() <= 0.5 / SCALE + 1e-15, "v={v} q={q}");
        });
    }

    #[test]
    fn saturation_add() {
        let big = Q8_24::from_f64(127.0);
        assert_eq!(big.add(big), Q8_24::MAX);
        let small = Q8_24::from_f64(-127.0);
        assert_eq!(small.add(small), Q8_24::MIN);
    }

    #[test]
    fn saturation_mul() {
        let a = Q8_24::from_f64(100.0);
        assert_eq!(a.mul(a), Q8_24::MAX); // 10000 >> 128
        let b = Q8_24::from_f64(-100.0);
        assert_eq!(a.mul(b), Q8_24::MIN);
    }

    #[test]
    fn mul_matches_float_within_half_ulp() {
        props("mul_close", 1024, |g| {
            let x = g.f64_in(-8.0, 8.0);
            let y = g.f64_in(-8.0, 8.0);
            let qx = Q8_24::from_f64(x);
            let qy = Q8_24::from_f64(y);
            let got = qx.mul(qy).to_f64();
            let want = qx.to_f64() * qy.to_f64();
            assert!((got - want).abs() <= 0.5 / SCALE + 1e-12, "x={x} y={y} got={got} want={want}");
        });
    }

    #[test]
    fn one_is_identity() {
        props("mul_one", 256, |g| {
            let x = Q8_24::from_f64(g.f64_in(-100.0, 100.0));
            assert_eq!(x.mul(Q8_24::ONE), x);
        });
    }

    #[test]
    fn mul_commutes() {
        props("mul_comm", 512, |g| {
            let a = Q8_24::from_f64(g.f64_in(-11.0, 11.0));
            let b = Q8_24::from_f64(g.f64_in(-11.0, 11.0));
            assert_eq!(a.mul(b), b.mul(a));
        });
    }

    #[test]
    fn wide_dot_more_accurate_than_narrow() {
        // Accumulating wide then rounding once must equal the exact integer
        // dot product rounded once.
        props("dot_exact", 128, |g| {
            let n = g.usize_in(1, 64);
            let a: Vec<Q8_24> = (0..n).map(|_| Q8_24::from_f64(g.f64_in(-1.0, 1.0))).collect();
            let b: Vec<Q8_24> = (0..n).map(|_| Q8_24::from_f64(g.f64_in(-1.0, 1.0))).collect();
            let got = dot_q(&a, &b).to_f64();
            let exact: f64 = a.iter().zip(&b).map(|(x, w)| x.to_f64() * w.to_f64()).sum();
            assert!((got - exact).abs() <= 0.5 / SCALE + 1e-9, "got={got} exact={exact}");
        });
    }

    #[test]
    fn from_wide_rounds_half_away() {
        // 1.5 ulp in wide scale rounds to 2 raw.
        let acc = 3i64 << (FRAC_BITS - 1); // = 1.5 * 2^24 in 2^48 scale? No:
        // acc is at scale 2^48; 1.5 raw-units of Q8.24 = 1.5 * 2^24 at 2^48.
        let acc = acc; // 3 * 2^23 = 1.5 * 2^24 ✓
        assert_eq!(Q8_24::from_wide(acc).0, 2);
        assert_eq!(Q8_24::from_wide(-acc).0, -2);
    }
}
