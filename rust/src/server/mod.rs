//! End-to-end anomaly-detection service (the deployment the paper
//! motivates in §1: real-time, high-throughput LSTM-AE scoring).
//!
//! Architecture (all std::thread + mpsc; the vendor set has no tokio —
//! and a blocking pool is the right shape for a compute-bound scorer):
//!
//! ```text
//! clients ──submit──► [ModelRegistry] ──► per-model Lane:
//!                      name → lane        bounded admission queue
//!                                          │ full → SubmitError::Overloaded
//!                                         [batcher thread]
//!                                          per-lane max_batch / max_wait
//!                                          │ (bounded)
//!                                         [worker pool] ──► Backend
//! ```
//!
//! - [`fabric`] — the multi-model serving fabric: [`ModelRegistry`] owns
//!   one [`Lane`] per served model (the paper evaluates four topologies
//!   concurrently); every lane has its own batching policy, bounded
//!   admission queue (explicit load shedding instead of unbounded
//!   buffering), worker pool, and metrics. [`AnomalyServer`] is the
//!   single-model compatibility wrapper over one lane.
//! - [`front`] — the async submission front: [`Lane::submit_async`]
//!   returns a [`Ticket`] (poll / wait / callback) backed by one
//!   completion-router thread per lane instead of a parked thread per
//!   request, and a [`CompletionSet`] fans tickets from many lanes into
//!   select-style "first of N" consumption. The blocking surface is a
//!   thin wrapper over the same machinery.
//! - [`batcher`] — dynamic batching policy (size + deadline), the L3
//!   serving analog of the paper's throughput scenario.
//! - [`backend`] — scoring backends: the AOT PJRT artifact (real
//!   numerics, Python-free) and the bit-accurate quantized golden model
//!   (the FPGA datapath in software). The quant backend executes on the
//!   temporal-pipeline engine ([`crate::engine`]): batches formed by the
//!   batcher hit the batched MMM kernel (each weight matrix streamed once
//!   across the batch), lone deep-model windows check a pipeline replica
//!   out of an engine [`crate::engine::PipelinePool`] (so concurrent
//!   workers don't serialize on one pipeline), and all paths are
//!   bit-identical to the sequential scorer — see the engine docs for the
//!   exact routing rules.
//! - [`metrics`] — per-lane latency histograms + throughput counters and
//!   the autoscaler's sensor gauges (queue depth, worker idle/busy time),
//!   rolled up by [`ModelRegistry::fleet_report`].
//! - [`autoscale`] — the metrics-driven per-lane autoscaler: a controller
//!   thread samples every lane on a tick and resizes lane worker pools
//!   and engine pipeline-replica pools between configured bounds with
//!   hysteresis (the software analogue of SHARP-style workload-adaptive
//!   resource allocation). See `ARCHITECTURE.md` for the control loop.
//! - [`shard`] — the cross-process scale step: a [`ShardRouter`] spreads
//!   the same `submit(model, window)` surface over N shard processes
//!   (each a [`crate::net::ShardServer`] over its own registry), with a
//!   static model map, health-weighted power-of-two balancing, and a
//!   self-healing control plane: probe/heartbeat health ticks drive each
//!   shard through Live→Suspect→Dead ([`ShardState`]), dead shards are
//!   redialed with capped backoff until they rejoin, `Leave` announcers
//!   drain gracefully, and [`ShardRouter::add_shard`] admits shards into
//!   a running fleet. [`ServingSurface`] is the trait both ends of that
//!   symmetry implement.
//! - [`fleetscale`] — the fleet-tier process autoscaler: a controller
//!   thread samples fleet-wide heartbeat signals (shed deltas, p99 EWMAs,
//!   in-flight counts, live-shard count) and spawns or drains whole
//!   `fleet serve` child processes between configured bounds with the
//!   same streak hysteresis the per-lane [`autoscale`] tier uses.

pub mod autoscale;
pub mod backend;
pub mod batcher;
pub mod cache;
pub mod fabric;
pub mod fleetscale;
pub mod front;
pub mod metrics;
pub mod shard;

pub use autoscale::{Autoscaler, AutoscalePolicy, ScaleDecision};
pub use backend::{Backend, PjrtBackend, QuantBackend, ThrottledBackend};
pub use cache::CacheConfig;
pub use fabric::{FleetLoad, Lane, ModelRegistry, SessionTable, SubmitError};
pub use fleetscale::{FleetScalePolicy, FleetScaler, ShardSpawner, SpawnedShard};
pub use front::{Completion, CompletionSet, Ticket};
pub use metrics::ServerMetrics;
pub use shard::{FleetSample, RouterConfig, RouterConfigBuilder, ShardRouter, ShardState};

/// The one serving surface: everything a client can ask of the fleet —
/// stateless window scoring, stateful streaming sessions, and the
/// rolled-up fleet report — behind a single trait. Implemented by the
/// in-process [`ModelRegistry`] and the cross-process [`ShardRouter`]
/// (which adds health-weighted balancing and sticky session→shard
/// routing), so the workload drivers
/// ([`crate::workload::trace::closed_loop_async`],
/// [`crate::workload::trace::replay_fleet`],
/// [`crate::workload::trace::replay_streams`], and friends) run unchanged
/// against one process or a whole shard fleet — the scale step the
/// ROADMAP's sharding item asks for, with client code untouched.
pub trait ServingSurface: Sync {
    /// Nonblocking submit: a [`Ticket`] on acceptance, the usual
    /// [`SubmitError`] admission outcomes otherwise. Remote surfaces may
    /// additionally resolve the *ticket* to `Err(Overloaded)` — their
    /// admission verdict arrives a round-trip later.
    fn submit_async(&self, model: &str, window: Window) -> Result<Ticket, SubmitError>;

    /// Submit and wait for the outcome.
    fn score_blocking(&self, model: &str, window: Window) -> Result<Response, SubmitError> {
        self.submit_async(model, window)?.wait()
    }

    /// Open (or reopen, resetting state) session `stream` on `model` with
    /// scoring window `window` (`0` → the lane default). Sessions carry
    /// LSTM hidden/cell state forward so each arriving sample costs one
    /// recurrence step instead of a full-window re-run.
    fn open_stream(&self, model: &str, stream: u64, window: usize) -> Result<(), SubmitError>;

    /// Feed one `F`-feature sample to an open session. The [`Ticket`]
    /// resolves to the session's updated trailing-window score.
    /// [`SubmitError::UnknownStream`] when the session was never opened,
    /// was closed, or was evicted.
    fn submit_sample(
        &self,
        model: &str,
        stream: u64,
        sample: Vec<f32>,
    ) -> Result<Ticket, SubmitError>;

    /// Close a session, releasing its table slot. Closing an unknown
    /// session is a no-op.
    fn close_stream(&self, model: &str, stream: u64);

    /// The rolled-up human-readable fleet report (per-lane counters,
    /// latency percentiles, cache and session totals). Default: empty —
    /// surfaces with nothing to report stay report-free.
    fn fleet_report(&self) -> String {
        String::new()
    }
}

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::workload::Window;

/// Per-lane server configuration (one per served model in the fabric).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max windows per dispatched batch.
    pub max_batch: usize,
    /// Max time the batcher holds the first request of a batch.
    pub max_wait: Duration,
    /// Worker threads.
    pub workers: usize,
    /// Bounded admission-queue capacity, in requests. A full queue fails
    /// `submit` fast with [`SubmitError::Overloaded`] (load shedding)
    /// instead of queuing unboundedly.
    pub queue_capacity: usize,
    /// Anomaly threshold on the reconstruction-error score
    /// (calibrate via [`calibrate_threshold`]).
    pub threshold: f64,
    /// Per-lane autoscaling policy. `None` (the default) pins the lane to
    /// its static `workers` count; `Some` makes the lane eligible for a
    /// registry [`Autoscaler`], which resizes the worker pool (and the
    /// backend's pipeline-replica pool, where one exists) between the
    /// policy's bounds. See [`autoscale`].
    pub autoscale: Option<AutoscalePolicy>,
    /// Per-lane exact-match score cache + single-flight coalescing (see
    /// [`cache`]). `None` (the default) runs the lane uncached; a config
    /// with `entries == 0` is also treated as off.
    pub cache: Option<CacheConfig>,
    /// Stream-session table sizing (see [`fabric::SessionTable`]). Only
    /// consulted on lanes whose backend exposes a
    /// [`Backend::session_model`]; window-only lanes ignore it.
    pub sessions: SessionConfig,
    /// Pin this lane's worker threads to cores `base, base+1, …` (modulo
    /// the machine's core count) via [`crate::util::affinity`]. `None`
    /// (the default) leaves placement to the scheduler. Best-effort and
    /// Linux-only, like the pipeline-stage pinning it extends.
    pub pin_base_core: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
            workers: 2,
            queue_capacity: 1024,
            threshold: 0.05,
            autoscale: None,
            cache: None,
            sessions: SessionConfig::default(),
            pin_base_core: None,
        }
    }
}

impl ServerConfig {
    /// Start a [`ServerConfigBuilder`] from the defaults. Prefer this
    /// over struct literals with `..Default::default()`: the builder
    /// validates at [`ServerConfigBuilder::build`], and adding a config
    /// field stops being a repo-wide diff.
    pub fn builder() -> ServerConfigBuilder {
        ServerConfigBuilder { cfg: ServerConfig::default() }
    }
}

/// Typed builder for [`ServerConfig`] — see [`ServerConfig::builder`].
///
/// ```
/// use lstm_ae_accel::server::ServerConfig;
/// let cfg = ServerConfig::builder().max_batch(4).workers(1).build();
/// assert_eq!(cfg.max_batch, 4);
/// ```
#[derive(Clone, Debug)]
pub struct ServerConfigBuilder {
    cfg: ServerConfig,
}

impl ServerConfigBuilder {
    /// Max windows per dispatched batch (must stay ≥ 1).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n;
        self
    }

    /// Max time the batcher holds the first request of a batch.
    pub fn max_wait(mut self, d: Duration) -> Self {
        self.cfg.max_wait = d;
        self
    }

    /// Worker threads (must stay ≥ 1).
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n;
        self
    }

    /// Bounded admission-queue capacity in requests (must stay ≥ 1).
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.cfg.queue_capacity = n;
        self
    }

    /// Anomaly threshold on the reconstruction-error score.
    pub fn threshold(mut self, t: f64) -> Self {
        self.cfg.threshold = t;
        self
    }

    /// Per-lane autoscaling policy (see [`AutoscalePolicy`]).
    pub fn autoscale(mut self, p: AutoscalePolicy) -> Self {
        self.cfg.autoscale = Some(p);
        self
    }

    /// Per-lane exact-match score cache (see [`CacheConfig`]).
    pub fn cache(mut self, c: CacheConfig) -> Self {
        self.cfg.cache = Some(c);
        self
    }

    /// Stream-session table sizing (capacity must stay ≥ 1).
    pub fn sessions(mut self, s: SessionConfig) -> Self {
        self.cfg.sessions = s;
        self
    }

    /// Pin the lane's worker threads from this core up.
    pub fn pin_base_core(mut self, c: usize) -> Self {
        self.cfg.pin_base_core = Some(c);
        self
    }

    /// Validate and produce the [`ServerConfig`].
    ///
    /// Panics on configurations no lane can run: a zero `max_batch`,
    /// `workers`, `queue_capacity`, or session capacity. Misconfiguration
    /// is a programming error, so it fails loudly at construction instead
    /// of wedging a batcher at runtime.
    pub fn build(self) -> ServerConfig {
        assert!(self.cfg.max_batch >= 1, "ServerConfig: max_batch must be >= 1");
        assert!(self.cfg.workers >= 1, "ServerConfig: workers must be >= 1");
        assert!(self.cfg.queue_capacity >= 1, "ServerConfig: queue_capacity must be >= 1");
        assert!(self.cfg.sessions.capacity >= 1, "ServerConfig: session capacity must be >= 1");
        self.cfg
    }
}

/// Sizing for a lane's stream-session table (the stateful half of the
/// serving surface — see [`fabric::SessionTable`]).
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// Max concurrently-open sessions per lane. Opening beyond this
    /// evicts the least-recently-stepped session (its next sample then
    /// fails with [`SubmitError::UnknownStream`] until reopened).
    pub capacity: usize,
    /// Default scoring window `W` per session: the score after each step
    /// is the reconstruction MSE over the last `min(steps, W)` samples.
    /// `StreamOpen` may override it per session.
    pub window: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig { capacity: 4096, window: 64 }
    }
}

/// A scored response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub score: f64,
    pub is_anomaly: bool,
    /// Time from submit to batch dispatch.
    pub queue_us: f64,
    /// Time spent scoring (per-batch, shared across its windows).
    pub service_us: f64,
    /// Submit → response.
    pub e2e_us: f64,
}

pub(crate) struct Request {
    id: u64,
    window: Window,
    submitted: Instant,
    /// Cache key for worker-side population — present exactly when the
    /// lane's score cache admitted this request as a miss.
    key: Option<cache::CacheKey>,
    /// Stream session this request steps, if any. `Some(id)` marks a
    /// one-sample session step (`window` is its `1×F` sample; steps never
    /// carry a cache key — carried state makes them uncacheable);
    /// `None` is the classic stateless window path.
    stream: Option<u64>,
    reply: Sender<Response>,
}

pub(crate) enum BatcherMsg {
    Req(Request),
    Shutdown,
}

/// What the batcher→worker channel carries: formed batches, plus the
/// autoscaler's graceful-retirement poison message (any one worker
/// consumes a `Retire` and exits its loop after finishing its current
/// batch — in-flight work is never abandoned).
pub(crate) enum WorkerMsg {
    Batch(Batch),
    Retire,
}

// Re-exported for the batcher module.
pub(crate) use BatcherMsg as Msg;
pub(crate) type Batch = Vec<Request>;

/// Handle to a running single-model server — the compatibility wrapper
/// over one fabric [`Lane`]. Multi-model deployments use
/// [`ModelRegistry`] directly; both run exactly the same lane machinery
/// (bounded admission, per-lane batcher, worker pool).
pub struct AnomalyServer {
    lane: fabric::Lane,
}

impl AnomalyServer {
    /// Start batcher + workers over a scoring backend.
    pub fn start(backend: Arc<dyn Backend>, cfg: ServerConfig) -> AnomalyServer {
        let name = backend.name();
        AnomalyServer { lane: fabric::Lane::start(name, backend, cfg) }
    }

    /// Submit a window; returns a receiver for the response, or an error
    /// when the bounded queue is full ([`SubmitError::Overloaded`]) or
    /// the server has shut down ([`SubmitError::Closed`]).
    pub fn submit(&self, window: Window) -> Result<Receiver<Response>, SubmitError> {
        self.lane.try_submit(window)
    }

    /// Nonblocking submit through the async front (see
    /// [`Lane::submit_async`]): same admission, batching, and shedding
    /// as [`Self::submit`], but completion is a [`Ticket`] instead of a
    /// parked `Receiver`.
    pub fn submit_async(&self, window: Window) -> Result<Ticket, SubmitError> {
        self.lane.submit_async(window)
    }

    /// Submit and wait (convenience for tests/examples).
    pub fn score_blocking(&self, window: Window) -> Result<Response, SubmitError> {
        self.lane.score_blocking(window)
    }

    pub fn metrics(&self) -> &ServerMetrics {
        self.lane.metrics()
    }

    pub fn threshold(&self) -> f64 {
        self.lane.threshold()
    }

    /// Graceful shutdown: drains in-flight work. Idempotent; later
    /// submissions return [`SubmitError::Closed`].
    pub fn shutdown(&self) {
        self.lane.shutdown()
    }
}

/// Calibrate the anomaly threshold as the `q`-quantile of benign scores
/// plus a small margin (the standard LSTM-AE deployment recipe).
///
/// Robust to degenerate inputs: NaN scores (a poisoned backend result)
/// are ignored, and when nothing usable remains the threshold is
/// `f64::INFINITY` — an uncalibrated detector flags nothing, rather than
/// panicking or flagging everything.
pub fn calibrate_threshold(scores: &[f64], q: f64) -> f64 {
    let mut s: Vec<f64> = scores.iter().copied().filter(|v| !v.is_nan()).collect();
    if s.is_empty() {
        return f64::INFINITY;
    }
    s.sort_by(|a, b| a.total_cmp(b));
    let p = crate::util::stats::percentile_sorted(&s, q);
    p * 1.25
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LstmAutoencoder, Topology};
    use crate::workload::TelemetryGen;

    fn quant_server(cfg: ServerConfig) -> (AnomalyServer, TelemetryGen) {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo, 1);
        let backend = Arc::new(QuantBackend::new(ae));
        (AnomalyServer::start(backend, cfg), TelemetryGen::new(32, 2))
    }

    #[test]
    fn scores_flow_end_to_end() {
        let (srv, mut gen) = quant_server(ServerConfig::default());
        let mut responses = Vec::new();
        for _ in 0..20 {
            responses.push(srv.submit(gen.benign_window(8)).expect("admitted"));
        }
        for rx in responses {
            let r = rx.recv().unwrap();
            assert!(r.score.is_finite() && r.score >= 0.0);
            assert!(r.e2e_us > 0.0);
        }
        assert_eq!(srv.metrics().completed(), 20);
        srv.shutdown();
    }

    #[test]
    fn batching_respects_max_batch() {
        let cfg = ServerConfig { max_batch: 4, ..Default::default() };
        let (srv, mut gen) = quant_server(cfg);
        let rxs: Vec<_> = (0..32)
            .map(|_| srv.submit(gen.benign_window(8)).expect("admitted"))
            .collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        assert!(srv.metrics().max_batch_seen() <= 4);
        srv.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let (srv, mut gen) = quant_server(ServerConfig::default());
        let r = srv.score_blocking(gen.benign_window(4)).unwrap();
        assert!(r.score >= 0.0);
        srv.shutdown();
        srv.shutdown();
    }

    #[test]
    fn submit_after_shutdown_returns_closed() {
        let (srv, mut gen) = quant_server(ServerConfig::default());
        srv.score_blocking(gen.benign_window(4)).unwrap();
        srv.shutdown();
        // The old behaviour silently dropped the request and then
        // panicked in score_blocking's recv(); now both error cleanly.
        assert!(matches!(srv.submit(gen.benign_window(4)), Err(SubmitError::Closed)));
        assert!(matches!(srv.score_blocking(gen.benign_window(4)), Err(SubmitError::Closed)));
    }

    #[test]
    fn threshold_separates_obvious_anomalies() {
        // With a *trained-ish* criterion this is exercised in the example;
        // here: scores for spiky windows exceed benign scores on average
        // even with random weights (bigger inputs → bigger residuals).
        let (srv, mut gen) = quant_server(ServerConfig::default());
        let benign: f64 = (0..10)
            .map(|_| srv.score_blocking(gen.benign_window(16)).unwrap().score)
            .sum::<f64>()
            / 10.0;
        let spiky: f64 = (0..10)
            .map(|_| {
                srv.score_blocking(
                    gen.anomalous_window(16, crate::workload::AnomalyKind::Spike),
                )
                .unwrap()
                .score
            })
            .sum::<f64>()
            / 10.0;
        assert!(spiky > benign, "spiky {spiky} benign {benign}");
        srv.shutdown();
    }

    #[test]
    fn calibrate_threshold_above_bulk() {
        let scores: Vec<f64> = (0..100).map(|i| 0.01 + 0.0001 * i as f64).collect();
        let th = calibrate_threshold(&scores, 0.99);
        let below = scores.iter().filter(|&&s| s <= th).count();
        assert!(below >= 99);
    }

    #[test]
    fn calibrate_threshold_ignores_nan_and_defines_empty() {
        assert_eq!(calibrate_threshold(&[], 0.99), f64::INFINITY);
        assert_eq!(calibrate_threshold(&[f64::NAN, f64::NAN], 0.5), f64::INFINITY);
        let clean = calibrate_threshold(&[0.3, 0.1, 0.2], 0.5);
        let noisy = calibrate_threshold(&[0.3, f64::NAN, 0.1, 0.2, f64::NAN], 0.5);
        assert!(clean.is_finite());
        assert_eq!(clean.to_bits(), noisy.to_bits(), "NaNs must not shift the quantile");
    }
}
