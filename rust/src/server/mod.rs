//! End-to-end anomaly-detection service (the deployment the paper
//! motivates in §1: real-time, high-throughput LSTM-AE scoring).
//!
//! Architecture (all std::thread + mpsc; the vendor set has no tokio —
//! and a blocking pool is the right shape for a compute-bound scorer):
//!
//! ```text
//! clients ──submit──► [batcher thread] ──batches──► [worker pool]
//!                      dynamic batching:             score via Backend
//!                      max_batch / max_wait          (PJRT artifact or
//!                                                     bit-accurate Q8.24)
//! ```
//!
//! - [`batcher`] — dynamic batching policy (size + deadline), the L3
//!   serving analog of the paper's throughput scenario.
//! - [`backend`] — scoring backends: the AOT PJRT artifact (real
//!   numerics, Python-free) and the bit-accurate quantized golden model
//!   (the FPGA datapath in software). The quant backend executes on the
//!   temporal-pipeline engine ([`crate::engine`]): batches formed by the
//!   batcher hit the batched MMM kernel (each weight matrix streamed once
//!   across the batch), lone deep-model windows hit the per-layer worker
//!   pipeline, and both are bit-identical to the sequential scorer — see
//!   the engine docs for the exact routing rules.
//! - [`metrics`] — latency histograms + throughput counters.

pub mod backend;
pub mod batcher;
pub mod metrics;

pub use backend::{Backend, PjrtBackend, QuantBackend};
pub use metrics::ServerMetrics;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::workload::Window;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max windows per dispatched batch.
    pub max_batch: usize,
    /// Max time the batcher holds the first request of a batch.
    pub max_wait: Duration,
    /// Worker threads.
    pub workers: usize,
    /// Anomaly threshold on the reconstruction-error score
    /// (calibrate via [`calibrate_threshold`]).
    pub threshold: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
            workers: 2,
            threshold: 0.05,
        }
    }
}

/// A scored response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub score: f64,
    pub is_anomaly: bool,
    /// Time from submit to batch dispatch.
    pub queue_us: f64,
    /// Time spent scoring (per-batch, shared across its windows).
    pub service_us: f64,
    /// Submit → response.
    pub e2e_us: f64,
}

pub(crate) struct Request {
    id: u64,
    window: Window,
    submitted: Instant,
    reply: Sender<Response>,
}

pub(crate) enum BatcherMsg {
    Req(Request),
    Shutdown,
}

/// Handle to a running server.
pub struct AnomalyServer {
    tx: Sender<BatcherMsg>,
    metrics: Arc<ServerMetrics>,
    threshold: f64,
    next_id: AtomicU64,
    threads: Mutex<Vec<JoinHandle<()>>>,
    running: Arc<AtomicBool>,
}

impl AnomalyServer {
    /// Start batcher + workers over a scoring backend.
    pub fn start(backend: Arc<dyn Backend>, cfg: ServerConfig) -> AnomalyServer {
        assert!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let metrics = Arc::new(ServerMetrics::new());
        let running = Arc::new(AtomicBool::new(true));
        let (tx, rx) = channel::<BatcherMsg>();
        let (batch_tx, batch_rx) = channel::<Vec<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        let mut threads = Vec::new();
        // Batcher.
        {
            let cfg2 = cfg.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("batcher".into())
                    .spawn(move || batcher::run_batcher(rx, batch_tx, cfg2))
                    .expect("spawn batcher"),
            );
        }
        // Workers.
        for wid in 0..cfg.workers {
            let backend = backend.clone();
            let rx = batch_rx.clone();
            let metrics = metrics.clone();
            let threshold = cfg.threshold;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("scorer-{wid}"))
                    .spawn(move || worker_loop(backend, rx, metrics, threshold))
                    .expect("spawn worker"),
            );
        }
        AnomalyServer {
            tx,
            metrics,
            threshold: cfg.threshold,
            next_id: AtomicU64::new(0),
            threads: Mutex::new(threads),
            running,
        }
    }

    /// Submit a window; returns a receiver for the response.
    pub fn submit(&self, window: Window) -> Receiver<Response> {
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.on_submit();
        let _ = self.tx.send(BatcherMsg::Req(Request {
            id,
            window,
            submitted: Instant::now(),
            reply,
        }));
        rx
    }

    /// Submit and wait (convenience for tests/examples).
    pub fn score_blocking(&self, window: Window) -> Response {
        self.submit(window).recv().expect("server alive")
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// Graceful shutdown: drains in-flight work.
    pub fn shutdown(&self) {
        if self.running.swap(false, Ordering::SeqCst) {
            let _ = self.tx.send(BatcherMsg::Shutdown);
            for t in self.threads.lock().unwrap().drain(..) {
                let _ = t.join();
            }
        }
    }
}

impl Drop for AnomalyServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    backend: Arc<dyn Backend>,
    rx: Arc<Mutex<Receiver<Vec<Request>>>>,
    metrics: Arc<ServerMetrics>,
    threshold: f64,
) {
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        let Ok(batch) = batch else { return };
        if batch.is_empty() {
            continue;
        }
        let dispatch = Instant::now();
        let windows: Vec<&Window> = batch.iter().map(|r| &r.window).collect();
        let scores = backend.score_batch(&windows);
        let service_us = dispatch.elapsed().as_secs_f64() * 1e6;
        metrics.on_batch(batch.len(), service_us);
        for (req, score) in batch.into_iter().zip(scores) {
            let e2e_us = req.submitted.elapsed().as_secs_f64() * 1e6;
            let queue_us = e2e_us - service_us;
            let resp = Response {
                id: req.id,
                score,
                is_anomaly: score > threshold,
                queue_us: queue_us.max(0.0),
                service_us,
                e2e_us,
            };
            metrics.on_response(&resp);
            let _ = req.reply.send(resp);
        }
    }
}

// Re-exported for the batcher module.
pub(crate) use BatcherMsg as Msg;
pub(crate) type Batch = Vec<Request>;

/// Calibrate the anomaly threshold as the `q`-quantile of benign scores
/// plus a small margin (the standard LSTM-AE deployment recipe).
pub fn calibrate_threshold(scores: &[f64], q: f64) -> f64 {
    let mut s = scores.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = crate::util::stats::percentile_sorted(&s, q);
    p * 1.25
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LstmAutoencoder, Topology};
    use crate::workload::TelemetryGen;

    fn quant_server(cfg: ServerConfig) -> (AnomalyServer, TelemetryGen) {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo, 1);
        let backend = Arc::new(QuantBackend::new(ae));
        (AnomalyServer::start(backend, cfg), TelemetryGen::new(32, 2))
    }

    #[test]
    fn scores_flow_end_to_end() {
        let (srv, mut gen) = quant_server(ServerConfig::default());
        let mut responses = Vec::new();
        for _ in 0..20 {
            responses.push(srv.submit(gen.benign_window(8)));
        }
        for rx in responses {
            let r = rx.recv().unwrap();
            assert!(r.score.is_finite() && r.score >= 0.0);
            assert!(r.e2e_us > 0.0);
        }
        assert_eq!(srv.metrics().completed(), 20);
        srv.shutdown();
    }

    #[test]
    fn batching_respects_max_batch() {
        let cfg = ServerConfig { max_batch: 4, ..Default::default() };
        let (srv, mut gen) = quant_server(cfg);
        let rxs: Vec<_> = (0..32).map(|_| srv.submit(gen.benign_window(8))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        assert!(srv.metrics().max_batch_seen() <= 4);
        srv.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent() {
        let (srv, mut gen) = quant_server(ServerConfig::default());
        let r = srv.score_blocking(gen.benign_window(4));
        assert!(r.score >= 0.0);
        srv.shutdown();
        srv.shutdown();
    }

    #[test]
    fn threshold_separates_obvious_anomalies() {
        // With a *trained-ish* criterion this is exercised in the example;
        // here: scores for spiky windows exceed benign scores on average
        // even with random weights (bigger inputs → bigger residuals).
        let (srv, mut gen) = quant_server(ServerConfig::default());
        let benign: f64 = (0..10)
            .map(|_| srv.score_blocking(gen.benign_window(16)).score)
            .sum::<f64>()
            / 10.0;
        let spiky: f64 = (0..10)
            .map(|_| {
                srv.score_blocking(
                    gen.anomalous_window(16, crate::workload::AnomalyKind::Spike),
                )
                .score
            })
            .sum::<f64>()
            / 10.0;
        assert!(spiky > benign, "spiky {spiky} benign {benign}");
        srv.shutdown();
    }

    #[test]
    fn calibrate_threshold_above_bulk() {
        let scores: Vec<f64> = (0..100).map(|i| 0.01 + 0.0001 * i as f64).collect();
        let th = calibrate_threshold(&scores, 0.99);
        let below = scores.iter().filter(|&&s| s <= th).count();
        assert!(below >= 99);
    }
}
