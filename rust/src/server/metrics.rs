//! Serving metrics: thread-safe counters + latency histograms.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::LogHistogram;

use super::Response;

/// Thread-safe metrics sink shared by workers.
pub struct ServerMetrics {
    submitted: AtomicU64,
    /// Submissions rejected at admission (bounded queue full).
    shed: AtomicU64,
    completed: AtomicU64,
    anomalies: AtomicU64,
    batches: AtomicU64,
    batched_windows: AtomicU64,
    max_batch: AtomicUsize,
    e2e_us: Mutex<LogHistogram>,
    queue_us: Mutex<LogHistogram>,
    service_us: Mutex<LogHistogram>,
    started: Instant,
}

impl ServerMetrics {
    pub fn new() -> ServerMetrics {
        ServerMetrics {
            submitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            anomalies: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_windows: AtomicU64::new(0),
            max_batch: AtomicUsize::new(0),
            e2e_us: Mutex::new(LogHistogram::for_latency()),
            queue_us: Mutex::new(LogHistogram::for_latency()),
            service_us: Mutex::new(LogHistogram::for_latency()),
            started: Instant::now(),
        }
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// A submission was rejected at admission (queue full — load shed).
    pub fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self, size: usize, service_us: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_windows.fetch_add(size as u64, Ordering::Relaxed);
        self.max_batch.fetch_max(size, Ordering::Relaxed);
        self.service_us.lock().unwrap().record(service_us * 1e-6);
    }

    pub fn on_response(&self, r: &Response) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if r.is_anomaly {
            self.anomalies.fetch_add(1, Ordering::Relaxed);
        }
        self.e2e_us.lock().unwrap().record(r.e2e_us * 1e-6);
        self.queue_us.lock().unwrap().record(r.queue_us * 1e-6);
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn anomalies(&self) -> u64 {
        self.anomalies.load(Ordering::Relaxed)
    }

    pub fn max_batch_seen(&self) -> usize {
        self.max_batch.load(Ordering::Relaxed)
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_windows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Completed requests per second since start.
    pub fn throughput_rps(&self) -> f64 {
        self.completed() as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// (p50, p95, p99) end-to-end latency in microseconds.
    pub fn e2e_percentiles_us(&self) -> (f64, f64, f64) {
        let h = self.e2e_us.lock().unwrap();
        (h.percentile(0.5) * 1e6, h.percentile(0.95) * 1e6, h.percentile(0.99) * 1e6)
    }

    /// Mean service time per batch in microseconds.
    pub fn mean_service_us(&self) -> f64 {
        self.service_us.lock().unwrap().mean() * 1e6
    }

    pub fn report(&self) -> String {
        let (p50, p95, p99) = self.e2e_percentiles_us();
        format!(
            "requests: {} submitted, {} shed, {} completed, {} flagged | \
             batches: mean size {:.2}, max {} | \
             e2e latency µs: p50 {:.0}, p95 {:.0}, p99 {:.0} | \
             throughput {:.0} rps",
            self.submitted(),
            self.shed(),
            self.completed(),
            self.anomalies(),
            self.mean_batch_size(),
            self.max_batch_seen(),
            p50,
            p95,
            p99,
            self.throughput_rps(),
        )
    }
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = ServerMetrics::new();
        m.on_submit();
        m.on_submit();
        m.on_shed();
        m.on_batch(2, 100.0);
        for (id, anomaly) in [(0u64, false), (1, true)] {
            m.on_response(&Response {
                id,
                score: 0.1,
                is_anomaly: anomaly,
                queue_us: 50.0,
                service_us: 100.0,
                e2e_us: 150.0,
            });
        }
        assert_eq!(m.submitted(), 2);
        assert_eq!(m.shed(), 1);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.anomalies(), 1);
        assert_eq!(m.max_batch_seen(), 2);
        assert!((m.mean_batch_size() - 2.0).abs() < 1e-12);
        let (p50, _, _) = m.e2e_percentiles_us();
        assert!(p50 > 100.0 && p50 < 250.0, "p50 {p50}");
        assert!(m.report().contains("2 completed"));
    }
}
