//! Serving metrics: thread-safe counters, latency histograms, and the
//! gauges the per-lane autoscaler samples (admission-queue depth, worker
//! idle/busy time) — see [`crate::server::autoscale`].

use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::LogHistogram;

use super::Response;

/// Thread-safe metrics sink shared by workers.
pub struct ServerMetrics {
    submitted: AtomicU64,
    /// Submissions rejected at admission (bounded queue full).
    shed: AtomicU64,
    /// Submissions rejected because the lane was shut down (or mid
    /// teardown) — kept separate from `shed` so requests turned away
    /// during teardown don't vanish from the accounting: every
    /// `try_submit`/`submit_async` call lands in exactly one of
    /// `submitted`, `shed`, or `rejected_closed`.
    rejected_closed: AtomicU64,
    /// Worker threads that died unwinding a backend panic. A panicked
    /// worker decrements the lane's alive count via a drop guard, so the
    /// autoscaler never sizes a phantom pool; this counter is the
    /// operator-visible trace that it happened.
    worker_panics: AtomicU64,
    /// Accepted requests actively removed from the lane by
    /// [`crate::server::Ticket::cancel`] before scoring. The accepted-work
    /// conservation law becomes `submitted == completed + cancelled`
    /// after a drain — cancelled work leaves the lane through this
    /// counter instead of vanishing.
    cancelled: AtomicU64,
    /// Submissions a [`crate::server::ShardRouter`] had to route around
    /// (or re-issue after) a dead shard connection.
    shard_failovers: AtomicU64,
    /// Control plane: health probes sent to shards.
    health_probes: AtomicU64,
    /// Control plane: heartbeat replies consumed from shards.
    heartbeats: AtomicU64,
    /// Control plane: Live→Suspect demotions (missed-probe threshold).
    shard_suspects: AtomicU64,
    /// Control plane: demotions to Dead (in-flight work poisoned).
    shard_deaths: AtomicU64,
    /// Control plane: reconnect dials attempted (successful or not).
    shard_reconnect_attempts: AtomicU64,
    /// Control plane: reconnects that landed — a dead shard rejoined.
    shard_reconnects: AtomicU64,
    /// Fleet autoscaler: shard processes spawned (and admitted) by a
    /// scale-up decision.
    shard_spawns: AtomicU64,
    /// Fleet autoscaler: shard processes drained and reaped by a
    /// scale-down decision.
    shard_retires: AtomicU64,
    /// Control plane: fleet membership by state, refreshed every health
    /// tick — (live, suspect, draining, down). Point-in-time gauges,
    /// unlike the monotone counters above.
    shards_live: AtomicUsize,
    shards_suspect: AtomicUsize,
    shards_draining: AtomicUsize,
    shards_down: AtomicUsize,
    /// Submissions answered straight from the lane's score cache — never
    /// admitted, so counted beside (not inside) `submitted`: the call-level
    /// accounting becomes calls = `submitted` + `shed` + `rejected_closed`
    /// + `cache_hits` + `coalesced`.
    cache_hits: AtomicU64,
    /// Submissions that attached to an in-flight identical window
    /// (single-flight followers) instead of occupying a batch slot.
    coalesced: AtomicU64,
    /// Entries evicted from the score cache (entry-count or byte cap).
    cache_evictions: AtomicU64,
    /// Stream sessions currently open on this lane (point-in-time gauge,
    /// refreshed on open/close and on worker-side implicit reopens).
    sessions: AtomicUsize,
    /// Stream sessions restarted cold: worker-side implicit reopens
    /// after a close/evict raced an admitted sample, and (on routers)
    /// sessions reopened on another shard after a failover — each one is
    /// a documented state reset, so downstream scores restart as a fresh
    /// stream's.
    stream_resets: AtomicU64,
    completed: AtomicU64,
    anomalies: AtomicU64,
    batches: AtomicU64,
    batched_windows: AtomicU64,
    max_batch: AtomicUsize,
    /// Requests currently sitting in the bounded admission queue:
    /// incremented on accepted submit, decremented when the batcher pops
    /// the request into an open batch. Signed because the two updates
    /// race (the batcher can pop before the submitter increments); reads
    /// clamp at zero.
    queue_depth: AtomicI64,
    /// Cumulative nanoseconds workers spent waiting for a batch.
    worker_idle_ns: AtomicU64,
    /// Cumulative nanoseconds workers spent scoring batches.
    worker_busy_ns: AtomicU64,
    e2e_us: Mutex<LogHistogram>,
    queue_us: Mutex<LogHistogram>,
    service_us: Mutex<LogHistogram>,
    started: Instant,
}

impl ServerMetrics {
    pub fn new() -> ServerMetrics {
        ServerMetrics {
            submitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            rejected_closed: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            shard_failovers: AtomicU64::new(0),
            health_probes: AtomicU64::new(0),
            heartbeats: AtomicU64::new(0),
            shard_suspects: AtomicU64::new(0),
            shard_deaths: AtomicU64::new(0),
            shard_reconnect_attempts: AtomicU64::new(0),
            shard_reconnects: AtomicU64::new(0),
            shard_spawns: AtomicU64::new(0),
            shard_retires: AtomicU64::new(0),
            shards_live: AtomicUsize::new(0),
            shards_suspect: AtomicUsize::new(0),
            shards_draining: AtomicUsize::new(0),
            shards_down: AtomicUsize::new(0),
            cache_hits: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            sessions: AtomicUsize::new(0),
            stream_resets: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            anomalies: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_windows: AtomicU64::new(0),
            max_batch: AtomicUsize::new(0),
            queue_depth: AtomicI64::new(0),
            worker_idle_ns: AtomicU64::new(0),
            worker_busy_ns: AtomicU64::new(0),
            e2e_us: Mutex::new(LogHistogram::for_latency()),
            queue_us: Mutex::new(LogHistogram::for_latency()),
            service_us: Mutex::new(LogHistogram::for_latency()),
            started: Instant::now(),
        }
    }

    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A submission was rejected at admission (queue full — load shed).
    pub fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A submission was rejected because the lane is (or is going) down.
    pub fn on_rejected_closed(&self) {
        self.rejected_closed.fetch_add(1, Ordering::Relaxed);
    }

    /// A worker thread died unwinding a backend panic.
    pub fn on_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// A cancelled request was actively removed from the lane's queue.
    pub fn on_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// A submission was routed around (or re-issued after) a dead shard.
    pub fn on_shard_failover(&self) {
        self.shard_failovers.fetch_add(1, Ordering::Relaxed);
    }

    /// A health probe went out to a shard.
    pub fn on_health_probe(&self) {
        self.health_probes.fetch_add(1, Ordering::Relaxed);
    }

    /// A fresh heartbeat reply was consumed from a shard.
    pub fn on_heartbeat(&self) {
        self.heartbeats.fetch_add(1, Ordering::Relaxed);
    }

    /// A shard was demoted Live→Suspect (missed-probe threshold).
    pub fn on_shard_suspect(&self) {
        self.shard_suspects.fetch_add(1, Ordering::Relaxed);
    }

    /// A shard was demoted to Dead; its in-flight tickets were poisoned.
    pub fn on_shard_death(&self) {
        self.shard_deaths.fetch_add(1, Ordering::Relaxed);
    }

    /// A reconnect dial was attempted against a dead shard.
    pub fn on_shard_reconnect_attempt(&self) {
        self.shard_reconnect_attempts.fetch_add(1, Ordering::Relaxed);
    }

    /// A reconnect succeeded — the shard is back in the routable set.
    pub fn on_shard_reconnect(&self) {
        self.shard_reconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// The fleet autoscaler spawned a shard process and admitted it.
    pub fn on_shard_spawn(&self) {
        self.shard_spawns.fetch_add(1, Ordering::Relaxed);
    }

    /// The fleet autoscaler drained and reaped a shard process.
    pub fn on_shard_retire(&self) {
        self.shard_retires.fetch_add(1, Ordering::Relaxed);
    }

    /// Refresh the fleet-membership gauges (called once per health tick
    /// with a consistent snapshot; `down` folds Dead and Reconnecting).
    pub fn set_shard_states(&self, live: usize, suspect: usize, draining: usize, down: usize) {
        self.shards_live.store(live, Ordering::Relaxed);
        self.shards_suspect.store(suspect, Ordering::Relaxed);
        self.shards_draining.store(draining, Ordering::Relaxed);
        self.shards_down.store(down, Ordering::Relaxed);
    }

    /// A submission was answered from the score cache without admission.
    pub fn on_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// A submission attached to an in-flight identical window.
    pub fn on_coalesced(&self) {
        self.coalesced.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` entries were evicted from the score cache by one insert.
    pub fn on_cache_evictions(&self, n: u64) {
        self.cache_evictions.fetch_add(n, Ordering::Relaxed);
    }

    /// Refresh the open-sessions gauge (called after table mutations).
    pub fn set_sessions(&self, n: usize) {
        self.sessions.store(n, Ordering::Relaxed);
    }

    /// `n` stream sessions restarted cold (implicit reopen or failover).
    pub fn on_stream_resets(&self, n: u64) {
        self.stream_resets.fetch_add(n, Ordering::Relaxed);
    }

    /// The batcher popped one request out of the admission queue.
    pub fn on_dequeue(&self) {
        self.queue_depth.fetch_sub(1, Ordering::Relaxed);
    }

    /// A worker finished waiting for work (`ns` spent idle on the batch
    /// queue). Idle-fraction deltas drive autoscaler scale-down.
    pub fn on_worker_idle(&self, ns: u64) {
        self.worker_idle_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn on_batch(&self, size: usize, service_us: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_windows.fetch_add(size as u64, Ordering::Relaxed);
        self.max_batch.fetch_max(size, Ordering::Relaxed);
        self.worker_busy_ns.fetch_add((service_us * 1e3) as u64, Ordering::Relaxed);
        self.service_us.lock().unwrap().record(service_us * 1e-6);
    }

    pub fn on_response(&self, r: &Response) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if r.is_anomaly {
            self.anomalies.fetch_add(1, Ordering::Relaxed);
        }
        self.e2e_us.lock().unwrap().record(r.e2e_us * 1e-6);
        self.queue_us.lock().unwrap().record(r.queue_us * 1e-6);
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Submissions rejected with [`crate::server::SubmitError::Closed`]
    /// (lane down or mid-teardown) — the third leg of the admission
    /// accounting: calls = `submitted` + `shed` + `rejected_closed`.
    pub fn rejected_closed(&self) -> u64 {
        self.rejected_closed.load(Ordering::Relaxed)
    }

    /// Worker threads lost to backend panics over this lane's lifetime.
    pub fn worker_panics(&self) -> u64 {
        self.worker_panics.load(Ordering::Relaxed)
    }

    /// Accepted requests removed before scoring by
    /// [`crate::server::Ticket::cancel`] — the second leg of the
    /// accepted-work conservation law, `submitted == completed +
    /// cancelled` after a drain.
    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Submissions that had to avoid or abandon a dead shard connection
    /// (counted by [`crate::server::ShardRouter`]).
    pub fn shard_failovers(&self) -> u64 {
        self.shard_failovers.load(Ordering::Relaxed)
    }

    /// Health probes sent to shards so far.
    pub fn health_probes(&self) -> u64 {
        self.health_probes.load(Ordering::Relaxed)
    }

    /// Heartbeat replies consumed from shards so far.
    pub fn heartbeats(&self) -> u64 {
        self.heartbeats.load(Ordering::Relaxed)
    }

    /// Live→Suspect demotions so far.
    pub fn shard_suspects(&self) -> u64 {
        self.shard_suspects.load(Ordering::Relaxed)
    }

    /// Demotions to Dead so far.
    pub fn shard_deaths(&self) -> u64 {
        self.shard_deaths.load(Ordering::Relaxed)
    }

    /// Reconnect dials attempted so far (successful or not) — together
    /// with [`Self::shard_reconnects`] this makes the backoff schedule
    /// observable: attempts grow while a shard stays down, reconnects
    /// ticks once when it comes back.
    pub fn shard_reconnect_attempts(&self) -> u64 {
        self.shard_reconnect_attempts.load(Ordering::Relaxed)
    }

    /// Reconnects that landed so far.
    pub fn shard_reconnects(&self) -> u64 {
        self.shard_reconnects.load(Ordering::Relaxed)
    }

    /// Shard processes spawned by the fleet autoscaler so far.
    pub fn shard_spawns(&self) -> u64 {
        self.shard_spawns.load(Ordering::Relaxed)
    }

    /// Shard processes drained and reaped by the fleet autoscaler so far.
    pub fn shard_retires(&self) -> u64 {
        self.shard_retires.load(Ordering::Relaxed)
    }

    /// Fleet membership gauges as of the last health tick:
    /// (live, suspect, draining, down).
    pub fn shard_states(&self) -> (usize, usize, usize, usize) {
        (
            self.shards_live.load(Ordering::Relaxed),
            self.shards_suspect.load(Ordering::Relaxed),
            self.shards_draining.load(Ordering::Relaxed),
            self.shards_down.load(Ordering::Relaxed),
        )
    }

    /// Submissions answered from the score cache (never admitted).
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Submissions that rode an in-flight identical window to completion
    /// (single-flight followers — zero batch slots occupied).
    pub fn coalesced(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }

    /// Score-cache entries evicted so far (entry-count or byte cap).
    pub fn cache_evictions(&self) -> u64 {
        self.cache_evictions.load(Ordering::Relaxed)
    }

    /// Stream sessions currently open (gauge, as of the last refresh).
    pub fn sessions(&self) -> usize {
        self.sessions.load(Ordering::Relaxed)
    }

    /// Stream sessions restarted cold so far (see the field note: each
    /// is a documented fresh-stream state reset, never silent reuse).
    pub fn stream_resets(&self) -> u64 {
        self.stream_resets.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn anomalies(&self) -> u64 {
        self.anomalies.load(Ordering::Relaxed)
    }

    pub fn max_batch_seen(&self) -> usize {
        self.max_batch.load(Ordering::Relaxed)
    }

    /// Requests currently waiting in the bounded admission queue
    /// (clamped at zero — see the field note on update racing).
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed).max(0) as usize
    }

    /// Batches dispatched so far (the denominator of
    /// [`Self::mean_batch_size`]; windowed occupancy = delta of
    /// [`Self::batched_windows`] over delta of this).
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Windows dispatched inside batches so far.
    pub fn batched_windows(&self) -> u64 {
        self.batched_windows.load(Ordering::Relaxed)
    }

    /// Cumulative worker idle time (waiting on the batch queue), ns.
    pub fn worker_idle_ns(&self) -> u64 {
        self.worker_idle_ns.load(Ordering::Relaxed)
    }

    /// Cumulative worker busy time (scoring batches), ns.
    pub fn worker_busy_ns(&self) -> u64 {
        self.worker_busy_ns.load(Ordering::Relaxed)
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_windows.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Completed requests per second since start.
    pub fn throughput_rps(&self) -> f64 {
        self.completed() as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// (p50, p95, p99) end-to-end latency in microseconds.
    pub fn e2e_percentiles_us(&self) -> (f64, f64, f64) {
        let h = self.e2e_us.lock().unwrap();
        (h.percentile(0.5) * 1e6, h.percentile(0.95) * 1e6, h.percentile(0.99) * 1e6)
    }

    /// Mean service time per batch in microseconds.
    pub fn mean_service_us(&self) -> f64 {
        self.service_us.lock().unwrap().mean() * 1e6
    }

    pub fn report(&self) -> String {
        let (p50, p95, p99) = self.e2e_percentiles_us();
        let mut extra = String::new();
        if self.rejected_closed() > 0 {
            extra.push_str(&format!(" | {} rejected (closed)", self.rejected_closed()));
        }
        if self.worker_panics() > 0 {
            extra.push_str(&format!(" | {} worker panics", self.worker_panics()));
        }
        if self.cancelled() > 0 {
            extra.push_str(&format!(" | {} cancelled", self.cancelled()));
        }
        if self.shard_failovers() > 0 {
            extra.push_str(&format!(" | {} shard failovers", self.shard_failovers()));
        }
        if self.cache_hits() + self.coalesced() + self.cache_evictions() > 0 {
            extra.push_str(&format!(
                " | cache: {} hits, {} coalesced, {} evictions",
                self.cache_hits(),
                self.coalesced(),
                self.cache_evictions(),
            ));
        }
        if self.sessions() > 0 || self.stream_resets() > 0 {
            extra.push_str(&format!(
                " | streams: {} sessions, {} resets",
                self.sessions(),
                self.stream_resets(),
            ));
        }
        if self.health_probes() > 0 {
            extra.push_str(&format!(
                " | control: {} probes, {} heartbeats, {} suspects, {} deaths, \
                 {} reconnects ({} attempts)",
                self.health_probes(),
                self.heartbeats(),
                self.shard_suspects(),
                self.shard_deaths(),
                self.shard_reconnects(),
                self.shard_reconnect_attempts(),
            ));
            let (live, suspect, draining, down) = self.shard_states();
            extra.push_str(&format!(
                " | fleet: {live} live, {suspect} suspect, {draining} draining, {down} down"
            ));
        }
        if self.shard_spawns() + self.shard_retires() > 0 {
            extra.push_str(&format!(
                " | scaler: {} shard spawns, {} shard retires",
                self.shard_spawns(),
                self.shard_retires(),
            ));
        }
        format!(
            "requests: {} submitted, {} shed, {} completed, {} flagged | \
             batches: mean size {:.2}, max {} | \
             e2e latency µs: p50 {:.0}, p95 {:.0}, p99 {:.0} | \
             throughput {:.0} rps{extra}",
            self.submitted(),
            self.shed(),
            self.completed(),
            self.anomalies(),
            self.mean_batch_size(),
            self.max_batch_seen(),
            p50,
            p95,
            p99,
            self.throughput_rps(),
        )
    }
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = ServerMetrics::new();
        m.on_submit();
        m.on_submit();
        m.on_shed();
        m.on_batch(2, 100.0);
        for (id, anomaly) in [(0u64, false), (1, true)] {
            m.on_response(&Response {
                id,
                score: 0.1,
                is_anomaly: anomaly,
                queue_us: 50.0,
                service_us: 100.0,
                e2e_us: 150.0,
            });
        }
        assert_eq!(m.submitted(), 2);
        assert_eq!(m.shed(), 1);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.anomalies(), 1);
        assert_eq!(m.max_batch_seen(), 2);
        assert!((m.mean_batch_size() - 2.0).abs() < 1e-12);
        let (p50, _, _) = m.e2e_percentiles_us();
        assert!(p50 > 100.0 && p50 < 250.0, "p50 {p50}");
        assert!(m.report().contains("2 completed"));
    }

    #[test]
    fn queue_depth_gauge_tracks_submit_and_dequeue() {
        let m = ServerMetrics::new();
        assert_eq!(m.queue_depth(), 0);
        m.on_submit();
        m.on_submit();
        assert_eq!(m.queue_depth(), 2);
        m.on_dequeue();
        assert_eq!(m.queue_depth(), 1);
        m.on_dequeue();
        // The batcher can pop before the submitter's increment lands;
        // the extra dequeue must clamp, not wrap.
        m.on_dequeue();
        assert_eq!(m.queue_depth(), 0);
        m.on_submit();
        assert!(m.queue_depth() <= 1, "clamped reads must stay sane");
    }

    #[test]
    fn closed_rejections_and_panics_are_counted() {
        let m = ServerMetrics::new();
        assert_eq!((m.rejected_closed(), m.worker_panics()), (0, 0));
        assert!(!m.report().contains("rejected (closed)"));
        m.on_rejected_closed();
        m.on_rejected_closed();
        m.on_worker_panic();
        assert_eq!(m.rejected_closed(), 2);
        assert_eq!(m.worker_panics(), 1);
        let report = m.report();
        assert!(report.contains("2 rejected (closed)"), "{report}");
        assert!(report.contains("1 worker panics"), "{report}");
    }

    #[test]
    fn cancelled_and_failover_counters_surface_in_the_report() {
        let m = ServerMetrics::new();
        assert_eq!((m.cancelled(), m.shard_failovers()), (0, 0));
        let quiet = m.report();
        assert!(!quiet.contains("cancelled") && !quiet.contains("failover"), "{quiet}");
        m.on_cancelled();
        m.on_cancelled();
        m.on_shard_failover();
        assert_eq!(m.cancelled(), 2);
        assert_eq!(m.shard_failovers(), 1);
        let report = m.report();
        assert!(report.contains("2 cancelled"), "{report}");
        assert!(report.contains("1 shard failovers"), "{report}");
    }

    #[test]
    fn cache_counters_surface_in_the_report() {
        let m = ServerMetrics::new();
        assert_eq!((m.cache_hits(), m.coalesced(), m.cache_evictions()), (0, 0, 0));
        assert!(!m.report().contains("cache:"), "quiet report must omit the cache segment");
        m.on_cache_hit();
        m.on_cache_hit();
        m.on_coalesced();
        m.on_cache_evictions(3);
        assert_eq!(m.cache_hits(), 2);
        assert_eq!(m.coalesced(), 1);
        assert_eq!(m.cache_evictions(), 3);
        let report = m.report();
        assert!(report.contains("cache: 2 hits, 1 coalesced, 3 evictions"), "{report}");
    }

    #[test]
    fn stream_gauges_surface_in_the_report() {
        let m = ServerMetrics::new();
        assert_eq!((m.sessions(), m.stream_resets()), (0, 0));
        assert!(!m.report().contains("streams:"), "quiet report must omit the stream segment");
        m.set_sessions(5);
        m.on_stream_resets(2);
        m.on_stream_resets(1);
        assert_eq!(m.sessions(), 5);
        assert_eq!(m.stream_resets(), 3);
        let report = m.report();
        assert!(report.contains("streams: 5 sessions, 3 resets"), "{report}");
        m.set_sessions(0);
        assert!(m.report().contains("streams: 0 sessions, 3 resets"), "resets keep the segment");
    }

    #[test]
    fn control_plane_counters_and_gauges_surface_in_the_report() {
        let m = ServerMetrics::new();
        assert_eq!(m.shard_states(), (0, 0, 0, 0));
        let quiet = m.report();
        assert!(!quiet.contains("control:"), "{quiet}");
        for _ in 0..4 {
            m.on_health_probe();
        }
        for _ in 0..3 {
            m.on_heartbeat();
        }
        m.on_shard_suspect();
        m.on_shard_death();
        m.on_shard_reconnect_attempt();
        m.on_shard_reconnect_attempt();
        m.on_shard_reconnect();
        m.set_shard_states(2, 1, 0, 1);
        assert_eq!(m.health_probes(), 4);
        assert_eq!(m.heartbeats(), 3);
        assert_eq!(m.shard_suspects(), 1);
        assert_eq!(m.shard_deaths(), 1);
        assert_eq!(m.shard_reconnect_attempts(), 2);
        assert_eq!(m.shard_reconnects(), 1);
        assert_eq!(m.shard_states(), (2, 1, 0, 1));
        let report = m.report();
        assert!(report.contains("4 probes"), "{report}");
        assert!(report.contains("1 reconnects (2 attempts)"), "{report}");
        assert!(report.contains("2 live, 1 suspect, 0 draining, 1 down"), "{report}");
    }

    #[test]
    fn scaler_counters_surface_in_the_report() {
        let m = ServerMetrics::new();
        assert_eq!((m.shard_spawns(), m.shard_retires()), (0, 0));
        assert!(!m.report().contains("scaler:"), "quiet report must omit the scaler segment");
        m.on_shard_spawn();
        m.on_shard_spawn();
        m.on_shard_retire();
        assert_eq!(m.shard_spawns(), 2);
        assert_eq!(m.shard_retires(), 1);
        let report = m.report();
        assert!(report.contains("scaler: 2 shard spawns, 1 shard retires"), "{report}");
    }

    #[test]
    fn worker_time_accumulates() {
        let m = ServerMetrics::new();
        m.on_worker_idle(1_000);
        m.on_worker_idle(500);
        m.on_batch(4, 2.0); // 2 µs of service = 2000 ns busy
        assert_eq!(m.worker_idle_ns(), 1_500);
        assert_eq!(m.worker_busy_ns(), 2_000);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.batched_windows(), 4);
    }
}
