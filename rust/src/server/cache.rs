//! Exact-match window score cache with single-flight coalescing.
//!
//! The cheapest timestep is the one never recomputed: periodic sensors,
//! retry storms, and fan-out dashboards resubmit identical windows
//! constantly. Because the whole stack is bit-deterministic (same window
//! bytes → same `f64` score, see `integration_bitexact.rs`), an
//! exact-match cache preserves every correctness guarantee trivially —
//! a hit returns the very bits the backend would have produced.
//!
//! Two mechanisms live here, both per-lane:
//!
//! - **LRU score cache** — keyed by the raw bit pattern of the window
//!   (`window_key`), capped by entry count and resident bytes. The key
//!   encoding is injective (length-prefixed rows of `f32::to_bits`), so
//!   collision safety needs no hashing argument: the full encoding IS
//!   the `HashMap` key.
//! - **Single-flight map** — concurrent submits of a window already being
//!   scored attach to the leader's completion instead of occupying batch
//!   slots. The leader registers before admission (under the map lock, so
//!   exactly one leader exists per key) and fans its outcome — success or
//!   failure — out to followers via `release`. Blocking submitters never
//!   lead: a blocking leader has no completion hook, so a worker panic
//!   would strand its followers forever.

use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

use super::front::TicketShared;
use super::{Completion, Response};
use crate::workload::Window;

/// Per-lane score-cache sizing. `entries == 0` disables caching for the
/// lane entirely (no lookup, no coalescing).
#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Maximum number of resident entries.
    pub entries: usize,
    /// Maximum resident bytes (keys + bookkeeping overhead).
    pub bytes: usize,
}

impl Default for CacheConfig {
    fn default() -> CacheConfig {
        CacheConfig { entries: 4096, bytes: 64 << 20 }
    }
}

/// Injective encoding of a window's raw sample bits. Shared by the cache
/// map and the in-flight map; `Arc` so clones are pointer-sized.
pub(crate) type CacheKey = Arc<[u32]>;

/// Encode a window's data as a length-prefixed bit string: row count,
/// then per row its length followed by each sample's `to_bits()`. The
/// prefixes make the encoding injective across layouts — `[[1,2],[3]]`
/// and `[[1],[2,3]]` differ even though the flat samples match. The
/// anomaly label is deliberately excluded: scoring depends only on the
/// data, and cached scores must not split on metadata.
pub(crate) fn window_key(w: &Window) -> CacheKey {
    let total: usize = w.data.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(1 + w.data.len() + total);
    out.push(w.data.len() as u32);
    for row in &w.data {
        out.push(row.len() as u32);
        out.extend(row.iter().map(|v| v.to_bits()));
    }
    out.into()
}

/// Flat bookkeeping estimate per entry: two map slots, an `Arc` header,
/// the `Entry` struct. Keeps the byte cap honest without pretending to
/// allocator-level precision.
const ENTRY_OVERHEAD: usize = 96;

fn key_bytes(key: &CacheKey) -> usize {
    key.len() * 4 + ENTRY_OVERHEAD
}

struct Entry {
    score: f64,
    /// Recency tick; also the entry's key in `recency`.
    tick: u64,
    bytes: usize,
}

struct LruInner {
    map: HashMap<CacheKey, Entry>,
    /// tick → key, ordered oldest-first; eviction pops the front.
    recency: BTreeMap<u64, CacheKey>,
    tick: u64,
    resident: usize,
}

/// A submitter waiting on another request's in-flight score.
pub(crate) enum Follower {
    /// Async submitter: complete its ticket slot directly.
    Async { id: u64, slot: Arc<TicketShared> },
    /// Blocking submitter: forward the response over its reply channel.
    /// On leader failure the sender is simply dropped, which errors the
    /// follower's `recv` — `score_blocking` reports that as `Closed`.
    Blocking { id: u64, reply: Sender<Response> },
}

/// Per-lane cache + single-flight state. All methods are lock-internal
/// and safe to call from any thread.
pub(crate) struct LaneCache {
    cfg: CacheConfig,
    lru: Mutex<LruInner>,
    inflight: Mutex<HashMap<CacheKey, Vec<Follower>>>,
}

impl LaneCache {
    pub(crate) fn new(cfg: CacheConfig) -> LaneCache {
        LaneCache {
            cfg,
            lru: Mutex::new(LruInner {
                map: HashMap::new(),
                recency: BTreeMap::new(),
                tick: 0,
                resident: 0,
            }),
            inflight: Mutex::new(HashMap::new()),
        }
    }

    /// Cached score for `key`, refreshing its recency on a hit.
    pub(crate) fn lookup(&self, key: &CacheKey) -> Option<f64> {
        let mut lru = self.lru.lock().unwrap();
        let inner = &mut *lru;
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner.map.get_mut(key)?;
        let old = std::mem::replace(&mut entry.tick, tick);
        inner.recency.remove(&old);
        inner.recency.insert(tick, key.clone());
        Some(entry.score)
    }

    /// Insert (or refresh) a scored entry, then evict oldest-first until
    /// both caps hold. Returns the number of evictions performed.
    pub(crate) fn insert(&self, key: CacheKey, score: f64) -> u64 {
        let bytes = key_bytes(&key);
        let mut lru = self.lru.lock().unwrap();
        let inner = &mut *lru;
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(prev) = inner.map.insert(key.clone(), Entry { score, tick, bytes }) {
            inner.recency.remove(&prev.tick);
            inner.resident = inner.resident.saturating_sub(prev.bytes);
        }
        inner.recency.insert(tick, key);
        inner.resident += bytes;
        let mut evicted = 0u64;
        while inner.map.len() > self.cfg.entries || inner.resident > self.cfg.bytes {
            let Some((&oldest, _)) = inner.recency.iter().next() else { break };
            let victim = inner.recency.remove(&oldest).expect("tick present");
            if let Some(e) = inner.map.remove(&victim) {
                inner.resident = inner.resident.saturating_sub(e.bytes);
            }
            evicted += 1;
        }
        evicted
    }

    /// Single-flight election: returns `true` if the caller became the
    /// leader for `key` (it must go on to submit, then `release`).
    /// Otherwise the built follower was attached to the existing flight
    /// and the caller must NOT submit. The whole decision happens under
    /// the in-flight map lock, so exactly one caller leads per key.
    pub(crate) fn lead_or_attach(
        &self,
        key: &CacheKey,
        follower: impl FnOnce() -> Follower,
    ) -> bool {
        use std::collections::hash_map::Entry as MapEntry;
        let mut inflight = self.inflight.lock().unwrap();
        match inflight.entry(key.clone()) {
            MapEntry::Occupied(mut e) => {
                e.get_mut().push(follower());
                false
            }
            MapEntry::Vacant(v) => {
                v.insert(Vec::new());
                true
            }
        }
    }

    /// Attach-only variant for blocking submitters: joins an existing
    /// flight but never starts one. Returns whether it attached.
    pub(crate) fn attach(&self, key: &CacheKey, follower: impl FnOnce() -> Follower) -> bool {
        let mut inflight = self.inflight.lock().unwrap();
        if let Some(fs) = inflight.get_mut(key) {
            fs.push(follower());
            true
        } else {
            false
        }
    }

    /// Fan the leader's outcome out to every follower and retire the
    /// flight. Followers are completed OUTSIDE the map lock — ticket
    /// callbacks may re-enter submission paths. Idempotent on a key with
    /// no flight (leader admission failure after a racing release).
    pub(crate) fn release(&self, key: &CacheKey, outcome: &Completion) {
        let followers = { self.inflight.lock().unwrap().remove(key).unwrap_or_default() };
        for f in followers {
            match (f, outcome) {
                (Follower::Async { id, slot }, Ok(r)) => {
                    slot.complete(Ok(Response { id, ..r.clone() }));
                }
                (Follower::Async { slot, .. }, Err(e)) => {
                    slot.complete(Err(e.clone()));
                }
                (Follower::Blocking { id, reply }, Ok(r)) => {
                    let _ = reply.send(Response { id, ..r.clone() });
                }
                // Dropping the sender errors the follower's recv.
                (Follower::Blocking { .. }, Err(_)) => {}
            }
        }
    }

    /// Number of flights currently open (leaders submitted, not yet
    /// released). Diagnostic; used by tests to prove no leaked flights.
    pub(crate) fn flights(&self) -> usize {
        self.inflight.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::super::front::Ticket;
    use super::super::SubmitError;
    use super::*;
    use std::sync::mpsc::channel;

    fn win(data: Vec<Vec<f32>>) -> Window {
        Window { data, anomaly: None }
    }

    #[test]
    fn window_key_separates_layout_nan_bits_and_signed_zero() {
        let nan_a = f32::from_bits(0x7FC0_0001);
        let nan_b = f32::from_bits(0x7FC0_0002);
        let windows = vec![
            win(vec![vec![1.0, 2.0], vec![3.0]]),
            win(vec![vec![1.0], vec![2.0, 3.0]]),
            win(vec![vec![1.0, 2.0, 3.0]]),
            win(vec![vec![1.0], vec![2.0], vec![3.0]]),
            win(vec![vec![], vec![5.0]]),
            win(vec![vec![5.0], vec![]]),
            win(vec![vec![5.0]]),
            win(vec![vec![0.0]]),
            win(vec![vec![-0.0]]),
            win(vec![vec![nan_a]]),
            win(vec![vec![nan_b]]),
        ];
        let keys: Vec<CacheKey> = windows.iter().map(window_key).collect();
        for i in 0..keys.len() {
            for j in (i + 1)..keys.len() {
                assert_ne!(keys[i], keys[j], "windows {i} and {j} collide");
            }
        }
        // Identical bits key identically, and the anomaly label is ignored.
        let mut labeled = win(vec![vec![nan_a]]);
        labeled.anomaly = Some(crate::workload::AnomalyKind::Spike);
        assert_eq!(window_key(&windows[9]), window_key(&labeled));
    }

    #[test]
    fn lru_evicts_oldest_first_and_lookup_refreshes() {
        let cache = LaneCache::new(CacheConfig { entries: 2, bytes: usize::MAX });
        let (a, b) = (window_key(&win(vec![vec![1.0]])), window_key(&win(vec![vec![2.0]])));
        let (c, d) = (window_key(&win(vec![vec![3.0]])), window_key(&win(vec![vec![4.0]])));
        assert_eq!(cache.insert(a.clone(), 0.1), 0);
        assert_eq!(cache.insert(b.clone(), 0.2), 0);
        assert_eq!(cache.lookup(&a), Some(0.1)); // refresh: b is now oldest
        assert_eq!(cache.insert(c.clone(), 0.3), 1);
        assert_eq!(cache.lookup(&b), None);
        assert_eq!(cache.lookup(&a), Some(0.1)); // refresh again: c oldest
        assert_eq!(cache.insert(d, 0.4), 1);
        assert_eq!(cache.lookup(&c), None);
        assert_eq!(cache.lookup(&a), Some(0.1));
    }

    #[test]
    fn byte_cap_bounds_resident_size() {
        let probe = window_key(&win(vec![vec![0.0]]));
        let cache = LaneCache::new(CacheConfig {
            entries: usize::MAX,
            bytes: key_bytes(&probe) * 3,
        });
        for i in 0..10u32 {
            cache.insert(window_key(&win(vec![vec![i as f32]])), i as f64);
        }
        // Only the 3 newest single-sample keys fit under the byte cap.
        for i in 0..7u32 {
            assert_eq!(cache.lookup(&window_key(&win(vec![vec![i as f32]]))), None);
        }
        for i in 7..10u32 {
            assert_eq!(
                cache.lookup(&window_key(&win(vec![vec![i as f32]]))),
                Some(i as f64)
            );
        }
    }

    #[test]
    fn flights_lead_attach_release_ok_and_err() {
        let cache = LaneCache::new(CacheConfig::default());
        let key = window_key(&win(vec![vec![7.0]]));

        // First caller leads; its follower closure must not run.
        assert!(cache.lead_or_attach(&key, || unreachable!("leader builds no follower")));
        assert_eq!(cache.flights(), 1);

        // An async and a blocking follower attach to the open flight.
        let (ticket, slot) = Ticket::raw(5, Arc::from("t"));
        assert!(!cache.lead_or_attach(&key, || Follower::Async { id: 5, slot }));
        let (reply, rx) = channel();
        assert!(cache.attach(&key, || Follower::Blocking { id: 9, reply }));
        // Blocking attach on a fresh key refuses to lead.
        let fresh = window_key(&win(vec![vec![8.0]]));
        let (lonely, _lonely_rx) = channel::<Response>();
        assert!(!cache.attach(&fresh, || Follower::Blocking { id: 1, reply: lonely }));

        // Release Ok: both followers see the score under their own id.
        let resp = Response {
            id: 1,
            score: 0.5,
            is_anomaly: false,
            queue_us: 1.0,
            service_us: 2.0,
            e2e_us: 3.0,
        };
        cache.release(&key, &Ok(resp));
        assert_eq!(cache.flights(), 0);
        let got = ticket.wait().expect("async follower completed");
        assert_eq!((got.id, got.score), (5, 0.5));
        let got = rx.recv().expect("blocking follower completed");
        assert_eq!((got.id, got.score), (9, 0.5));

        // Release Err: async follower poisoned, blocking sender dropped.
        assert!(cache.lead_or_attach(&key, || unreachable!()));
        let (ticket, slot) = Ticket::raw(11, Arc::from("t"));
        assert!(!cache.lead_or_attach(&key, || Follower::Async { id: 11, slot }));
        let (reply, rx) = channel();
        assert!(cache.attach(&key, || Follower::Blocking { id: 12, reply }));
        cache.release(&key, &Err(SubmitError::Cancelled));
        assert_eq!(ticket.wait(), Err(SubmitError::Cancelled));
        assert!(rx.recv().is_err(), "blocking follower's sender must be dropped");

        // Releasing a key with no flight is a no-op.
        cache.release(&key, &Err(SubmitError::Closed));
        assert_eq!(cache.flights(), 0);
    }
}
