//! Fleet-tier process autoscaling: spawning and retiring whole shard
//! processes from fleet-wide load.
//!
//! The per-lane tier ([`super::autoscale`]) resizes worker pools inside
//! one process; this tier closes the same loop one level up — the
//! runtime analogue of sizing the accelerator to the workload, done
//! with processes instead of fabric:
//!
//! ```text
//!            every `fleet_tick`
//!  ┌────────────────────────────────────────────────────────────┐
//!  │ sample   ShardRouter::fleet_sample(): live shards, shed    │
//!  │          delta, in-flight total, worst p99 EWMA            │
//!  │          (all already flowing through heartbeats)          │
//!  │ decide   pressure → Up, sustained quiet → Down, else Hold  │
//!  │          (the same streak hysteresis as the lane tier,     │
//!  │           clamped to [min_shards, max_shards])             │
//!  │ apply    Up:   ShardSpawner — free port, spawn             │
//!  │                `fleet serve --ephemeral`, readiness probe  │
//!  │                via the wire handshake, add_shard           │
//!  │          Down: pick the least-loaded spawned shard,        │
//!  │                retire_shard (drain over the wire), then    │
//!  │                reap the child once the slot lands Dead     │
//!  └────────────────────────────────────────────────────────────┘
//! ```
//!
//! Scale-down is lossless by construction: [`ShardRouter::retire_shard`]
//! rides the PR-6 drain path (`Leave` → Draining → in-flight zero →
//! clean close), so every in-flight ticket completes before the child is
//! reaped — the integration suite pins zero lost tickets and bit-exact
//! scores across churn. The scaler only ever retires shards *it*
//! spawned: the operator's static fleet is the floor it returns to.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::net::ShardClient;

use super::shard::{FleetSample, ShardRouter, ShardState};
use super::ScaleDecision;

/// Fleet-tier scaling bounds and hysteresis knobs. The thresholds read
/// against in-flight submissions *per live shard* (the fleet's queue
/// depth analogue); any shed since the last tick counts as pressure
/// outright, exactly like the lane tier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetScalePolicy {
    /// Never drain the fleet below this many live shards.
    pub min_shards: usize,
    /// Never spawn beyond this many live shards.
    pub max_shards: usize,
    /// Pressure threshold: a tick counts toward scale-up when in-flight
    /// per live shard reaches this (or any request was shed since the
    /// last tick).
    pub up_inflight_per_shard: f64,
    /// Consecutive pressure ticks required before one spawn.
    pub up_ticks: u32,
    /// Quiet threshold: a tick counts toward scale-down only when
    /// nothing was shed and in-flight per live shard is at most this.
    pub down_inflight_per_shard: f64,
    /// Consecutive quiet ticks required before one retire.
    pub down_ticks: u32,
}

impl Default for FleetScalePolicy {
    fn default() -> Self {
        FleetScalePolicy {
            min_shards: 1,
            max_shards: 4,
            up_inflight_per_shard: 32.0,
            up_ticks: 2,
            down_inflight_per_shard: 2.0,
            down_ticks: 8,
        }
    }
}

impl FleetScalePolicy {
    /// A policy bounded to `min..=max` live shards, other knobs default.
    pub fn bounded(min: usize, max: usize) -> FleetScalePolicy {
        let min = min.max(1);
        FleetScalePolicy { min_shards: min, max_shards: max.max(min), ..Default::default() }
    }
}

/// Controller memory across ticks: the previous cumulative shed count
/// and the hysteresis streaks.
#[derive(Debug, Default)]
struct FleetTrack {
    last_shed: u64,
    up_streak: u32,
    down_streak: u32,
}

/// The pure fleet-tier decision: fold one sample into the streaks and
/// report whether the process count should move. Same shape as the lane
/// tier's, with the floor/ceiling clamp folded in — a completed streak
/// at a bound emits Hold (and resets, so pressure at the ceiling doesn't
/// bank an instant spawn for later).
fn decide(
    policy: &FleetScalePolicy,
    sample: &FleetSample,
    track: &mut FleetTrack,
) -> ScaleDecision {
    let shed_delta = sample.shed_total.saturating_sub(track.last_shed);
    track.last_shed = sample.shed_total;
    let per_shard = sample.inflight as f64 / sample.live.max(1) as f64;
    let pressure = shed_delta > 0 || per_shard >= policy.up_inflight_per_shard;
    let quiet = shed_delta == 0 && per_shard <= policy.down_inflight_per_shard;
    if pressure {
        track.down_streak = 0;
        track.up_streak += 1;
        if track.up_streak >= policy.up_ticks {
            track.up_streak = 0;
            if sample.live < policy.max_shards {
                return ScaleDecision::Up;
            }
        }
    } else if quiet {
        track.up_streak = 0;
        track.down_streak += 1;
        if track.down_streak >= policy.down_ticks {
            track.down_streak = 0;
            if sample.live > policy.min_shards {
                return ScaleDecision::Down;
            }
        }
    } else {
        track.up_streak = 0;
        track.down_streak = 0;
    }
    ScaleDecision::Hold
}

/// Spawns ephemeral shard processes: allocate a free loopback port,
/// launch `<binary> <base_args..> --bind <addr> --ephemeral`, and probe
/// readiness by completing the wire handshake against the new port.
/// A child that never becomes ready is killed *and reaped* before the
/// error returns — a failed spawn leaves no zombie and no router slot.
pub struct ShardSpawner {
    binary: PathBuf,
    base_args: Vec<String>,
    ready_timeout: Duration,
}

impl ShardSpawner {
    /// A spawner launching `binary` with `base_args` before the
    /// spawner-owned `--bind`/`--ephemeral` flags. For the fleet CLI the
    /// binary is the running executable itself and the args are
    /// `["fleet", "serve", ..model flags..]`.
    pub fn new(binary: impl Into<PathBuf>, base_args: Vec<String>) -> ShardSpawner {
        ShardSpawner { binary: binary.into(), base_args, ready_timeout: Duration::from_secs(10) }
    }

    /// How long a child gets to open its port and answer the handshake
    /// before the spawn is declared failed (default 10 s).
    pub fn ready_timeout(mut self, d: Duration) -> ShardSpawner {
        self.ready_timeout = d;
        self
    }

    /// Spawn one shard child and wait for it to serve the handshake.
    /// Returns the ready child and its address; the caller admits it
    /// with [`ShardRouter::add_shard`].
    pub fn spawn_shard(&self) -> std::io::Result<SpawnedShard> {
        // Bind port 0 to have the kernel pick a free port, then release
        // it for the child. The classic TOCTOU gap is tolerable on
        // loopback: a steal surfaces as a readiness failure, not a hang.
        let probe = std::net::TcpListener::bind("127.0.0.1:0")?;
        let addr = probe.local_addr()?.to_string();
        drop(probe);
        let mut child = Command::new(&self.binary)
            .args(&self.base_args)
            .arg("--bind")
            .arg(&addr)
            .arg("--ephemeral")
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()?;
        let deadline = Instant::now() + self.ready_timeout;
        loop {
            // Readiness = the full version handshake, not a bare TCP
            // accept: the child is provably speaking the protocol.
            if let Ok(client) = ShardClient::connect(&addr) {
                client.shutdown();
                return Ok(SpawnedShard { addr, child });
            }
            if Instant::now() >= deadline {
                let pid = child.id();
                // Kill then reap: wait() after kill cannot hang, and a
                // reaped child is no zombie.
                let _ = child.kill();
                let _ = child.wait();
                return Err(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    format!(
                        "shard at {addr} (pid {pid}) not ready within {:?}; killed and reaped",
                        self.ready_timeout
                    ),
                ));
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}

/// A ready shard child process, as produced by
/// [`ShardSpawner::spawn_shard`].
pub struct SpawnedShard {
    addr: String,
    child: Child,
}

impl SpawnedShard {
    /// The loopback address the child is serving on.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Non-blocking reap: `Some(status)` once the child has exited (an
    /// ephemeral child exits on its own after a drain completes).
    pub fn try_wait(&mut self) -> std::io::Result<Option<std::process::ExitStatus>> {
        self.child.try_wait()
    }

    /// Kill and reap the child unconditionally.
    pub fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// One scaler-owned shard child: its router slot, the OS process, and —
/// once a Down decision picked it — when the drain was requested.
struct ManagedShard {
    slot: usize,
    spawned: SpawnedShard,
    draining_since: Option<Instant>,
}

/// If a retiring child has not exited this long after its drain was
/// requested, it is killed. The drain path normally finishes in a few
/// health ticks; this is the backstop against a wedged child.
const RETIRE_KILL_AFTER: Duration = Duration::from_secs(30);

/// The fleet-tier controller: one background thread sampling
/// [`ShardRouter::fleet_sample`] every tick and spawning/retiring
/// ephemeral shard processes within the policy bounds. Stopping is
/// idempotent, happens on drop, and kills any children still alive —
/// the scaler never leaks processes past its own lifetime.
pub struct FleetScaler {
    stop_tx: Sender<()>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

impl FleetScaler {
    /// Spawn the controller over `router`, ticking every `tick`. Spawn
    /// and retire events tick the router's `shard_spawns`/`shard_retires`
    /// metrics. Panics when `policy` is unrunnable
    /// (`min_shards == 0` or `min_shards > max_shards`).
    pub fn start(
        router: Arc<ShardRouter>,
        spawner: ShardSpawner,
        policy: FleetScalePolicy,
        tick: Duration,
    ) -> FleetScaler {
        assert!(
            1 <= policy.min_shards && policy.min_shards <= policy.max_shards,
            "FleetScalePolicy: need 1 <= min_shards <= max_shards"
        );
        let (stop_tx, stop_rx) = channel::<()>();
        let handle = std::thread::Builder::new()
            .name("fleet-scaler".into())
            .spawn(move || {
                // Prime against the current cumulative shed so the first
                // tick sees only activity since start, not the fleet's
                // lifetime shed history.
                let mut track = FleetTrack {
                    last_shed: router.fleet_sample().shed_total,
                    ..FleetTrack::default()
                };
                let mut children: Vec<ManagedShard> = Vec::new();
                loop {
                    match stop_rx.recv_timeout(tick) {
                        Err(RecvTimeoutError::Timeout) => {}
                        Ok(()) | Err(RecvTimeoutError::Disconnected) => break,
                    }
                    reap_retired(&router, &mut children);
                    let sample = router.fleet_sample();
                    match decide(&policy, &sample, &mut track) {
                        ScaleDecision::Up => scale_up(&router, &spawner, &mut children),
                        ScaleDecision::Down => scale_down(&router, &policy, &mut children),
                        ScaleDecision::Hold => {}
                    }
                }
                // Teardown: no child outlives the scaler. Anything still
                // here either never got a Down decision (traffic is over
                // by stop time — a kill poisons nothing) or is mid-drain
                // and gets cut short the same way. A draining child still
                // counts as a retire: the drain was requested, stop just
                // beat the reap tick to it.
                for mut m in children {
                    let was_draining = m.draining_since.is_some();
                    let _ = m.spawned.child.kill();
                    let _ = m.spawned.child.wait();
                    if was_draining {
                        router.metrics().on_shard_retire();
                    }
                }
            })
            .expect("spawn fleet scaler");
        FleetScaler { stop_tx, handle: Mutex::new(Some(handle)) }
    }

    /// Stop the controller, reap its children, and join (idempotent).
    pub fn stop(&self) {
        let _ = self.stop_tx.send(());
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for FleetScaler {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One Up step: spawn a child, wait for readiness, admit it. A child
/// that fails readiness was already killed and reaped by the spawner; a
/// child the router refuses is killed here — either way no zombie and no
/// phantom slot.
fn scale_up(router: &ShardRouter, spawner: &ShardSpawner, children: &mut Vec<ManagedShard>) {
    let Ok(mut spawned) = spawner.spawn_shard() else {
        return;
    };
    match router.add_shard(&spawned.addr) {
        Ok(slot) => {
            router.metrics().on_shard_spawn();
            children.push(ManagedShard { slot, spawned, draining_since: None });
        }
        Err(_) => {
            let _ = spawned.child.kill();
            let _ = spawned.child.wait();
        }
    }
}

/// One Down step: among scaler-owned, not-yet-draining children whose
/// slots are still Live, drain the least-loaded one. Only spawned shards
/// are ever retired — the operator's static fleet is the floor.
fn scale_down(router: &ShardRouter, policy: &FleetScalePolicy, children: &mut [ManagedShard]) {
    let target = children
        .iter_mut()
        .filter(|m| {
            m.draining_since.is_none() && router.shard_state(m.slot) == ShardState::Live
        })
        .min_by_key(|m| router.shard_inflight(m.slot));
    let Some(m) = target else {
        return;
    };
    // Re-clamp against the floor at apply time: live may have moved
    // (a shard died, a spawn landed) since the decision's sample.
    if router.live_shards() <= policy.min_shards {
        return;
    }
    // A failed drain request means the connection is already gone — the
    // slot is retired either way, so fall through to the reap path.
    let _ = router.retire_shard(m.slot);
    m.draining_since = Some(Instant::now());
}

/// Reap draining children: once the router observed the drain complete
/// (slot Dead) the child exits on its own and `try_wait` collects it;
/// a child wedged past [`RETIRE_KILL_AFTER`] is killed. Each reaped
/// child counts one `shard retires`.
fn reap_retired(router: &ShardRouter, children: &mut Vec<ManagedShard>) {
    children.retain_mut(|m| {
        let Some(since) = m.draining_since else {
            return true;
        };
        if since.elapsed() >= RETIRE_KILL_AFTER {
            let _ = m.spawned.child.kill();
            let _ = m.spawned.child.wait();
            router.metrics().on_shard_retire();
            return false;
        }
        // The ephemeral child exits once its drain completes; until the
        // slot lands Dead it is still answering in-flight work.
        if router.shard_state(m.slot) != ShardState::Dead {
            return true;
        }
        match m.spawned.child.try_wait() {
            Ok(Some(_)) => {
                router.metrics().on_shard_retire();
                false
            }
            // Dead slot but the process is still winding down its
            // connections: check again next tick.
            Ok(None) => true,
            Err(_) => {
                let _ = m.spawned.child.kill();
                let _ = m.spawned.child.wait();
                router.metrics().on_shard_retire();
                false
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(live: usize, shed_total: u64, inflight: u64) -> FleetSample {
        FleetSample { live, shed_total, inflight, p99_us: 0.0 }
    }

    fn policy() -> FleetScalePolicy {
        FleetScalePolicy {
            min_shards: 1,
            max_shards: 3,
            up_inflight_per_shard: 16.0,
            up_ticks: 2,
            down_inflight_per_shard: 1.0,
            down_ticks: 3,
        }
    }

    #[test]
    fn scale_up_requires_sustained_pressure() {
        let p = policy();
        let mut t = FleetTrack::default();
        // One pressured tick, one deadband tick, then two pressured: the
        // deadband tick must reset the streak.
        assert_eq!(decide(&p, &sample(1, 0, 100), &mut t), ScaleDecision::Hold);
        assert_eq!(decide(&p, &sample(1, 0, 8), &mut t), ScaleDecision::Hold);
        assert_eq!(decide(&p, &sample(1, 0, 100), &mut t), ScaleDecision::Hold);
        assert_eq!(decide(&p, &sample(1, 0, 100), &mut t), ScaleDecision::Up);
        // Emitted decisions reset the streak: one step per streak.
        assert_eq!(decide(&p, &sample(1, 0, 100), &mut t), ScaleDecision::Hold);
    }

    #[test]
    fn shed_delta_counts_as_pressure_and_is_differenced() {
        let p = FleetScalePolicy { up_ticks: 1, ..policy() };
        let mut t = FleetTrack { last_shed: 40, ..FleetTrack::default() };
        // Cumulative 50 against a remembered 40: 10 shed this tick.
        assert_eq!(decide(&p, &sample(1, 50, 0), &mut t), ScaleDecision::Up);
        // Unchanged cumulative count: no new shed, idle fleet → quiet.
        assert_eq!(decide(&p, &sample(1, 50, 0), &mut t), ScaleDecision::Hold);
        assert_eq!(t.down_streak, 1, "no-new-shed idle tick must count toward Down");
    }

    #[test]
    fn scale_down_requires_sustained_quiet_and_respects_floor() {
        let p = policy();
        let mut t = FleetTrack::default();
        // Two shards, three quiet ticks → Down.
        assert_eq!(decide(&p, &sample(2, 0, 0), &mut t), ScaleDecision::Hold);
        assert_eq!(decide(&p, &sample(2, 0, 0), &mut t), ScaleDecision::Hold);
        assert_eq!(decide(&p, &sample(2, 0, 0), &mut t), ScaleDecision::Down);
        // At the floor the completed streak emits Hold instead.
        for _ in 0..10 {
            assert_eq!(decide(&p, &sample(1, 0, 0), &mut t), ScaleDecision::Hold);
        }
    }

    #[test]
    fn ceiling_clamps_sustained_pressure_to_hold() {
        let p = policy();
        let mut t = FleetTrack::default();
        for _ in 0..10 {
            assert_eq!(decide(&p, &sample(3, 0, 1000), &mut t), ScaleDecision::Hold);
        }
    }

    #[test]
    fn deadband_holds_and_resets_both_streaks() {
        let p = policy();
        let mut t = FleetTrack::default();
        // Almost-complete streaks on both sides, each broken by a
        // deadband tick (between the down and up thresholds).
        assert_eq!(decide(&p, &sample(2, 0, 200), &mut t), ScaleDecision::Hold);
        assert_eq!(decide(&p, &sample(2, 0, 0), &mut t), ScaleDecision::Hold);
        assert_eq!(decide(&p, &sample(2, 0, 0), &mut t), ScaleDecision::Hold);
        assert_eq!(decide(&p, &sample(2, 0, 10), &mut t), ScaleDecision::Hold);
        assert_eq!((t.up_streak, t.down_streak), (0, 0));
    }

    #[test]
    fn bounded_policy_clamps_degenerate_ranges() {
        let p = FleetScalePolicy::bounded(0, 0);
        assert_eq!((p.min_shards, p.max_shards), (1, 1));
        let p = FleetScalePolicy::bounded(3, 2);
        assert_eq!((p.min_shards, p.max_shards), (3, 3));
    }

    #[test]
    fn spawner_readiness_timeout_kills_and_reaps_the_child() {
        // A child that never opens the port: the bind/ephemeral flags the
        // spawner appends land as unused positional args to `sh -c`.
        let spawner = ShardSpawner::new("/bin/sh", vec!["-c".into(), "sleep 300".into()])
            .ready_timeout(Duration::from_millis(200));
        let started = Instant::now();
        let err = spawner.spawn_shard().expect_err("never-ready child must fail the spawn");
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut);
        assert!(started.elapsed() >= Duration::from_millis(200));
        // The error names the pid it killed; the child must be fully
        // reaped — no /proc entry left, not even a zombie's.
        #[cfg(target_os = "linux")]
        {
            let msg = err.to_string();
            let pid: u64 = msg
                .split("(pid ")
                .nth(1)
                .and_then(|s| s.split(')').next())
                .and_then(|s| s.parse().ok())
                .expect("error message carries the killed pid");
            assert!(
                !std::path::Path::new(&format!("/proc/{pid}")).exists(),
                "pid {pid} still present after failed spawn: {msg}"
            );
        }
    }
}
