//! Scoring backends for the anomaly server.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

use crate::engine::{BatchEngine, ExecMode, PipelineOptions, PipelinePool, PIPELINE_MIN_DEPTH};
use crate::model::LstmAutoencoder;
use crate::runtime::Runtime;
use crate::workload::Window;

/// A reconstruction-error scorer over batches of windows.
pub trait Backend: Send + Sync {
    /// Human-readable backend name (for reports).
    fn name(&self) -> String;
    /// Score each window (mean squared reconstruction error).
    fn score_batch(&self, windows: &[&Window]) -> Vec<f64>;
    /// Pipeline replicas currently backing this scorer, when it executes
    /// on a resizable replica pool ([`crate::engine::PipelinePool`]);
    /// `None` when replica scaling does not apply to this backend. The
    /// autoscaler samples this before resizing.
    fn pipeline_replicas(&self) -> Option<usize> {
        None
    }
    /// Resize the backing replica pool, when one exists — the
    /// autoscaler's replica knob. The default is a no-op so backends
    /// without replica parallelism (PJRT, test doubles) ignore scaling.
    fn set_pipeline_replicas(&self, _replicas: usize) {}
    /// The model behind this scorer, when one is available for stateful
    /// stream sessions ([`crate::engine::session`]). Lanes whose backend
    /// returns `Some` grow a per-lane `SessionTable` and accept
    /// `submit_sample`; `None` (the default — PJRT executes windows only,
    /// test doubles have no recurrence to carry) leaves the lane
    /// window-only.
    fn session_model(&self) -> Option<Arc<LstmAutoencoder>> {
        None
    }
}

/// Scores through the AOT-compiled PJRT artifact — real numerics,
/// Python-free request path (the production configuration).
///
/// The `xla` crate's PJRT handles are `Rc`-based (not `Send`/`Sync`), so
/// the backend owns a dedicated executor thread that holds the
/// [`Runtime`]; `score_batch` ships flattened windows over a channel and
/// waits for scores. Worker threads thus serialize on the PJRT executor
/// (the CPU client is single-stream anyway; XLA parallelizes internally).
pub struct PjrtBackend {
    tx: Mutex<Sender<Job>>,
    label: String,
    t: usize,
    #[allow(dead_code)]
    features: usize,
}

struct Job {
    /// Flattened `[T][F]` windows.
    windows: Vec<Vec<f32>>,
    reply: Sender<Vec<f64>>,
}

impl PjrtBackend {
    /// Spawn the executor thread over the artifact directory. Fails fast
    /// if the manifest/model/T is unavailable.
    pub fn new(dir: std::path::PathBuf, model: &str, t: usize) -> anyhow::Result<PjrtBackend> {
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<(String, usize)>>();
        let model = model.to_string();
        std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || {
                // Construct the runtime *inside* the thread (not Send).
                let setup = (|| -> anyhow::Result<(Runtime, String, usize)> {
                    let rt = Runtime::open(&dir)?;
                    let entry = rt
                        .manifest()
                        .find(&model)
                        .ok_or_else(|| anyhow::anyhow!("model {model:?} not in manifest"))?;
                    let name = entry.name.clone();
                    let features = entry.features;
                    rt.executable(&name, t)?; // pre-compile
                    Ok((rt, name, features))
                })();
                let (rt, name) = match setup {
                    Ok((rt, name, features)) => {
                        let _ = ready_tx.send(Ok((name.clone(), features)));
                        (rt, name)
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut flat_buf: Vec<f32> = Vec::new();
                while let Ok(job) = rx.recv() {
                    // One batched PJRT dispatch for the whole job (vmap
                    // artifacts, greedy chunking inside infer_batch).
                    let b = job.windows.len();
                    flat_buf.clear();
                    for w in &job.windows {
                        flat_buf.extend_from_slice(w);
                    }
                    let per = flat_buf.len() / b.max(1);
                    let scores = match rt.infer_batch(&name, t, b, &flat_buf) {
                        Ok(recon) => (0..b)
                            .map(|i| {
                                mse_flat(
                                    &flat_buf[i * per..(i + 1) * per],
                                    &recon[i * per..(i + 1) * per],
                                )
                            })
                            .collect(),
                        Err(_) => vec![f64::INFINITY; b],
                    };
                    let _ = job.reply.send(scores);
                }
            })
            .expect("spawn pjrt executor");
        let (name, features) = ready_rx.recv().map_err(|_| anyhow::anyhow!("executor died"))??;
        Ok(PjrtBackend {
            tx: Mutex::new(tx),
            label: format!("pjrt:{name}/T{t}"),
            t,
            features,
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        let flat: Vec<Vec<f32>> = windows
            .iter()
            .map(|w| {
                assert_eq!(w.data.len(), self.t, "window length matches artifact T");
                w.data.iter().flat_map(|row| row.iter().copied()).collect()
            })
            .collect();
        let (reply, rx) = channel();
        {
            let tx = self.tx.lock().unwrap();
            if tx.send(Job { windows: flat, reply }).is_err() {
                return vec![f64::INFINITY; windows.len()];
            }
        }
        rx.recv().unwrap_or_else(|_| vec![f64::INFINITY; windows.len()])
    }
}

/// Scores through the bit-accurate Q8.24 + PWL golden model — exactly the
/// arithmetic the FPGA datapath performs (used to validate that
/// quantization does not change detection decisions, and as the
/// artifact-free fallback).
///
/// Execution is routed through the temporal-pipeline engine
/// ([`crate::engine`]): multi-window batches run on the batched MMM
/// kernel (grouped by sequence length), single windows of deep models
/// run on the per-layer worker pipeline, and everything degenerates to
/// the sequential zero-alloc scratch path otherwise. All paths are
/// bit-identical, so the chosen [`ExecMode`] changes throughput, never
/// scores.
pub struct QuantBackend {
    ae: Arc<LstmAutoencoder>,
    mode: ExecMode,
    /// Spawned only when the mode can route to it (threads per layer per
    /// replica); replicas are checked out per batch so concurrent server
    /// workers don't serialize on one pipeline's endpoint lock.
    pool: Option<PipelinePool>,
    batch: BatchEngine,
}

impl QuantBackend {
    /// Backend with [`ExecMode::Auto`] routing and a single pipeline
    /// replica (the single-lane serving default).
    pub fn new(ae: LstmAutoencoder) -> QuantBackend {
        Self::with_options(ae, ExecMode::Auto, 1)
    }

    /// Backend pinned to one execution path, for operators who want
    /// deterministic routing (and for the mode-agreement tests below;
    /// `benches/hotpath.rs` compares the underlying engines directly).
    pub fn with_mode(ae: LstmAutoencoder, mode: ExecMode) -> QuantBackend {
        Self::with_options(ae, mode, 1)
    }

    /// Backend with an explicit pipeline replica count. `replicas` only
    /// matters for modes that can route to the pipeline (`Auto` on deep
    /// models, `Pipelined`); lanes with several workers should size it to
    /// the worker count so pipelined scoring runs worker-parallel.
    pub fn with_options(ae: LstmAutoencoder, mode: ExecMode, replicas: usize) -> QuantBackend {
        Self::with_engine_options(ae, mode, replicas, PipelineOptions::default())
    }

    /// [`Self::with_options`] plus per-replica [`PipelineOptions`] (FIFO
    /// capacity, stage core pinning) threaded into the pool. Only modes
    /// that can route to the pipeline build one; otherwise `engine` is
    /// ignored.
    pub fn with_engine_options(
        ae: LstmAutoencoder,
        mode: ExecMode,
        replicas: usize,
        engine: PipelineOptions,
    ) -> QuantBackend {
        let ae = Arc::new(ae);
        let wants_pipeline = match mode {
            ExecMode::Pipelined => true,
            ExecMode::Auto => ae.topo.depth >= PIPELINE_MIN_DEPTH,
            ExecMode::Sequential | ExecMode::Batched => false,
        };
        let pool = if wants_pipeline {
            Some(PipelinePool::with_options(ae.clone(), replicas, engine))
        } else {
            None
        };
        let batch = BatchEngine::new(ae.clone());
        QuantBackend { ae, mode, pool, batch }
    }

    /// The execution mode this backend routes through.
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// `(replicas, distinct replicas used so far)` of the pipeline pool,
    /// or `None` when this mode never routes to the pipeline. Lets
    /// operators and tests verify pipelined scoring really spreads
    /// across replicas instead of serializing on one.
    pub fn replica_stats(&self) -> Option<(usize, usize)> {
        self.pool.as_ref().map(|p| (p.replicas(), p.used_replicas()))
    }

    /// Batched scoring with windows grouped by sequence length (the MMM
    /// kernel requires uniform `T` within a batch). Singleton groups go
    /// through the pipeline when this mode constructed one (deep models
    /// under [`ExecMode::Auto`]), else the sequential scratch path — so
    /// mixed-length deep-model batches are never slower than submitting
    /// the same windows individually.
    fn score_grouped(&self, windows: &[&Window]) -> Vec<f64> {
        let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, w) in windows.iter().enumerate() {
            groups.entry(w.data.len()).or_default().push(i);
        }
        let mut scores = vec![0.0f64; windows.len()];
        let mut singles: Vec<usize> = Vec::new();
        for idxs in groups.values() {
            if let [i] = idxs[..] {
                singles.push(i);
            } else {
                let group: Vec<&[Vec<f32>]> =
                    idxs.iter().map(|&i| windows[i].data.as_slice()).collect();
                for (&i, s) in idxs.iter().zip(self.batch.score_batch(&group)) {
                    scores[i] = s;
                }
            }
        }
        if !singles.is_empty() {
            match &self.pool {
                // One back-to-back pass over all the odd-length windows
                // on a checked-out replica — layers stay busy across
                // window boundaries instead of filling and draining per
                // window.
                Some(pool) => {
                    let group: Vec<&[Vec<f32>]> =
                        singles.iter().map(|&i| windows[i].data.as_slice()).collect();
                    for (&i, s) in singles.iter().zip(pool.score_batch(&group)) {
                        scores[i] = s;
                    }
                }
                None => {
                    for &i in &singles {
                        scores[i] = self.ae.score_quant(&windows[i].data);
                    }
                }
            }
        }
        scores
    }
}

impl Backend for QuantBackend {
    fn name(&self) -> String {
        format!("quant:{}", self.ae.topo.name)
    }

    fn pipeline_replicas(&self) -> Option<usize> {
        self.pool.as_ref().map(|p| p.replicas())
    }

    fn session_model(&self) -> Option<Arc<LstmAutoencoder>> {
        Some(self.ae.clone())
    }

    fn set_pipeline_replicas(&self, replicas: usize) {
        if let Some(pool) = &self.pool {
            pool.set_replicas(replicas);
        }
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        match self.mode {
            ExecMode::Sequential => {
                windows.iter().map(|w| self.ae.score_quant(&w.data)).collect()
            }
            ExecMode::Pipelined => {
                let wins: Vec<&[Vec<f32>]> =
                    windows.iter().map(|w| w.data.as_slice()).collect();
                self.pool
                    .as_ref()
                    .expect("pipelined backend always constructs its pool")
                    .score_batch(&wins)
            }
            ExecMode::Batched => self.score_grouped(windows),
            ExecMode::Auto => match (windows, &self.pool) {
                ([w], Some(pool)) => vec![pool.score(&w.data)],
                ([w], None) => vec![self.ae.score_quant(&w.data)],
                _ => self.score_grouped(windows),
            },
        }
    }
}

/// Deterministically throttled scorer for capacity experiments: a fixed
/// service-time floor per batch makes lane capacity a pure function of
/// worker count on any host (≈ `workers / floor` batches per second).
/// With a model attached ([`Self::scoring`]), windows are scored through
/// the bit-exact sequential Q8.24 scorer after the floor elapses — so
/// autoscaling experiments can assert bit-identity while saturating
/// lanes; without one ([`Self::zeros`]) every score is `0.0`. Shared by
/// the autoscaler tests, `tests/integration_autoscale.rs`, and the
/// rotating-hot scenario in `benches/hotpath.rs`.
pub struct ThrottledBackend {
    floor: std::time::Duration,
    scorer: Option<LstmAutoencoder>,
}

impl ThrottledBackend {
    /// Floor-only backend: every score is `0.0`.
    pub fn zeros(floor: std::time::Duration) -> ThrottledBackend {
        ThrottledBackend { floor, scorer: None }
    }

    /// Floor plus bit-exact sequential scoring through `ae`.
    pub fn scoring(ae: LstmAutoencoder, floor: std::time::Duration) -> ThrottledBackend {
        ThrottledBackend { floor, scorer: Some(ae) }
    }
}

impl Backend for ThrottledBackend {
    fn name(&self) -> String {
        match &self.scorer {
            Some(ae) => format!("throttled:{}", ae.topo.name),
            None => "throttled".into(),
        }
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        std::thread::sleep(self.floor);
        match &self.scorer {
            Some(ae) => windows.iter().map(|w| ae.score_quant(&w.data)).collect(),
            None => vec![0.0; windows.len()],
        }
    }
}

fn mse_flat(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().max(1);
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>() / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;
    use crate::workload::TelemetryGen;

    #[test]
    fn quant_backend_scores_are_reconstruction_mse() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo.clone(), 1);
        let ae2 = LstmAutoencoder::random(topo, 1);
        let b = QuantBackend::new(ae);
        let mut gen = TelemetryGen::new(32, 3);
        let w = gen.benign_window(8);
        let got = b.score_batch(&[&w])[0];
        assert!((got - ae2.score_quant(&w.data)).abs() < 1e-12);
    }

    #[test]
    fn mse_flat_basic() {
        assert_eq!(mse_flat(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse_flat(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pjrt_backend_fails_cleanly_without_artifacts() {
        let err = PjrtBackend::new(std::path::PathBuf::from("/nonexistent"), "F32-D2", 4);
        assert!(err.is_err());
    }

    #[test]
    fn all_exec_modes_agree_bitwise() {
        // Mixed-length batch through every mode of both a shallow and a
        // deep model: scores must be identical to the last bit — the
        // engine may only change speed, never results.
        for name in ["F32-D2", "F32-D6"] {
            let topo = Topology::from_name(name).unwrap();
            let mut gen = TelemetryGen::new(topo.features, 13);
            let windows: Vec<Window> = [8usize, 4, 8, 8, 4, 1]
                .iter()
                .map(|&t| gen.benign_window(t))
                .collect();
            let refs: Vec<&Window> = windows.iter().collect();
            let mk = |mode| {
                QuantBackend::with_mode(
                    LstmAutoencoder::random(Topology::from_name(name).unwrap(), 77),
                    mode,
                )
            };
            let golden = mk(crate::engine::ExecMode::Sequential).score_batch(&refs);
            for mode in [
                crate::engine::ExecMode::Auto,
                crate::engine::ExecMode::Pipelined,
                crate::engine::ExecMode::Batched,
            ] {
                let got = mk(mode).score_batch(&refs);
                let same = golden
                    .iter()
                    .zip(&got)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "{name} {mode:?}: {golden:?} vs {got:?}");
            }
        }
    }

    #[test]
    fn multi_replica_backend_is_bit_identical_and_spreads_load() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let ae = LstmAutoencoder::random(topo.clone(), 8);
        let reference = LstmAutoencoder::random(topo, 8);
        let backend = QuantBackend::with_options(ae, ExecMode::Auto, 3);
        assert_eq!(backend.replica_stats(), Some((3, 0)));
        let mut gen = TelemetryGen::new(64, 4);
        for i in 0..6 {
            let w = gen.benign_window(3 + i % 3);
            let got = backend.score_batch(&[&w])[0];
            assert_eq!(got.to_bits(), reference.score_quant(&w.data).to_bits());
        }
        let (replicas, used) = backend.replica_stats().unwrap();
        assert_eq!(replicas, 3);
        assert_eq!(used, 3, "rotating checkout must visit every replica");
        // Shallow models never construct a pool, whatever the count.
        let shallow = QuantBackend::with_options(
            LstmAutoencoder::random(Topology::from_name("F32-D2").unwrap(), 1),
            ExecMode::Auto,
            4,
        );
        assert_eq!(shallow.replica_stats(), None);
    }

    #[test]
    fn auto_mode_single_window_agrees_on_deep_model() {
        // Deep model + single window exercises the pipeline branch of
        // Auto. One model instance, one window: score sequentially first,
        // then hand the same model to the backend.
        let topo = Topology::from_name("F64-D6").unwrap();
        let ae = LstmAutoencoder::random(topo, 5);
        let w = TelemetryGen::new(64, 3).benign_window(6);
        let want = ae.score_quant(&w.data);
        let backend = QuantBackend::new(ae);
        let got = backend.score_batch(&[&w])[0];
        assert_eq!(got.to_bits(), want.to_bits());
    }
}
