//! Scoring backends for the anomaly server.

use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use crate::model::LstmAutoencoder;
use crate::runtime::Runtime;
use crate::workload::Window;

/// A reconstruction-error scorer over batches of windows.
pub trait Backend: Send + Sync {
    /// Human-readable backend name (for reports).
    fn name(&self) -> String;
    /// Score each window (mean squared reconstruction error).
    fn score_batch(&self, windows: &[&Window]) -> Vec<f64>;
}

/// Scores through the AOT-compiled PJRT artifact — real numerics,
/// Python-free request path (the production configuration).
///
/// The `xla` crate's PJRT handles are `Rc`-based (not `Send`/`Sync`), so
/// the backend owns a dedicated executor thread that holds the
/// [`Runtime`]; `score_batch` ships flattened windows over a channel and
/// waits for scores. Worker threads thus serialize on the PJRT executor
/// (the CPU client is single-stream anyway; XLA parallelizes internally).
pub struct PjrtBackend {
    tx: Mutex<Sender<Job>>,
    label: String,
    t: usize,
    #[allow(dead_code)]
    features: usize,
}

struct Job {
    /// Flattened `[T][F]` windows.
    windows: Vec<Vec<f32>>,
    reply: Sender<Vec<f64>>,
}

impl PjrtBackend {
    /// Spawn the executor thread over the artifact directory. Fails fast
    /// if the manifest/model/T is unavailable.
    pub fn new(dir: std::path::PathBuf, model: &str, t: usize) -> anyhow::Result<PjrtBackend> {
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<(String, usize)>>();
        let model = model.to_string();
        std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || {
                // Construct the runtime *inside* the thread (not Send).
                let setup = (|| -> anyhow::Result<(Runtime, String, usize)> {
                    let rt = Runtime::open(&dir)?;
                    let entry = rt
                        .manifest()
                        .find(&model)
                        .ok_or_else(|| anyhow::anyhow!("model {model:?} not in manifest"))?;
                    let name = entry.name.clone();
                    let features = entry.features;
                    rt.executable(&name, t)?; // pre-compile
                    Ok((rt, name, features))
                })();
                let (rt, name) = match setup {
                    Ok((rt, name, features)) => {
                        let _ = ready_tx.send(Ok((name.clone(), features)));
                        (rt, name)
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut flat_buf: Vec<f32> = Vec::new();
                while let Ok(job) = rx.recv() {
                    // One batched PJRT dispatch for the whole job (vmap
                    // artifacts, greedy chunking inside infer_batch).
                    let b = job.windows.len();
                    flat_buf.clear();
                    for w in &job.windows {
                        flat_buf.extend_from_slice(w);
                    }
                    let per = flat_buf.len() / b.max(1);
                    let scores = match rt.infer_batch(&name, t, b, &flat_buf) {
                        Ok(recon) => (0..b)
                            .map(|i| {
                                mse_flat(
                                    &flat_buf[i * per..(i + 1) * per],
                                    &recon[i * per..(i + 1) * per],
                                )
                            })
                            .collect(),
                        Err(_) => vec![f64::INFINITY; b],
                    };
                    let _ = job.reply.send(scores);
                }
            })
            .expect("spawn pjrt executor");
        let (name, features) = ready_rx.recv().map_err(|_| anyhow::anyhow!("executor died"))??;
        Ok(PjrtBackend {
            tx: Mutex::new(tx),
            label: format!("pjrt:{name}/T{t}"),
            t,
            features,
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        let flat: Vec<Vec<f32>> = windows
            .iter()
            .map(|w| {
                assert_eq!(w.data.len(), self.t, "window length matches artifact T");
                w.data.iter().flat_map(|row| row.iter().copied()).collect()
            })
            .collect();
        let (reply, rx) = channel();
        {
            let tx = self.tx.lock().unwrap();
            if tx.send(Job { windows: flat, reply }).is_err() {
                return vec![f64::INFINITY; windows.len()];
            }
        }
        rx.recv().unwrap_or_else(|_| vec![f64::INFINITY; windows.len()])
    }
}

/// Scores through the bit-accurate Q8.24 + PWL golden model — exactly the
/// arithmetic the FPGA datapath performs (used to validate that
/// quantization does not change detection decisions, and as the
/// artifact-free fallback).
pub struct QuantBackend {
    ae: LstmAutoencoder,
}

impl QuantBackend {
    pub fn new(ae: LstmAutoencoder) -> QuantBackend {
        QuantBackend { ae }
    }
}

impl Backend for QuantBackend {
    fn name(&self) -> String {
        format!("quant:{}", self.ae.topo.name)
    }

    fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
        windows.iter().map(|w| self.ae.score_quant(&w.data)).collect()
    }
}

fn mse_flat(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().max(1);
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>() / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;
    use crate::workload::TelemetryGen;

    #[test]
    fn quant_backend_scores_are_reconstruction_mse() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo.clone(), 1);
        let ae2 = LstmAutoencoder::random(topo, 1);
        let b = QuantBackend::new(ae);
        let mut gen = TelemetryGen::new(32, 3);
        let w = gen.benign_window(8);
        let got = b.score_batch(&[&w])[0];
        assert!((got - ae2.score_quant(&w.data)).abs() < 1e-12);
    }

    #[test]
    fn mse_flat_basic() {
        assert_eq!(mse_flat(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse_flat(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pjrt_backend_fails_cleanly_without_artifacts() {
        let err = PjrtBackend::new(std::path::PathBuf::from("/nonexistent"), "F32-D2", 4);
        assert!(err.is_err());
    }
}
