//! The async submission front: nonblocking [`Ticket`]s over the lanes.
//!
//! The blocking surface ([`crate::server::Lane::try_submit`]) hands every
//! caller a private `Receiver<Response>`; holding a thousand requests in
//! flight therefore pins a thousand parked OS threads on `recv()` — the
//! process-edge analogue of the idle silicon the paper's temporal
//! pipeline exists to eliminate. This module replaces the parked thread
//! per request with **one completion router thread per lane**:
//!
//! ```text
//! client ── Lane::submit_async(window) ──► Ticket   (returns immediately)
//!                 │ registers slot (id → shared state)
//!                 ▼
//!   admission ► batcher ► workers ──(shared completion channel)──►
//!                                         [completion router thread]
//!                                           id → slot lookup; fills the
//!                                           slot, wakes waiters, runs the
//!                                           registered callback, feeds
//!                                           any attached CompletionSet
//! ```
//!
//! All of a lane's async replies multiplex over a single channel (the
//! worker hot path is unchanged — it still just sends a `Response`), the
//! router owns the only parked thread, and a [`Ticket`] is plain shared
//! slot state: [`Ticket::poll`] is a lock-and-look, [`Ticket::wait`] /
//! [`Ticket::wait_timeout`] park on a condvar, [`Ticket::on_complete`]
//! registers a callback the router invokes on delivery. A
//! [`CompletionSet`] fans in tickets from any number of lanes for
//! select-style "first of N" consumption — the primitive the closed-loop
//! drivers (`fleet --async`, `workload::trace::closed_loop_async`) use to
//! keep thousands of requests outstanding from a handful of threads.
//!
//! Semantics are deliberately identical to the blocking path everywhere
//! else: admission, batching, backpressure, and shedding are the same
//! code ([`SubmitError::Overloaded`] fails the submit before a ticket is
//! issued), and scores stay bit-identical to `ExecMode::Sequential`
//! (`tests/integration_front.rs` pins both down).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{Response, SubmitError};

/// Ids whose requests were cancelled while still queued, shared between a
/// lane's tickets (writers via [`Ticket::cancel`]) and its batcher +
/// workers (consumers: a queued request whose id is marked here is
/// removed from the lane instead of being scored, and counted in
/// [`super::ServerMetrics::cancelled`]).
pub(crate) type CancelSet = Arc<Mutex<HashSet<u64>>>;

/// What a completed ticket resolves to: the scored [`Response`], or
/// [`SubmitError::Closed`] when the lane shut down before the request
/// could be answered (only possible when a worker died mid-batch — a
/// graceful shutdown drains accepted work).
pub type Completion = Result<Response, SubmitError>;

type Callback = Box<dyn FnOnce(Completion) + Send + 'static>;

/// Internal completion observer (used by the score cache's single-flight
/// fan-out): runs on the completing thread *before* the user-facing
/// callback and set hook, borrowing the outcome rather than consuming it.
type Observer = Box<dyn FnOnce(&Completion) + Send + 'static>;

/// Hook installed by [`CompletionSet::add`]: on completion the router
/// pushes `(key, outcome)` into the set's ready queue.
struct SetHook {
    key: u64,
    set: Arc<SetShared>,
}

#[derive(Default)]
struct TicketState {
    outcome: Option<Completion>,
    observer: Option<Observer>,
    callback: Option<Callback>,
    hook: Option<SetHook>,
}

/// The slot shared between a [`Ticket`] and its completer (a lane's
/// completion router, or a [`crate::net::ShardClient`] reader thread for
/// tickets that resolve over the wire): outcome + condvar for waiters,
/// plus the optional callback and completion-set hook consumed at
/// delivery.
pub(crate) struct TicketShared {
    state: Mutex<TicketState>,
    cond: Condvar,
}

impl TicketShared {
    fn new() -> TicketShared {
        TicketShared { state: Mutex::new(TicketState::default()), cond: Condvar::new() }
    }

    /// Resolve the slot. Called exactly once per ticket — by the router
    /// on delivery, or by the router's exit drain with `Err(Closed)`.
    pub(crate) fn complete(&self, outcome: Completion) {
        let (observer, callback, hook) = {
            let mut st = self.state.lock().unwrap();
            debug_assert!(st.outcome.is_none(), "a ticket completes exactly once");
            st.outcome = Some(outcome.clone());
            (st.observer.take(), st.callback.take(), st.hook.take())
        };
        self.cond.notify_all();
        // Observer first: single-flight followers must see the outcome no
        // later than any user callback that might resubmit the window.
        if let Some(obs) = observer {
            obs(&outcome);
        }
        if let Some(cb) = callback {
            cb(outcome.clone());
        }
        if let Some(h) = hook {
            h.set.push(h.key, outcome);
        }
    }

    /// Register an internal observer; if the outcome already arrived, `f`
    /// runs immediately on the calling thread (so attach-after-delivery
    /// races still fire exactly once).
    pub(crate) fn observe<F>(&self, f: F)
    where
        F: FnOnce(&Completion) + Send + 'static,
    {
        let outcome = {
            let mut st = self.state.lock().unwrap();
            match st.outcome.clone() {
                Some(o) => o,
                None => {
                    debug_assert!(st.observer.is_none(), "one observer per ticket");
                    st.observer = Some(Box::new(f));
                    return;
                }
            }
        };
        f(&outcome);
    }
}

/// A pending async submission: shared slot state filled by the lane's
/// completion router, never a parked thread.
///
/// Obtained from [`crate::server::Lane::submit_async`] /
/// [`crate::server::ModelRegistry::submit_async`] — a ticket exists only
/// for *accepted* requests (shed submissions fail before one is issued),
/// so under normal operation every ticket resolves to `Ok(Response)`.
/// Redeem it any way you like:
///
/// - [`Ticket::poll`] — non-blocking check (returns a clone, so polling
///   is repeatable);
/// - [`Ticket::wait`] / [`Ticket::wait_timeout`] — park on the slot's
///   condvar;
/// - [`Ticket::on_complete`] — register a callback the router thread
///   runs at delivery (fire-and-forget: it consumes the ticket and fires
///   even if nothing else is held);
/// - [`CompletionSet::add`] — fan in with tickets from other lanes.
///
/// Dropping an unredeemed ticket is free: the router still removes the
/// slot when the response arrives (or at lane shutdown), so abandoned
/// tickets never leak router slots or block shutdown —
/// `tests/integration_front.rs` pins that down.
pub struct Ticket {
    id: u64,
    /// Shared with the router — no per-submit allocation for the name.
    lane: Arc<str>,
    shared: Arc<TicketShared>,
    /// Wiring for [`Ticket::cancel`] on lane-local tickets; `None` for
    /// tickets resolved by other completers (e.g. the net client), which
    /// cannot reach into a remote lane's queue.
    cancel: Option<CancelHook>,
}

/// What [`Ticket::cancel`] needs to reach back into its lane: the lane's
/// cancel set (so the batcher/workers drop the queued request) and the
/// router's slot map (so the slot is retired before the ticket resolves
/// `Err(Cancelled)` — a Weak, because tickets routinely outlive lanes).
struct CancelHook {
    set: CancelSet,
    slots: Weak<Mutex<HashMap<u64, Arc<TicketShared>>>>,
}

impl Ticket {
    /// A ticket with no lane-side wiring, resolved by whoever holds the
    /// returned slot (the net client's reader thread completes these from
    /// `Response`/`Shed` frames).
    pub(crate) fn raw(id: u64, lane: Arc<str>) -> (Ticket, Arc<TicketShared>) {
        let shared = Arc::new(TicketShared::new());
        (Ticket { id, lane, shared: shared.clone(), cancel: None }, shared)
    }

    /// The lane-local request id this ticket redeems (matches
    /// [`Response::id`]).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Name of the lane the request was submitted to.
    pub fn lane(&self) -> &str {
        &self.lane
    }

    /// Non-blocking completion check: `None` while in flight, a clone of
    /// the outcome once delivered (repeatable — polling never consumes).
    pub fn poll(&self) -> Option<Completion> {
        self.shared.state.lock().unwrap().outcome.clone()
    }

    /// Whether the router has delivered this ticket's outcome.
    pub fn is_complete(&self) -> bool {
        self.shared.state.lock().unwrap().outcome.is_some()
    }

    /// Block until the outcome is delivered.
    ///
    /// An accepted request is normally always answered (shutdown drains
    /// accepted work), but a worker that panics mid-batch takes its
    /// requests with it — those tickets resolve to `Err(Closed)` at lane
    /// shutdown. Prefer [`Ticket::wait_timeout`] when the backend isn't
    /// trusted.
    pub fn wait(&self) -> Completion {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(o) = st.outcome.clone() {
                return o;
            }
            st = self.shared.cond.wait(st).unwrap();
        }
    }

    /// [`Ticket::wait`] with a deadline: `None` on timeout, with the
    /// ticket still live and redeemable by any other means.
    pub fn wait_timeout(&self, dur: Duration) -> Option<Completion> {
        let deadline = Instant::now() + dur;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(o) = st.outcome.clone() {
                return Some(o);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _) = self.shared.cond.wait_timeout(st, deadline - now).unwrap();
            st = g;
        }
    }

    /// Register a completion callback and detach. The router thread runs
    /// `f` at delivery (so keep it cheap — it shares the thread with
    /// every other completion on the lane); if the outcome already
    /// arrived, `f` runs immediately on the calling thread. Consuming
    /// `self` makes this fire-and-forget: the callback fires even though
    /// the ticket itself is gone.
    pub fn on_complete<F>(self, f: F)
    where
        F: FnOnce(Completion) + Send + 'static,
    {
        let mut st = self.shared.state.lock().unwrap();
        match st.outcome.clone() {
            Some(outcome) => {
                drop(st);
                f(outcome);
            }
            None => st.callback = Some(Box::new(f)),
        }
    }

    /// Register the ticket's internal completion observer (see
    /// [`TicketShared::observe`]); the lane attaches the score cache's
    /// single-flight fan-out here after a leader submission.
    pub(crate) fn observe<F>(&self, f: F)
    where
        F: FnOnce(&Completion) + Send + 'static,
    {
        self.shared.observe(f);
    }

    /// Cancel a still-queued request: actively **removes** it from the
    /// lane (the batcher and workers drop a marked request instead of
    /// scoring it, counting it in
    /// [`super::ServerMetrics::cancelled`] so admission accounting still
    /// conserves — after a drain, `submitted == completed + cancelled`)
    /// and resolves this ticket immediately with
    /// `Err(`[`SubmitError::Cancelled`]`)`, waking every waiter.
    ///
    /// Returns `true` when the ticket was resolved by this call. Returns
    /// `false` — and changes nothing — when the outcome already arrived
    /// (or arrives concurrently: delivery wins the race), and for tickets
    /// without lane-side wiring (remote tickets from a
    /// [`crate::net::ShardClient`]). Best-effort beyond the queue: a
    /// request a worker already picked up is scored anyway; its response
    /// is discarded (the ticket has resolved `Cancelled`) and it counts
    /// as `completed`, not `cancelled`, keeping the conservation law
    /// intact either way.
    pub fn cancel(&self) -> bool {
        let Some(hook) = &self.cancel else { return false };
        {
            // Mark under the slot lock: a concurrent delivery is either
            // already done (outcome set — we bail) or will run after we
            // release, and then the slot-map removal below arbitrates.
            let st = self.shared.state.lock().unwrap();
            if st.outcome.is_some() {
                return false;
            }
            hook.set.lock().unwrap().insert(self.id);
        }
        let won = match hook.slots.upgrade() {
            Some(slots) => slots.lock().unwrap().remove(&self.id).is_some(),
            // Router gone ⇒ its exit drain owns every remaining slot (it
            // may already be completing this one): delivery wins.
            None => false,
        };
        if !won {
            // Delivery got the slot first: roll the mark back and let the
            // real outcome stand.
            hook.set.lock().unwrap().remove(&self.id);
            return false;
        }
        self.shared.complete(Err(SubmitError::Cancelled));
        true
    }
}

/// Per-lane completion router: the single thread that multiplexes every
/// async reply on the lane. Workers send each [`Response`] over one
/// shared channel; the router looks the id up in the slot map, removes
/// the entry, and resolves the ticket's shared state. Owned by the lane;
/// [`CompletionRouter::shutdown`] runs after the worker pool has drained,
/// so the router sees every in-flight reply before its channel
/// disconnects, then poisons whatever is left (requests lost to a worker
/// panic) with `Err(Closed)`.
pub(crate) struct CompletionRouter {
    /// Lane name, shared into every ticket (`Arc<str>`: the submit hot
    /// path allocates no string per request).
    name: Arc<str>,
    /// Retained producer endpoint, cloned into each async submission's
    /// `Request.reply`. Dropped (`None`) at shutdown so the router's
    /// `recv` disconnects once every in-flight clone is gone. The lock
    /// is written exactly once (shutdown) and otherwise uncontended next
    /// to the lane's admission `sync_channel`, which already serializes
    /// submitters.
    tx: Mutex<Option<Sender<Response>>>,
    slots: Arc<Mutex<HashMap<u64, Arc<TicketShared>>>>,
    /// The lane's cancel set, shared into every issued ticket's hook and
    /// consulted by the routing thread to clean up marks whose request
    /// was scored before the batcher/workers could drop it.
    cancels: CancelSet,
    handle: Mutex<Option<JoinHandle<()>>>,
}

impl CompletionRouter {
    pub(crate) fn start(lane: &str, cancels: CancelSet) -> CompletionRouter {
        let (tx, rx) = channel::<Response>();
        let slots: Arc<Mutex<HashMap<u64, Arc<TicketShared>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let thread_slots = slots.clone();
        let thread_cancels = cancels.clone();
        let handle = std::thread::Builder::new()
            .name(format!("cpl:{lane}"))
            .spawn(move || route(rx, thread_slots, thread_cancels))
            .expect("spawn completion router");
        CompletionRouter {
            name: Arc::from(lane),
            tx: Mutex::new(Some(tx)),
            slots,
            cancels,
            handle: Mutex::new(Some(handle)),
        }
    }

    /// Issue a ticket for request `id`: registers the slot (before the
    /// request can possibly complete) and returns the ticket plus the
    /// reply sender to submit with. Fails `Closed` after shutdown.
    pub(crate) fn issue(&self, id: u64) -> Result<(Ticket, Sender<Response>), SubmitError> {
        let guard = self.tx.lock().unwrap();
        let Some(tx) = guard.as_ref() else {
            return Err(SubmitError::Closed);
        };
        let shared = Arc::new(TicketShared::new());
        self.slots.lock().unwrap().insert(id, shared.clone());
        let cancel = Some(CancelHook {
            set: self.cancels.clone(),
            slots: Arc::downgrade(&self.slots),
        });
        Ok((Ticket { id, lane: self.name.clone(), shared, cancel }, tx.clone()))
    }

    /// Remove a slot whose submission was rejected (shed or closed) —
    /// the ticket was never handed out.
    pub(crate) fn revoke(&self, id: u64) {
        self.slots.lock().unwrap().remove(&id);
    }

    /// Async submissions currently awaiting delivery (registered slots).
    pub(crate) fn inflight(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    /// The lane name shared into issued tickets — also used for raw
    /// tickets the lane completes itself (cache hits).
    pub(crate) fn lane_name(&self) -> Arc<str> {
        self.name.clone()
    }

    /// Drop the retained sender and join the router thread. Call only
    /// after the lane's workers have drained: the channel then holds
    /// every outstanding reply, the router routes them all, poisons any
    /// slot that never got one, and exits. Idempotent.
    pub(crate) fn shutdown(&self) {
        *self.tx.lock().unwrap() = None;
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn route(
    rx: Receiver<Response>,
    slots: Arc<Mutex<HashMap<u64, Arc<TicketShared>>>>,
    cancels: CancelSet,
) {
    while let Ok(resp) = rx.recv() {
        // Remove-then-complete outside the map lock: callbacks run on
        // this thread and must not hold the slot map hostage.
        let slot = slots.lock().unwrap().remove(&resp.id);
        if let Some(slot) = slot {
            slot.complete(Ok(resp));
        } else {
            // A missing slot means the submission was revoked — or
            // cancelled after a worker had already picked it up, in which
            // case nothing downstream will ever consume the cancel mark:
            // retire it here so the set stays bounded.
            cancels.lock().unwrap().remove(&resp.id);
        }
    }
    // Every producer endpoint is gone (lane shutdown, workers joined):
    // any slot still registered belongs to a request that died with a
    // panicking worker. Poison them so waiters wake instead of hanging.
    let orphaned: Vec<Arc<TicketShared>> =
        slots.lock().unwrap().drain().map(|(_, s)| s).collect();
    for slot in orphaned {
        slot.complete(Err(SubmitError::Closed));
    }
}

struct SetShared {
    ready: Mutex<VecDeque<(u64, Completion)>>,
    cond: Condvar,
}

impl SetShared {
    fn push(&self, key: u64, outcome: Completion) {
        self.ready.lock().unwrap().push_back((key, outcome));
        self.cond.notify_all();
    }
}

/// Select-style fan-in over tickets from any number of lanes: add each
/// [`Ticket`] under a caller-chosen key, then reap completions in
/// *delivery* order — "first of N lanes" — without polling and without a
/// thread per ticket. The closed-loop drivers use one set per client
/// thread to keep hundreds of requests outstanding each.
///
/// ```no_run
/// use lstm_ae_accel::engine::ExecMode;
/// use lstm_ae_accel::server::{CompletionSet, ModelRegistry};
/// use lstm_ae_accel::workload::TelemetryGen;
///
/// let registry = ModelRegistry::paper_fleet(7, ExecMode::Auto, 2);
/// let mut set = CompletionSet::new();
/// for (key, model) in registry.models().enumerate() {
///     let features = lstm_ae_accel::model::Topology::from_name(model).unwrap().features;
///     let window = TelemetryGen::new(features, 3).benign_window(8);
///     set.add(key as u64, registry.submit_async(model, window).unwrap());
/// }
/// // First of the four lanes to score wins; reap all four.
/// while let Some((key, outcome)) = set.wait() {
///     println!("lane {key}: score {:.6}", outcome.unwrap().score);
/// }
/// registry.shutdown();
/// ```
pub struct CompletionSet {
    shared: Arc<SetShared>,
    /// Tickets added minus completions reaped; [`CompletionSet::wait`]
    /// returns `None` exactly when this hits zero.
    outstanding: usize,
}

impl CompletionSet {
    pub fn new() -> CompletionSet {
        CompletionSet {
            shared: Arc::new(SetShared {
                ready: Mutex::new(VecDeque::new()),
                cond: Condvar::new(),
            }),
            outstanding: 0,
        }
    }

    /// Attach a ticket under `key` (not required to be unique — e.g. a
    /// lane index shared by many tickets). Already-completed tickets are
    /// immediately reapable.
    pub fn add(&mut self, key: u64, ticket: Ticket) {
        self.outstanding += 1;
        let mut st = ticket.shared.state.lock().unwrap();
        match st.outcome.clone() {
            Some(outcome) => {
                drop(st);
                self.shared.push(key, outcome);
            }
            None => st.hook = Some(SetHook { key, set: self.shared.clone() }),
        }
    }

    /// Tickets added but not yet reaped (completed-but-unreaped included).
    pub fn pending(&self) -> usize {
        self.outstanding
    }

    /// Non-blocking reap of the next delivered completion, if any.
    pub fn try_next(&mut self) -> Option<(u64, Completion)> {
        let item = self.shared.ready.lock().unwrap().pop_front();
        if item.is_some() {
            self.outstanding -= 1;
        }
        item
    }

    /// Reap the next completion in delivery order, blocking while the set
    /// has outstanding tickets; `None` once every added ticket has been
    /// reaped (so `while let Some(..) = set.wait()` drains the set).
    pub fn wait(&mut self) -> Option<(u64, Completion)> {
        if self.outstanding == 0 {
            return None;
        }
        let mut q = self.shared.ready.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                self.outstanding -= 1;
                return Some(item);
            }
            q = self.shared.cond.wait(q).unwrap();
        }
    }

    /// [`CompletionSet::wait`] with a deadline: `None` on timeout *or*
    /// when the set is empty — check [`CompletionSet::pending`] to tell
    /// the two apart.
    pub fn wait_timeout(&mut self, dur: Duration) -> Option<(u64, Completion)> {
        if self.outstanding == 0 {
            return None;
        }
        let deadline = Instant::now() + dur;
        let mut q = self.shared.ready.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                self.outstanding -= 1;
                return Some(item);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _) = self.shared.cond.wait_timeout(q, deadline - now).unwrap();
            q = g;
        }
    }
}

impl Default for CompletionSet {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, score: f64) -> Response {
        Response {
            id,
            score,
            is_anomaly: false,
            queue_us: 1.0,
            service_us: 1.0,
            e2e_us: 2.0,
        }
    }

    fn ticket(id: u64) -> (Ticket, Arc<TicketShared>) {
        Ticket::raw(id, Arc::from("t"))
    }

    #[test]
    fn poll_wait_and_timeout_observe_one_completion() {
        let (t, slot) = ticket(3);
        assert!(t.poll().is_none());
        assert!(!t.is_complete());
        assert!(t.wait_timeout(Duration::from_millis(5)).is_none(), "times out in flight");
        slot.complete(Ok(resp(3, 0.25)));
        // Polling is repeatable; wait returns instantly once complete.
        for _ in 0..2 {
            assert_eq!(t.poll().unwrap().unwrap().score, 0.25);
        }
        assert!(t.is_complete());
        assert_eq!(t.wait().unwrap().score, 0.25);
        assert_eq!(t.wait_timeout(Duration::from_millis(1)).unwrap().unwrap().id, 3);
    }

    #[test]
    fn wait_parks_until_the_router_delivers() {
        let (t, slot) = ticket(9);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            slot.complete(Ok(resp(9, 1.5)));
        });
        assert_eq!(t.wait().unwrap().score, 1.5);
        h.join().unwrap();
    }

    #[test]
    fn callback_fires_on_delivery_and_immediately_when_late() {
        use std::sync::mpsc::channel;
        // Registered before completion: fires at delivery.
        let (t, slot) = ticket(1);
        let (tx, rx) = channel();
        t.on_complete(move |o| tx.send(o.unwrap().score).unwrap());
        slot.complete(Ok(resp(1, 0.5)));
        assert_eq!(rx.recv().unwrap(), 0.5);
        // Registered after completion: fires right away, on the caller.
        let (t, slot) = ticket(2);
        slot.complete(Err(SubmitError::Closed));
        let (tx, rx) = channel();
        t.on_complete(move |o| tx.send(o.is_err()).unwrap());
        assert!(rx.try_recv().unwrap(), "late registration must fire synchronously");
    }

    #[test]
    fn observer_runs_before_callback_and_immediately_when_late() {
        // Registered before completion: observer fires at delivery, and
        // strictly before the user callback.
        let log: Arc<Mutex<Vec<(&str, bool)>>> = Arc::default();
        let (t, slot) = ticket(1);
        let l = log.clone();
        t.observe(move |o| l.lock().unwrap().push(("observer", o.is_ok())));
        let l = log.clone();
        t.on_complete(move |o| l.lock().unwrap().push(("callback", o.is_ok())));
        slot.complete(Ok(resp(1, 0.5)));
        assert_eq!(*log.lock().unwrap(), vec![("observer", true), ("callback", true)]);
        // Registered after completion: fires synchronously on the caller.
        let (t, slot) = ticket(2);
        slot.complete(Err(SubmitError::Closed));
        let log: Arc<Mutex<Vec<bool>>> = Arc::default();
        let l = log.clone();
        t.observe(move |o| l.lock().unwrap().push(o.is_err()));
        assert_eq!(*log.lock().unwrap(), vec![true], "late observe must fire synchronously");
    }

    #[test]
    fn completion_set_reaps_in_delivery_order_then_drains_to_none() {
        let (ta, sa) = ticket(10);
        let (tb, sb) = ticket(11);
        let (tc, sc) = ticket(12);
        sc.complete(Ok(resp(12, 3.0))); // completed before being added
        let mut set = CompletionSet::new();
        set.add(0, ta);
        set.add(1, tb);
        set.add(2, tc);
        assert_eq!(set.pending(), 3);
        // The pre-completed ticket is reapable without blocking.
        let (k, o) = set.try_next().expect("c already delivered");
        assert_eq!((k, o.unwrap().score), (2, 3.0));
        assert!(set.try_next().is_none());
        // b then a complete: delivery order, not insertion order.
        sb.complete(Ok(resp(11, 2.0)));
        assert_eq!(set.wait().unwrap().0, 1);
        assert!(set.wait_timeout(Duration::from_millis(5)).is_none(), "a still in flight");
        sa.complete(Ok(resp(10, 1.0)));
        assert_eq!(set.wait().unwrap().0, 0);
        assert_eq!(set.pending(), 0);
        assert!(set.wait().is_none(), "drained set must not block");
    }

    #[test]
    fn raw_tickets_and_already_complete_tickets_refuse_cancel() {
        // Raw tickets (the net client's) have no lane to reach into.
        let (t, _slot) = ticket(1);
        assert!(!t.cancel());
        assert!(t.poll().is_none(), "refused cancel must not resolve the ticket");
        // Delivery always beats cancellation.
        let (t, slot) = ticket(2);
        slot.complete(Ok(resp(2, 0.5)));
        assert!(!t.cancel());
        assert_eq!(t.wait().unwrap().score, 0.5);
    }

    #[test]
    fn cancelling_a_routed_ticket_resolves_it_and_frees_the_slot() {
        let cancels: CancelSet = Arc::default();
        let router = CompletionRouter::start("test", cancels.clone());
        let (t, tx) = router.issue(5).unwrap();
        assert!(t.cancel(), "in-flight ticket must cancel");
        assert_eq!(t.wait().unwrap_err(), SubmitError::Cancelled);
        assert_eq!(router.inflight(), 0, "cancel retires the router slot");
        assert!(cancels.lock().unwrap().contains(&5), "queue mark left for the batcher");
        assert!(!t.cancel(), "second cancel is a no-op");
        // A late response (the request was scored before the lane saw the
        // mark) is dropped and retires the stale mark.
        tx.send(resp(5, 1.0)).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        while cancels.lock().unwrap().contains(&5) {
            assert!(Instant::now() < deadline, "router must retire the stale cancel mark");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(t.wait().unwrap_err(), SubmitError::Cancelled, "outcome must not change");
        drop(tx);
        router.shutdown();
    }

    #[test]
    fn router_routes_by_id_poisons_orphans_and_forgets_revoked() {
        let router = CompletionRouter::start("test", Arc::default());
        let (accepted, tx) = router.issue(0).unwrap();
        let (orphan, tx2) = router.issue(1).unwrap();
        let (revoked, tx3) = router.issue(2).unwrap();
        router.revoke(2);
        assert_eq!(router.inflight(), 2);
        tx.send(resp(0, 0.75)).unwrap();
        assert_eq!(accepted.wait().unwrap().score, 0.75);
        assert_eq!(router.inflight(), 1, "delivered slot is removed");
        // Every sender clone must be gone before shutdown, or the router
        // never sees its channel disconnect (in the lane, worker drain
        // guarantees this). Then shutdown poisons the orphan; the
        // revoked ticket stays unresolved forever — nothing holds it.
        drop(tx);
        drop(tx2);
        drop(tx3);
        router.shutdown();
        assert_eq!(orphan.wait().unwrap_err(), SubmitError::Closed);
        assert!(revoked.poll().is_none());
        assert_eq!(router.inflight(), 0);
        // issue() after shutdown fails Closed.
        assert!(matches!(router.issue(3), Err(SubmitError::Closed)));
        router.shutdown(); // idempotent
    }
}
