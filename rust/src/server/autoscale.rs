//! Metrics-driven per-lane autoscaling for the serving fabric.
//!
//! The paper's architectural argument is that a dataflow design keeping
//! every stage busy wins as depth grows; the serving-fabric analogue is
//! keeping every *thread* busy as traffic shifts. Static per-lane worker
//! and replica counts (PR 2) waste exactly that parallelism when the hot
//! model rotates: one lane sheds while its neighbours idle. This module
//! closes the loop — SHARP-style workload-adaptive resource allocation,
//! in software:
//!
//! ```text
//!            every `tick`
//!  ┌──────────────────────────────────────────────────────────┐
//!  │ for each watched Lane:                                   │
//!  │   sample   queue depth, shed Δ, batch occupancy Δ,       │
//!  │            worker idle/busy Δ        (ServerMetrics)     │
//!  │   decide   pressure → Up, sustained quiet → Down,        │
//!  │            else Hold             (hysteresis streaks)    │
//!  │   apply    Up:   Lane::add_worker (fleet budget          │
//!  │                  permitting) + one more pipeline replica │
//!  │            Down: Lane::retire_worker (graceful poison    │
//!  │                  message) + one fewer pipeline replica   │
//!  └──────────────────────────────────────────────────────────┘
//! ```
//!
//! Decisions are deliberately conservative: one worker and one replica
//! per lane per tick, scale-up only after [`AutoscalePolicy::up_ticks`]
//! consecutive pressure samples, scale-down only after
//! [`AutoscalePolicy::down_ticks`] consecutive quiet samples. Scaling
//! changes *capacity*, never *results*: every worker and every pipeline
//! replica runs the same bit-exact Q8.24 arithmetic, so responses stay
//! bit-identical to [`crate::engine::ExecMode::Sequential`] regardless
//! of how many threads served them (asserted by
//! `tests/integration_autoscale.rs`).

use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use super::fabric::Lane;
use super::ServerMetrics;

/// Per-lane autoscaling bounds and hysteresis knobs (carried by
/// [`super::ServerConfig::autoscale`]).
#[derive(Clone, Debug, PartialEq)]
pub struct AutoscalePolicy {
    /// Never retire below this many lane workers.
    pub min_workers: usize,
    /// Never grow beyond this many lane workers.
    pub max_workers: usize,
    /// Never shrink the backend's pipeline-replica pool below this.
    pub min_replicas: usize,
    /// Never grow the backend's pipeline-replica pool beyond this.
    pub max_replicas: usize,
    /// Queue pressure threshold: a tick counts toward scale-up when
    /// `queue_depth / queue_capacity` reaches this fraction (or any
    /// request was shed since the last tick).
    pub up_queue_frac: f64,
    /// Consecutive pressure ticks required before one scale-up step.
    pub up_ticks: u32,
    /// Idle threshold: a tick counts toward scale-down only when the
    /// queue is empty, nothing was shed, and the workers' idle fraction
    /// over the tick is at least this.
    pub down_idle_frac: f64,
    /// Consecutive quiet ticks required before one scale-down step.
    pub down_ticks: u32,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            min_workers: 1,
            max_workers: 8,
            min_replicas: 1,
            max_replicas: 4,
            up_queue_frac: 0.5,
            up_ticks: 2,
            down_idle_frac: 0.9,
            down_ticks: 20,
        }
    }
}

impl AutoscalePolicy {
    /// A policy bounded to `min..=max` workers (replica bounds follow the
    /// same range, clamped to the default replica ceiling).
    pub fn bounded(min: usize, max: usize) -> AutoscalePolicy {
        let d = AutoscalePolicy::default();
        AutoscalePolicy {
            min_workers: min.max(1),
            max_workers: max.max(min.max(1)),
            min_replicas: d.min_replicas,
            max_replicas: d.max_replicas.min(max.max(1)).max(d.min_replicas),
            ..d
        }
    }
}

/// One tick's sampled view of a lane (deltas are since the previous
/// tick).
#[derive(Clone, Copy, Debug)]
pub struct LaneSample {
    /// Requests waiting in the bounded admission queue right now.
    pub queue_depth: usize,
    /// The queue's capacity (denominator of the pressure fraction).
    pub queue_capacity: usize,
    /// Requests shed at admission since the last tick.
    pub shed_delta: u64,
    /// Requests completed since the last tick.
    pub completed_delta: u64,
    /// Mean batch occupancy (windows per dispatched batch) over the tick;
    /// 0 when no batch was dispatched.
    pub occupancy: f64,
    /// Fraction of worker time spent idle over the tick, in `[0, 1]`;
    /// 1.0 when workers recorded no activity at all.
    pub idle_frac: f64,
}

/// What one tick concluded for one lane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Sustained pressure: add capacity (one worker, one replica).
    Up,
    /// Sustained quiet: remove capacity (one worker, one replica).
    Down,
    /// Neither streak is complete; leave the lane as it is.
    Hold,
}

/// Per-lane controller memory: previous counter values and the
/// hysteresis streaks.
#[derive(Debug, Default)]
struct LaneTrack {
    last_shed: u64,
    last_completed: u64,
    last_batches: u64,
    last_batched_windows: u64,
    last_idle_ns: u64,
    last_busy_ns: u64,
    up_streak: u32,
    down_streak: u32,
}

impl LaneTrack {
    /// Read the lane's metrics, fold them into deltas against the last
    /// tick, and remember the new absolutes.
    fn sample(&mut self, metrics: &ServerMetrics, queue_capacity: usize) -> LaneSample {
        let shed = metrics.shed();
        let completed = metrics.completed();
        let batches = metrics.batches();
        let batched_windows = metrics.batched_windows();
        let idle_ns = metrics.worker_idle_ns();
        let busy_ns = metrics.worker_busy_ns();

        let batch_delta = batches - self.last_batches;
        let window_delta = batched_windows - self.last_batched_windows;
        let idle_delta = idle_ns - self.last_idle_ns;
        let busy_delta = busy_ns - self.last_busy_ns;
        let sample = LaneSample {
            queue_depth: metrics.queue_depth(),
            queue_capacity,
            shed_delta: shed - self.last_shed,
            completed_delta: completed - self.last_completed,
            occupancy: if batch_delta == 0 {
                0.0
            } else {
                window_delta as f64 / batch_delta as f64
            },
            idle_frac: if idle_delta + busy_delta == 0 {
                1.0
            } else {
                idle_delta as f64 / (idle_delta + busy_delta) as f64
            },
        };
        self.last_shed = shed;
        self.last_completed = completed;
        self.last_batches = batches;
        self.last_batched_windows = batched_windows;
        self.last_idle_ns = idle_ns;
        self.last_busy_ns = busy_ns;
        sample
    }
}

/// The pure decision function: fold one sample into the hysteresis
/// streaks and report whether capacity should move. Streaks reset after
/// an emitted decision (one step per completed streak) and whenever the
/// lane is neither pressured nor quiet.
fn decide(policy: &AutoscalePolicy, sample: &LaneSample, track: &mut LaneTrack) -> ScaleDecision {
    let pressure = sample.shed_delta > 0
        || sample.queue_depth as f64 >= policy.up_queue_frac * sample.queue_capacity as f64;
    let quiet = sample.shed_delta == 0
        && sample.queue_depth == 0
        && sample.idle_frac >= policy.down_idle_frac;
    if pressure {
        track.down_streak = 0;
        track.up_streak += 1;
        if track.up_streak >= policy.up_ticks {
            track.up_streak = 0;
            return ScaleDecision::Up;
        }
    } else if quiet {
        track.up_streak = 0;
        track.down_streak += 1;
        if track.down_streak >= policy.down_ticks {
            track.down_streak = 0;
            return ScaleDecision::Down;
        }
    } else {
        track.up_streak = 0;
        track.down_streak = 0;
    }
    ScaleDecision::Hold
}

/// Apply a decision to a lane within the policy bounds. `budget_room`
/// is how many more workers the fleet-wide budget allows (`usize::MAX`
/// when unlimited). Returns whether anything changed.
fn apply(
    lane: &Lane,
    policy: &AutoscalePolicy,
    decision: ScaleDecision,
    budget_room: usize,
) -> bool {
    match decision {
        ScaleDecision::Hold => false,
        ScaleDecision::Up => {
            let mut acted = false;
            if lane.workers() < policy.max_workers && budget_room > 0 {
                lane.add_worker();
                // Replicas ride along with a *budgeted* worker add (each
                // replica spawns depth threads of its own, so growing the
                // pool while the budget blocks worker adds would bypass
                // the fleet's fixed thread total).
                if let Some(r) = lane.pipeline_replicas() {
                    if r < policy.max_replicas {
                        lane.set_pipeline_replicas(r + 1);
                    }
                }
                acted = true;
            }
            if acted {
                lane.record_scale(true);
            }
            acted
        }
        ScaleDecision::Down => {
            let mut acted = false;
            if lane.workers() > policy.min_workers && lane.retire_worker() {
                acted = true;
            }
            if let Some(r) = lane.pipeline_replicas() {
                if r > policy.min_replicas {
                    lane.set_pipeline_replicas(r - 1);
                    acted = true;
                }
            }
            if acted {
                lane.record_scale(false);
            }
            acted
        }
    }
}

/// The fleet controller: one background thread sampling every watched
/// lane on a fixed tick and resizing worker pools / replica pools within
/// each lane's [`AutoscalePolicy`], optionally under a fleet-wide worker
/// budget. Start via [`crate::server::ModelRegistry::start_autoscaler`]
/// (or [`Autoscaler::start`] directly for hand-built lanes); stopping is
/// idempotent and also happens on drop.
pub struct Autoscaler {
    stop_tx: Sender<()>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

impl Autoscaler {
    /// Spawn the controller over `lanes` (each must carry a policy —
    /// lanes without one are skipped), ticking every `tick`.
    /// `worker_budget` caps the *sum* of watched lanes' worker counts:
    /// scale-ups that would exceed it are skipped, so a shifting
    /// workload redistributes a fixed thread budget instead of growing
    /// it.
    pub fn start(
        lanes: Vec<Arc<Lane>>,
        tick: Duration,
        worker_budget: Option<usize>,
    ) -> Autoscaler {
        let (stop_tx, stop_rx) = channel::<()>();
        let handle = std::thread::Builder::new()
            .name("autoscaler".into())
            .spawn(move || {
                let mut watched: Vec<(Arc<Lane>, AutoscalePolicy, LaneTrack)> = lanes
                    .into_iter()
                    .filter_map(|l| {
                        let policy = l.autoscale_policy()?.clone();
                        let mut track = LaneTrack::default();
                        // Prime against the lane's current counters so the
                        // first tick sees only activity since start — not
                        // the lane's lifetime shed/idle history (which
                        // would fire a spurious scale decision on start or
                        // restart).
                        let _ = track.sample(l.metrics(), l.queue_capacity());
                        Some((l, policy, track))
                    })
                    .collect();
                loop {
                    match stop_rx.recv_timeout(tick) {
                        Err(RecvTimeoutError::Timeout) => {}
                        Ok(()) | Err(RecvTimeoutError::Disconnected) => return,
                    }
                    // Fleet-wide worker total, kept current across this
                    // tick's per-lane actions so the budget holds even
                    // when several lanes want to grow at once.
                    let mut total: usize = watched.iter().map(|(l, _, _)| l.workers()).sum();
                    for (lane, policy, track) in watched.iter_mut() {
                        let lane: &Lane = lane.as_ref();
                        let sample = track.sample(lane.metrics(), lane.queue_capacity());
                        let decision = decide(policy, &sample, track);
                        let room = worker_budget.map_or(usize::MAX, |b| b.saturating_sub(total));
                        let before = lane.workers();
                        apply(lane, policy, decision, room);
                        let after = lane.workers();
                        total = total.saturating_sub(before) + after;
                    }
                }
            })
            .expect("spawn autoscaler");
        Autoscaler { stop_tx, handle: Mutex::new(Some(handle)) }
    }

    /// Stop the controller and join its thread (idempotent).
    pub fn stop(&self) {
        let _ = self.stop_tx.send(());
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::super::{ServerConfig, ThrottledBackend};
    use super::*;
    use crate::workload::Window;
    use std::time::Instant;

    fn sample(depth: usize, cap: usize, shed: u64, idle: f64) -> LaneSample {
        LaneSample {
            queue_depth: depth,
            queue_capacity: cap,
            shed_delta: shed,
            completed_delta: 0,
            occupancy: 0.0,
            idle_frac: idle,
        }
    }

    #[test]
    fn scale_up_requires_sustained_pressure() {
        let policy = AutoscalePolicy { up_ticks: 3, ..Default::default() };
        let mut track = LaneTrack::default();
        // Two pressured ticks, one calm, two pressured: no Up yet — the
        // calm tick resets the streak.
        assert_eq!(decide(&policy, &sample(600, 1024, 0, 0.2), &mut track), ScaleDecision::Hold);
        assert_eq!(decide(&policy, &sample(600, 1024, 0, 0.2), &mut track), ScaleDecision::Hold);
        assert_eq!(decide(&policy, &sample(0, 1024, 0, 0.5), &mut track), ScaleDecision::Hold);
        assert_eq!(decide(&policy, &sample(600, 1024, 0, 0.2), &mut track), ScaleDecision::Hold);
        assert_eq!(decide(&policy, &sample(600, 1024, 0, 0.2), &mut track), ScaleDecision::Hold);
        // Third consecutive pressured tick fires, then the streak resets.
        assert_eq!(decide(&policy, &sample(600, 1024, 0, 0.2), &mut track), ScaleDecision::Up);
        assert_eq!(decide(&policy, &sample(600, 1024, 0, 0.2), &mut track), ScaleDecision::Hold);
    }

    #[test]
    fn shed_counts_as_pressure_regardless_of_depth() {
        let policy = AutoscalePolicy { up_ticks: 1, ..Default::default() };
        let mut track = LaneTrack::default();
        assert_eq!(decide(&policy, &sample(0, 1024, 5, 0.9), &mut track), ScaleDecision::Up);
    }

    #[test]
    fn scale_down_requires_sustained_quiet() {
        let policy = AutoscalePolicy { down_ticks: 3, down_idle_frac: 0.8, ..Default::default() };
        let mut track = LaneTrack::default();
        assert_eq!(decide(&policy, &sample(0, 1024, 0, 0.95), &mut track), ScaleDecision::Hold);
        assert_eq!(decide(&policy, &sample(0, 1024, 0, 0.95), &mut track), ScaleDecision::Hold);
        assert_eq!(decide(&policy, &sample(0, 1024, 0, 0.95), &mut track), ScaleDecision::Down);
        // A busy tick (low idle fraction) breaks the quiet streak.
        assert_eq!(decide(&policy, &sample(0, 1024, 0, 0.95), &mut track), ScaleDecision::Hold);
        assert_eq!(decide(&policy, &sample(0, 1024, 0, 0.3), &mut track), ScaleDecision::Hold);
        assert_eq!(decide(&policy, &sample(0, 1024, 0, 0.95), &mut track), ScaleDecision::Hold);
    }

    #[test]
    fn deltas_are_per_tick_not_cumulative() {
        let metrics = ServerMetrics::new();
        let mut track = LaneTrack::default();
        metrics.on_shed();
        metrics.on_shed();
        let s1 = track.sample(&metrics, 64);
        assert_eq!(s1.shed_delta, 2);
        // No new sheds: the next tick must see zero, not the running total.
        let s2 = track.sample(&metrics, 64);
        assert_eq!(s2.shed_delta, 0);
        metrics.on_shed();
        assert_eq!(track.sample(&metrics, 64).shed_delta, 1);
    }

    fn tiny_window() -> Window {
        Window { data: vec![vec![0.0f32]], anomaly: None }
    }

    #[test]
    fn controller_scales_a_pressured_lane_up_and_an_idle_lane_down() {
        let policy = AutoscalePolicy {
            min_workers: 1,
            max_workers: 3,
            up_queue_frac: 0.25,
            up_ticks: 1,
            down_idle_frac: 0.5,
            down_ticks: 2,
            ..Default::default()
        };
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(1),
            workers: 2,
            queue_capacity: 64,
            threshold: 1.0,
            autoscale: Some(policy),
            ..Default::default()
        };
        let lane = Arc::new(Lane::start(
            "hot",
            Arc::new(ThrottledBackend::zeros(Duration::from_millis(2))),
            cfg,
        ));
        let scaler = Autoscaler::start(vec![lane.clone()], Duration::from_millis(5), None);

        // Saturate: 2 ms per singleton batch per worker, offered far
        // above capacity, so the queue stays deep until workers grow.
        let mut inflight = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(10);
        while lane.workers() < 3 && Instant::now() < deadline {
            for _ in 0..8 {
                if let Ok(rx) = lane.try_submit(tiny_window()) {
                    inflight.push(rx);
                }
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(lane.workers(), 3, "sustained pressure must reach max_workers");
        let (ups, _) = lane.scale_counts();
        assert!(ups >= 1);
        for rx in inflight {
            let _ = rx.recv();
        }

        // Then go quiet: sustained idle must walk workers back to min.
        let deadline = Instant::now() + Duration::from_secs(10);
        while lane.workers() > 1 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(lane.workers(), 1, "sustained idle must reach min_workers");
        let (_, downs) = lane.scale_counts();
        assert!(downs >= 1);
        scaler.stop();
        lane.shutdown();
    }

    #[test]
    fn budget_caps_fleet_wide_scale_up() {
        let policy = AutoscalePolicy {
            min_workers: 1,
            max_workers: 4,
            up_queue_frac: 0.1,
            up_ticks: 1,
            down_ticks: 1000, // effectively never scale down in this test
            ..Default::default()
        };
        let mk_lane = |name: &str| {
            Arc::new(Lane::start(
                name,
                Arc::new(ThrottledBackend::zeros(Duration::from_millis(2))),
                ServerConfig {
                    max_batch: 1,
                    max_wait: Duration::from_micros(1),
                    workers: 1,
                    queue_capacity: 64,
                    threshold: 1.0,
                    autoscale: Some(policy.clone()),
                    ..Default::default()
                },
            ))
        };
        let a = mk_lane("a");
        let b = mk_lane("b");
        // Budget 3 across two lanes starting at 1+1: at most one
        // additional worker may ever be added fleet-wide.
        let scaler =
            Autoscaler::start(vec![a.clone(), b.clone()], Duration::from_millis(5), Some(3));
        let mut inflight = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while Instant::now() < deadline && a.workers() + b.workers() < 3 {
            for lane in [&a, &b] {
                for _ in 0..4 {
                    if let Ok(rx) = lane.try_submit(tiny_window()) {
                        inflight.push(rx);
                    }
                }
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // Give the controller a few more ticks to (incorrectly) overshoot.
        std::thread::sleep(Duration::from_millis(40));
        let total = a.workers() + b.workers();
        assert!(total <= 3, "budget 3 exceeded: {total}");
        scaler.stop();
        drop(inflight);
        a.shutdown();
        b.shutdown();
    }
}
