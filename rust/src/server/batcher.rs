//! Dynamic batching policy: dispatch a batch when it reaches
//! `max_batch` windows or when the oldest queued request has waited
//! `max_wait` — the classic size-or-deadline policy serving systems use
//! to trade throughput against tail latency.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::Instant;

use super::{Batch, Msg, ServerConfig};

pub(crate) fn run_batcher(rx: Receiver<Msg>, out: Sender<Batch>, cfg: ServerConfig) {
    let mut pending: Batch = Vec::with_capacity(cfg.max_batch);
    let mut oldest: Option<Instant> = None;
    loop {
        // How long may we keep waiting before flushing?
        let timeout = match oldest {
            Some(t0) => cfg.max_wait.saturating_sub(t0.elapsed()),
            None => cfg.max_wait.max(std::time::Duration::from_millis(50)),
        };
        match rx.recv_timeout(timeout) {
            Ok(Msg::Req(req)) => {
                if pending.is_empty() {
                    oldest = Some(Instant::now());
                }
                pending.push(req);
                if pending.len() >= cfg.max_batch {
                    flush(&mut pending, &mut oldest, &out);
                }
            }
            Ok(Msg::Shutdown) => {
                flush(&mut pending, &mut oldest, &out);
                return; // dropping `out` stops the workers
            }
            Err(RecvTimeoutError::Timeout) => {
                if oldest.map(|t0| t0.elapsed() >= cfg.max_wait).unwrap_or(false) {
                    flush(&mut pending, &mut oldest, &out);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                flush(&mut pending, &mut oldest, &out);
                return;
            }
        }
    }
}

fn flush(pending: &mut Batch, oldest: &mut Option<Instant>, out: &Sender<Batch>) {
    if !pending.is_empty() {
        let batch = std::mem::take(pending);
        let _ = out.send(batch);
    }
    *oldest = None;
}
