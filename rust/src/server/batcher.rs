//! Dynamic batching policy: dispatch a batch when it reaches
//! `max_batch` windows or when the oldest queued request has waited
//! `max_wait` — the classic size-or-deadline policy serving systems use
//! to trade throughput against tail latency.
//!
//! The wait logic is split by state so the idle wait is independent of
//! flush deadlines: with no batch open there is nothing to flush, so the
//! batcher blocks on `recv()` until traffic or shutdown wakes it (no
//! timeout floor, no spurious wakeups); with a batch open it waits only
//! for the remainder of that batch's deadline. Downstream dispatch is a
//! bounded `sync_channel`, so when every worker is busy the flush blocks,
//! the admission queue fills, and the lane sheds — backpressure instead
//! of unbounded buffering.
//!
//! Stream-session steps (`Request::stream` set) and stateless windows
//! never share a batch: the two dispatch to different worker code paths
//! (`step_batch_into` over carried state vs. window scoring), so a kind
//! boundary in the arrival order flushes the open batch and starts a new
//! one. Same-kind runs still coalesce — a burst of steps from many
//! sessions becomes one batched `step_batch_into` call.

use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use super::front::CancelSet;
use super::{Batch, Msg, Request, ServerConfig, ServerMetrics, WorkerMsg};

/// Dequeue-side cancellation filter: a request whose id carries a cancel
/// mark ([`crate::server::Ticket::cancel`]) is removed from the lane here
/// — counted, never batched, never scored. Returns the request only when
/// it is still live.
fn admit(req: Request, cancels: &CancelSet, metrics: &ServerMetrics) -> Option<Request> {
    metrics.on_dequeue();
    if cancels.lock().unwrap().remove(&req.id) {
        metrics.on_cancelled();
        return None;
    }
    Some(req)
}

/// True when `req` cannot join the open batch: session steps and
/// stateless windows dispatch to different worker paths and never mix.
fn kind_differs(pending: &Batch, req: &Request) -> bool {
    pending.first().is_some_and(|head| head.stream.is_some() != req.stream.is_some())
}

pub(crate) fn run_batcher(
    rx: Receiver<Msg>,
    out: SyncSender<WorkerMsg>,
    cfg: ServerConfig,
    metrics: Arc<ServerMetrics>,
    cancels: CancelSet,
) {
    let mut pending: Batch = Vec::with_capacity(cfg.max_batch);
    // Meaningful only while `pending` is non-empty: *submit* time of the
    // open batch's first request. Anchoring the flush deadline at submit
    // (not dequeue) means time spent waiting in the admission queue
    // counts against `max_wait` — a request that already waited there
    // flushes immediately instead of paying queue-wait + max_wait.
    let mut oldest = Instant::now();
    loop {
        if pending.is_empty() {
            // Idle: no deadline armed — block until traffic or shutdown.
            match rx.recv() {
                Ok(Msg::Req(req)) => {
                    if let Some(req) = admit(req, &cancels, &metrics) {
                        oldest = req.submitted;
                        pending.push(req);
                        if pending.len() >= cfg.max_batch {
                            flush(&mut pending, &out);
                        }
                    }
                }
                Ok(Msg::Shutdown) | Err(_) => return,
            }
        } else {
            // A batch is open: wait only for the rest of its deadline.
            let remaining = cfg.max_wait.saturating_sub(oldest.elapsed());
            if remaining.is_zero() {
                // Deadline already spent — usually a request whose
                // max_wait budget went to *queue* wait under backlog.
                // Greedily absorb whatever else is already queued (up to
                // max_batch) before flushing: under sustained overload
                // every dequeued request is overdue, and flushing each
                // one alone would collapse batching to singletons exactly
                // when the throughput of big batches matters most.
                let mut switched = false;
                while pending.len() < cfg.max_batch {
                    match rx.try_recv() {
                        Ok(Msg::Req(req)) => {
                            if let Some(req) = admit(req, &cancels, &metrics) {
                                if kind_differs(&pending, &req) {
                                    // Kind boundary: dispatch the overdue
                                    // batch and open a fresh one with this
                                    // request — its own deadline applies.
                                    flush(&mut pending, &out);
                                    oldest = req.submitted;
                                    pending.push(req);
                                    switched = true;
                                    break;
                                }
                                pending.push(req);
                            }
                        }
                        Ok(Msg::Shutdown) => {
                            flush(&mut pending, &out);
                            return;
                        }
                        Err(_) => break,
                    }
                }
                if !switched {
                    flush(&mut pending, &out);
                }
                continue;
            }
            match rx.recv_timeout(remaining) {
                Ok(Msg::Req(req)) => {
                    if let Some(req) = admit(req, &cancels, &metrics) {
                        if kind_differs(&pending, &req) {
                            flush(&mut pending, &out);
                            oldest = req.submitted;
                        }
                        pending.push(req);
                        if pending.len() >= cfg.max_batch {
                            flush(&mut pending, &out);
                        }
                    }
                }
                Ok(Msg::Shutdown) => {
                    flush(&mut pending, &out);
                    return; // dropping `out` stops the workers
                }
                Err(RecvTimeoutError::Timeout) => flush(&mut pending, &out),
                Err(RecvTimeoutError::Disconnected) => {
                    flush(&mut pending, &out);
                    return;
                }
            }
        }
    }
}

fn flush(pending: &mut Batch, out: &SyncSender<WorkerMsg>) {
    if !pending.is_empty() {
        // Blocking send: a full batch queue is the backpressure signal.
        let _ = out.send(WorkerMsg::Batch(std::mem::take(pending)));
    }
}

#[cfg(test)]
mod tests {
    use super::super::{BatcherMsg, Request, Response, ServerMetrics, WorkerMsg};
    use super::*;
    use crate::workload::Window;
    use std::sync::mpsc::{channel, sync_channel, Sender};
    use std::sync::Arc;
    use std::time::Duration;

    fn req(id: u64) -> (Request, std::sync::mpsc::Receiver<Response>) {
        let (reply, rx): (Sender<Response>, _) = channel();
        let window = Window { data: vec![vec![0.0f32]], anomaly: None };
        (Request { id, window, submitted: Instant::now(), key: None, stream: None, reply }, rx)
    }

    fn spawn_batcher(
        cfg: ServerConfig,
    ) -> (Sender<BatcherMsg>, std::sync::mpsc::Receiver<WorkerMsg>, std::thread::JoinHandle<()>)
    {
        let (tx, rx) = channel::<BatcherMsg>();
        let (out_tx, out_rx) = sync_channel::<WorkerMsg>(16);
        let metrics = Arc::new(ServerMetrics::new());
        let h = std::thread::spawn(move || run_batcher(rx, out_tx, cfg, metrics, Arc::default()));
        (tx, out_rx, h)
    }

    /// Unwrap the batch a worker would score (tests never see `Retire`
    /// from the batcher — only the autoscaler injects those).
    fn batch_of(msg: WorkerMsg) -> Batch {
        match msg {
            WorkerMsg::Batch(b) => b,
            WorkerMsg::Retire => panic!("batcher never emits Retire"),
        }
    }

    #[test]
    fn first_request_after_idle_honors_its_own_deadline() {
        // Regression guard for the idle-timeout floor: the flush deadline
        // of the first request after an idle stretch is max_wait alone —
        // no 50 ms idle floor may leak into it.
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        };
        let (tx, out_rx, h) = spawn_batcher(cfg);
        std::thread::sleep(Duration::from_millis(30)); // idle stretch
        let (r, _reply) = req(0);
        let sent = Instant::now();
        tx.send(BatcherMsg::Req(r)).unwrap();
        let batch = batch_of(out_rx.recv().unwrap());
        let waited = sent.elapsed();
        assert_eq!(batch.len(), 1);
        assert!(waited < Duration::from_millis(40), "flush took {waited:?}");
        tx.send(BatcherMsg::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn flush_deadline_anchors_at_submit_time_not_dequeue() {
        // Regression guard: a request that sat in the admission queue
        // past its whole `max_wait` budget must flush immediately at
        // dequeue. The old behaviour re-anchored the deadline at dequeue
        // (`oldest = Instant::now()`), silently granting such requests
        // queue-wait + max_wait worst-case latency.
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(100),
            ..Default::default()
        };
        let (tx, out_rx, h) = spawn_batcher(cfg);
        let (r, _reply) = req(0); // `submitted` stamped now...
        std::thread::sleep(Duration::from_millis(150)); // ...then it "waits in the queue"
        let sent = Instant::now();
        tx.send(BatcherMsg::Req(r)).unwrap();
        let batch = batch_of(out_rx.recv().unwrap());
        let waited = sent.elapsed();
        assert_eq!(batch.len(), 1);
        assert!(
            waited < Duration::from_millis(80),
            "overdue request must flush at dequeue, not wait another max_wait ({waited:?})"
        );
        tx.send(BatcherMsg::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn overdue_backlog_coalesces_instead_of_flushing_singletons() {
        // Under backlog every dequeued request is already past its
        // submit-anchored deadline; the batcher must absorb the queued
        // requests behind it into one batch, not flush one singleton per
        // overdue request (which would kill batching exactly under load).
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            ..Default::default()
        };
        let (tx, rx) = channel::<BatcherMsg>();
        // Rendezvous dispatch: the batcher parks in flush until this test
        // accepts the batch, so the backlog below is queued before the
        // batcher can look at it.
        let (out_tx, out_rx) = sync_channel::<WorkerMsg>(0);
        let metrics = Arc::new(ServerMetrics::new());
        let h = std::thread::spawn(move || run_batcher(rx, out_tx, cfg, metrics, Arc::default()));
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        let (r3, _k3) = req(3);
        std::thread::sleep(Duration::from_millis(40)); // all three overdue
        tx.send(BatcherMsg::Req(r1)).unwrap();
        tx.send(BatcherMsg::Req(r2)).unwrap();
        tx.send(BatcherMsg::Req(r3)).unwrap();
        // All three are queued before the first batch is accepted, so at
        // most the head request can end up alone — the rest must coalesce.
        let mut sizes = vec![batch_of(out_rx.recv().unwrap()).len()];
        while sizes.iter().sum::<usize>() < 3 {
            sizes.push(batch_of(out_rx.recv().unwrap()).len());
        }
        assert_eq!(sizes.iter().sum::<usize>(), 3);
        assert!(sizes.len() <= 2, "overdue backlog must coalesce, got {sizes:?}");
        tx.send(BatcherMsg::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn cancelled_requests_are_dropped_at_dequeue_not_batched() {
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        };
        let (tx, rx) = channel::<BatcherMsg>();
        let (out_tx, out_rx) = sync_channel::<WorkerMsg>(16);
        let metrics = Arc::new(ServerMetrics::new());
        let cancels: CancelSet = Arc::default();
        // Queue three requests and mark the middle one cancelled before
        // the batcher starts, so the filter (not timing) decides.
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        let (r3, _k3) = req(3);
        tx.send(BatcherMsg::Req(r1)).unwrap();
        tx.send(BatcherMsg::Req(r2)).unwrap();
        tx.send(BatcherMsg::Req(r3)).unwrap();
        cancels.lock().unwrap().insert(2);
        let m2 = metrics.clone();
        let c2 = cancels.clone();
        let h = std::thread::spawn(move || run_batcher(rx, out_tx, cfg, m2, c2));
        let mut ids = Vec::new();
        while ids.len() < 2 {
            ids.extend(batch_of(out_rx.recv().unwrap()).iter().map(|r| r.id));
        }
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 3], "the cancelled request must never be dispatched");
        assert_eq!(metrics.cancelled(), 1);
        assert!(cancels.lock().unwrap().is_empty(), "consumed marks are retired");
        tx.send(BatcherMsg::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn step_and_window_requests_never_share_a_batch() {
        // A session step between two windows must split the batch: the
        // worker paths differ (carried-state stepping vs. window scoring)
        // and mixing them would score the step's 1×F sample as a window.
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        };
        let (tx, out_rx, h) = spawn_batcher(cfg);
        let (w1, _k1) = req(1);
        let (mut s2, _k2) = req(2);
        s2.stream = Some(42);
        let (w3, _k3) = req(3);
        tx.send(BatcherMsg::Req(w1)).unwrap();
        tx.send(BatcherMsg::Req(s2)).unwrap();
        tx.send(BatcherMsg::Req(w3)).unwrap();
        let mut total = 0;
        while total < 3 {
            let batch = batch_of(out_rx.recv().unwrap());
            total += batch.len();
            let steps = batch.iter().filter(|r| r.stream.is_some()).count();
            assert!(
                steps == 0 || steps == batch.len(),
                "mixed batch: {steps} steps among {} requests",
                batch.len()
            );
        }
        tx.send(BatcherMsg::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn size_flush_ignores_a_long_deadline() {
        let cfg = ServerConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(30),
            ..Default::default()
        };
        let (tx, out_rx, h) = spawn_batcher(cfg);
        let mut replies = Vec::new();
        let sent = Instant::now();
        for id in 0..3 {
            let (r, reply) = req(id);
            replies.push(reply);
            tx.send(BatcherMsg::Req(r)).unwrap();
        }
        let batch = batch_of(out_rx.recv().unwrap());
        assert_eq!(batch.len(), 3);
        assert!(sent.elapsed() < Duration::from_secs(5), "size flush must not wait the deadline");
        tx.send(BatcherMsg::Shutdown).unwrap();
        h.join().unwrap();
    }

    #[test]
    fn shutdown_from_idle_returns_promptly_and_drains_nothing() {
        let cfg = ServerConfig { max_wait: Duration::from_secs(30), ..Default::default() };
        let (tx, out_rx, h) = spawn_batcher(cfg);
        std::thread::sleep(Duration::from_millis(5));
        let sent = Instant::now();
        tx.send(BatcherMsg::Shutdown).unwrap();
        h.join().unwrap();
        assert!(sent.elapsed() < Duration::from_secs(5));
        assert!(out_rx.recv().is_err(), "no batch was open");
    }

    #[test]
    fn shutdown_flushes_the_open_batch() {
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(30),
            ..Default::default()
        };
        let (tx, out_rx, h) = spawn_batcher(cfg);
        let (r, _reply) = req(7);
        tx.send(BatcherMsg::Req(r)).unwrap();
        tx.send(BatcherMsg::Shutdown).unwrap();
        let batch = batch_of(out_rx.recv().unwrap());
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 7);
        h.join().unwrap();
    }
}
