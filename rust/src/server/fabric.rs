//! The multi-model serving fabric: named batching lanes over scoring
//! backends, bounded admission queues with explicit load shedding, and
//! per-model metrics that roll up into a fleet report.
//!
//! ```text
//! clients ──submit("LSTM-AE-F64-D6", window)──► ModelRegistry
//!                                                   │ name lookup
//!        ┌──────────────────────────────────────────┴───────────┐
//!        ▼                                                      ▼
//!  Lane "LSTM-AE-F32-D2"                            Lane "LSTM-AE-F64-D6"
//!  bounded admission queue ── try_send full? ──► SubmitError::Overloaded
//!        │
//!  [batcher thread]  per-lane size-or-deadline policy
//!        │           (a deep lane can hold a longer max_wait than a
//!  bounded batch q    latency-sensitive shallow lane)
//!        │
//!  [worker pool] ──► Backend (QuantBackend checks pipeline replicas
//!                    out of an engine PipelinePool per batch)
//! ```
//!
//! Backpressure is end to end: admission is a bounded `sync_channel`
//! (`try_send` → [`SubmitError::Overloaded`]) and the batcher→worker hop
//! is bounded too, so a slow backend fills the batch queue, then the
//! admission queue, then sheds — no unbounded buffering anywhere on the
//! request path. [`super::AnomalyServer`] is a single-lane compatibility
//! wrapper over exactly this machinery.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::engine::{
    step_session, step_sessions_batch, ExecMode, PipelineOptions, SessionState, PIPELINE_MIN_DEPTH,
};
use crate::model::{LstmAutoencoder, Topology};
use crate::util::affinity;
use crate::util::table::Table;
use crate::workload::Window;

use super::cache::{window_key, CacheConfig, CacheKey, Follower, LaneCache};
use super::front::{CancelSet, CompletionRouter};
use super::{
    batcher, calibrate_threshold, Autoscaler, AutoscalePolicy, Backend, BatcherMsg, QuantBackend,
    Request, Response, ServerConfig, ServerMetrics, SessionConfig, Ticket, WorkerMsg,
};

/// Why a submission was rejected at admission — and, through a
/// [`super::Completion`], why an accepted ticket failed to resolve into a
/// response (`Closed` after worker loss or a dead shard connection,
/// `Cancelled` after [`Ticket::cancel`], `Overloaded` when a remote
/// shard shed the request after local acceptance).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The lane's bounded admission queue is full — the request was shed.
    /// Back off and retry; accepted work is unaffected.
    Overloaded,
    /// The lane (or its reply path) has shut down; no work is accepted.
    Closed,
    /// The caller cancelled the request ([`Ticket::cancel`]) before it
    /// was scored; it was removed from its lane's queue.
    Cancelled,
    /// The request cannot be represented on the wire: the window exceeds
    /// the frame-size limit ([`crate::net::MAX_FRAME_LEN`]), has
    /// zero-width rows, or the model name is longer than a wire string.
    /// Returned by remote submission surfaces before anything touches
    /// the socket — per-request and terminal, never a connection
    /// failure.
    TooLarge,
    /// The registry serves no model by that name.
    UnknownModel(String),
    /// No open stream session by that id on the addressed lane: it was
    /// never opened, was explicitly closed, was LRU-evicted from a full
    /// [`SessionTable`], or the lane's backend serves windows only.
    /// Reopen (fresh state — the documented reset semantic) and resubmit.
    UnknownStream(u64),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded => write!(f, "admission queue full (load shed)"),
            SubmitError::Closed => write!(f, "lane is shut down"),
            SubmitError::Cancelled => write!(f, "request cancelled before scoring"),
            SubmitError::TooLarge => write!(f, "window exceeds the wire frame-size limit"),
            SubmitError::UnknownModel(m) => write!(f, "unknown model {m:?}"),
            SubmitError::UnknownStream(s) => write!(f, "unknown stream session {s}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Scores retained per session for online threshold recalibration.
const SCORE_RING: usize = 128;
/// Recalibrate a session's threshold every this many steps…
const RECAL_EVERY: u64 = 32;
/// …once at least this many scores have accumulated (earlier the
/// quantile is too noisy; the lane threshold applies until then).
const RECAL_MIN: usize = 16;
/// Quantile for per-session recalibration, matching the benign-quantile
/// recipe of [`calibrate_threshold`].
const RECAL_Q: f64 = 0.99;

/// One open stream session: carried engine state plus the lane-side
/// bookkeeping (recent scores, recalibrated threshold, LRU stamp).
struct SessionEntry {
    state: SessionState,
    /// The last ≤ [`SCORE_RING`] step scores, oldest first — the sample
    /// the per-session threshold recalibrates over (drift tracking: a
    /// stream whose baseline shifts re-learns its own normal).
    scores: VecDeque<f64>,
    /// Per-session recalibrated threshold; `None` until enough scores
    /// accumulate, during which the lane threshold applies.
    threshold: Option<f64>,
    /// Logical LRU clock stamp of the last open/step touch.
    last_used: u64,
}

impl SessionEntry {
    fn fresh(ae: &LstmAutoencoder, window: usize, now: u64) -> SessionEntry {
        SessionEntry {
            state: SessionState::new(ae, window),
            scores: VecDeque::new(),
            threshold: None,
            last_used: now,
        }
    }
}

struct TableInner {
    map: HashMap<u64, SessionEntry>,
    /// Monotonic logical clock stamping LRU order (no wall time on the
    /// step path).
    clock: u64,
}

/// Evict the least-recently-used session. O(n) scan — eviction only
/// runs when an open (or an implicit worker-side reopen) overflows
/// `capacity`, never on the per-step hot path.
fn evict_lru(inner: &mut TableInner) {
    if let Some((&id, _)) = inner.map.iter().min_by_key(|(_, e)| e.last_used) {
        inner.map.remove(&id);
    }
}

/// A lane's open stream sessions: bounded, LRU-evicting, explicitly
/// closeable. Built by [`Lane::start`] exactly when the lane's backend
/// exposes a [`Backend::session_model`]; sized by
/// [`ServerConfig::sessions`].
///
/// Lifecycle: [`Lane::open_stream`] inserts (reopening resets state),
/// opening past `capacity` evicts the least-recently-stepped session,
/// [`Lane::close_stream`] removes. Samples for a closed or evicted
/// session fail admission with [`SubmitError::UnknownStream`]; a session
/// that vanishes *after* admission (close/evict racing the queue) is
/// implicitly reopened cold by the worker and counted as a stream reset
/// — an admitted sample always resolves to a score.
pub struct SessionTable {
    ae: Arc<LstmAutoencoder>,
    capacity: usize,
    default_window: usize,
    inner: Mutex<TableInner>,
}

impl SessionTable {
    fn new(ae: Arc<LstmAutoencoder>, cfg: SessionConfig) -> SessionTable {
        SessionTable {
            ae,
            capacity: cfg.capacity.max(1),
            default_window: cfg.window.max(1),
            inner: Mutex::new(TableInner { map: HashMap::new(), clock: 0 }),
        }
    }

    /// Open sessions right now.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Whether no session is open.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether `stream` is currently open (admission check; the worker
    /// re-checks, since close/evict can race the queue).
    pub fn contains(&self, stream: u64) -> bool {
        self.inner.lock().unwrap().map.contains_key(&stream)
    }

    /// Max concurrently-open sessions before LRU eviction.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Feature width every sample must have.
    fn features(&self) -> usize {
        self.ae.topo.features
    }

    /// Insert a fresh session (reopening an existing id resets it), then
    /// LRU-evict down to capacity. `window == 0` takes the lane default.
    fn open(&self, stream: u64, window: usize) {
        let w = if window == 0 { self.default_window } else { window };
        let mut inner = self.inner.lock().unwrap();
        let now = inner.clock;
        inner.clock += 1;
        inner.map.insert(stream, SessionEntry::fresh(&self.ae, w, now));
        while inner.map.len() > self.capacity {
            evict_lru(&mut inner);
        }
    }

    /// Remove a session; `false` when it wasn't open (idempotent).
    fn close(&self, stream: u64) -> bool {
        self.inner.lock().unwrap().map.remove(&stream).is_some()
    }

    /// Advance sessions by one sample each, in dispatch order, and return
    /// `(score, is_anomaly)` per request plus the number of implicit
    /// cold reopens (admission races — each is a stream reset).
    ///
    /// Requests are walked in order and grouped into maximal runs of
    /// pairwise-distinct stream ids, each run advancing through one
    /// [`step_sessions_batch`] call (the MVM → MMM weight reuse across
    /// sessions); a repeated id flushes the run so same-stream samples
    /// apply strictly in dispatch order. Missing sessions are reopened
    /// cold at the lane default window.
    fn step_many(&self, reqs: &[(u64, &[f32])], lane_threshold: f64) -> (Vec<(f64, bool)>, u64) {
        let mut inner = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(reqs.len());
        let mut resets = 0u64;
        let mut start = 0;
        while start < reqs.len() {
            let mut seen = HashSet::new();
            let mut end = start;
            while end < reqs.len() && seen.insert(reqs[end].0) {
                end += 1;
            }
            let mut entries: Vec<(u64, SessionEntry)> = Vec::with_capacity(end - start);
            for &(id, _) in &reqs[start..end] {
                let entry = match inner.map.remove(&id) {
                    Some(e) => e,
                    None => {
                        resets += 1;
                        SessionEntry::fresh(&self.ae, self.default_window, inner.clock)
                    }
                };
                entries.push((id, entry));
            }
            let samples: Vec<&[f32]> = reqs[start..end].iter().map(|&(_, s)| s).collect();
            let scores = if entries.len() == 1 {
                vec![step_session(&self.ae, &mut entries[0].1.state, samples[0])]
            } else {
                let mut states: Vec<&mut SessionState> =
                    entries.iter_mut().map(|(_, e)| &mut e.state).collect();
                step_sessions_batch(&self.ae, &mut states, &samples)
            };
            for ((id, mut entry), score) in entries.into_iter().zip(scores) {
                entry.scores.push_back(score);
                if entry.scores.len() > SCORE_RING {
                    entry.scores.pop_front();
                }
                if entry.state.steps() % RECAL_EVERY == 0 && entry.scores.len() >= RECAL_MIN {
                    entry.threshold =
                        Some(calibrate_threshold(entry.scores.make_contiguous(), RECAL_Q));
                }
                let thr = entry.threshold.unwrap_or(lane_threshold);
                out.push((score, score > thr));
                entry.last_used = inner.clock;
                inner.clock += 1;
                inner.map.insert(id, entry);
            }
            start = end;
        }
        // Implicit reopens may have grown the table past its bound.
        while inner.map.len() > self.capacity {
            evict_lru(&mut inner);
        }
        (out, resets)
    }
}

/// The dynamically resizable worker pool of one lane: worker threads
/// consuming batches from the shared (bounded) batch queue, plus the
/// machinery the autoscaler uses to grow and shrink it at runtime.
///
/// Growth spawns a fresh thread on the same queue. Shrinkage is
/// graceful: a [`WorkerMsg::Retire`] poison message is enqueued behind
/// any already-dispatched batches, and whichever worker consumes it
/// exits after its current batch — accepted work is never dropped.
struct WorkerSet {
    lane: String,
    backend: Arc<dyn Backend>,
    metrics: Arc<ServerMetrics>,
    threshold: f64,
    /// The lane's cancelled-request marks; workers drop marked requests
    /// from a batch before scoring it.
    cancels: CancelSet,
    /// The lane's score cache, shared with the submit paths: workers
    /// populate it after scoring cache-miss requests.
    cache: Option<Arc<LaneCache>>,
    /// The lane's stream-session table, shared with the submit paths:
    /// workers step admitted session samples against it. `None` on
    /// window-only lanes.
    sessions: Option<Arc<SessionTable>>,
    /// Pin worker `wid` to core `(pin_base + wid) % cores` when set —
    /// the batch-engine extension of the pipeline-stage pinning in
    /// [`crate::engine::PipelineOptions::pin_base_core`].
    pin_base: Option<usize>,
    /// Producer side of the batch queue, kept so retirement messages can
    /// be injected behind the batcher's traffic. Dropped (`None`) at
    /// shutdown so workers see a disconnected channel and exit.
    batch_tx: Mutex<Option<SyncSender<WorkerMsg>>>,
    batch_rx: Arc<Mutex<Receiver<WorkerMsg>>>,
    /// Workers currently alive (incremented at spawn, decremented by the
    /// worker itself on any exit path).
    alive: Arc<AtomicUsize>,
    /// Retirement messages sent but not yet consumed; effective worker
    /// count is `alive - pending_retire`.
    pending_retire: Arc<AtomicUsize>,
    next_wid: AtomicUsize,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl WorkerSet {
    /// Spawn one more worker on the shared batch queue.
    fn spawn_worker(&self) {
        let wid = self.next_wid.fetch_add(1, Ordering::Relaxed);
        self.alive.fetch_add(1, Ordering::Relaxed);
        let backend = self.backend.clone();
        let rx = self.batch_rx.clone();
        let metrics = self.metrics.clone();
        let threshold = self.threshold;
        let cancels = self.cancels.clone();
        let cache = self.cache.clone();
        let sessions = self.sessions.clone();
        let alive = self.alive.clone();
        let pending_retire = self.pending_retire.clone();
        let pin = self.pin_base.map(|base| (base + wid) % affinity::available_cores().max(1));
        let handle = std::thread::Builder::new()
            .name(format!("scr{wid}:{}", self.lane))
            .spawn(move || {
                if let Some(core) = pin {
                    // Best-effort, like every other pin in the stack.
                    let _ = affinity::pin_to_core(core);
                }
                worker_loop(
                    backend,
                    rx,
                    metrics,
                    threshold,
                    cancels,
                    cache,
                    sessions,
                    alive,
                    pending_retire,
                )
            })
            .expect("spawn worker");
        let mut handles = self.handles.lock().unwrap();
        // Reap handles of workers that already retired, so a lane that
        // scales up and down for days doesn't accumulate dead handles.
        let mut live = Vec::with_capacity(handles.len() + 1);
        for h in handles.drain(..) {
            if h.is_finished() {
                let _ = h.join();
            } else {
                live.push(h);
            }
        }
        live.push(handle);
        *handles = live;
    }

    /// Ask one worker to retire after its current batch. Refuses to drop
    /// below one effective worker (a lane must keep draining), and skips
    /// (returns `false`) when the batch queue is full — a full queue
    /// means the workers are saturated, which is never a scale-down
    /// moment.
    fn retire_worker(&self) -> bool {
        if self.effective_workers() <= 1 {
            return false;
        }
        let guard = self.batch_tx.lock().unwrap();
        let Some(tx) = guard.as_ref() else { return false };
        match tx.try_send(WorkerMsg::Retire) {
            Ok(()) => {
                self.pending_retire.fetch_add(1, Ordering::Relaxed);
                true
            }
            Err(_) => false,
        }
    }

    /// Workers serving the lane once in-flight retirements land.
    fn effective_workers(&self) -> usize {
        let alive = self.alive.load(Ordering::Relaxed);
        alive.saturating_sub(self.pending_retire.load(Ordering::Relaxed))
    }

    /// Drop the retained producer endpoint and join every worker.
    fn shutdown(&self) {
        *self.batch_tx.lock().unwrap() = None;
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// One model's serving lane: bounded admission queue → batcher thread →
/// worker pool over a scoring backend, with its own metrics, batching
/// policy, and (optionally) autoscaling bounds.
///
/// Worker threads and the backend's pipeline-replica pool are resizable
/// at runtime via [`Lane::add_worker`] / [`Lane::retire_worker`] /
/// [`Lane::set_pipeline_replicas`]; a registry [`Autoscaler`] drives
/// those from the lane's own metrics when the lane's
/// [`ServerConfig::autoscale`] policy is set.
pub struct Lane {
    name: String,
    tx: SyncSender<BatcherMsg>,
    metrics: Arc<ServerMetrics>,
    threshold: f64,
    queue_capacity: usize,
    policy: Option<AutoscalePolicy>,
    next_id: AtomicU64,
    /// Admission gate. An RwLock (not an atomic) so shutdown can close
    /// admission and enqueue `Shutdown` under the write lock: every
    /// submitter that saw the gate open finished its send under the read
    /// lock, i.e. strictly before `Shutdown` in the queue — an accepted
    /// request is therefore always drained, never silently dropped.
    accepting: RwLock<bool>,
    batcher: Mutex<Option<JoinHandle<()>>>,
    workers: WorkerSet,
    /// The async front's completion router: one thread multiplexing every
    /// [`Lane::submit_async`] reply on this lane (see [`super::front`]).
    front: CompletionRouter,
    /// The lane's exact-match score cache + single-flight map, when the
    /// config enables one (see [`super::cache`]). Shared with the worker
    /// set, which populates it after scoring miss requests.
    cache: Option<Arc<LaneCache>>,
    /// The lane's stream-session table, built exactly when the backend
    /// exposes a [`Backend::session_model`]. Shared with the worker set,
    /// which steps admitted samples against it.
    sessions: Option<Arc<SessionTable>>,
    /// Autoscaling decisions applied to this lane (scale-ups, downs).
    scale_ups: AtomicU64,
    scale_downs: AtomicU64,
}

impl Lane {
    /// Spawn the lane's batcher and workers over a scoring backend.
    pub fn start(name: impl Into<String>, backend: Arc<dyn Backend>, cfg: ServerConfig) -> Lane {
        let name = name.into();
        assert!(cfg.workers >= 1 && cfg.max_batch >= 1);
        let metrics = Arc::new(ServerMetrics::new());
        let (tx, rx) = sync_channel::<BatcherMsg>(cfg.queue_capacity.max(1));
        // Bounded dispatch too: when every worker is busy the batcher's
        // flush blocks, admission fills, and try_submit sheds. Sized for
        // the autoscaler's upper bound so scale-up isn't starved by a
        // channel provisioned for the initial worker count.
        let dispatch_workers =
            cfg.autoscale.as_ref().map_or(cfg.workers, |p| p.max_workers.max(cfg.workers));
        let (batch_tx, batch_rx) = sync_channel::<WorkerMsg>(dispatch_workers.max(1) * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));

        // One cancel set per lane, shared by tickets (writers), the
        // batcher, the workers, and the completion router (consumers).
        let cancels: CancelSet = Arc::default();
        // `entries == 0` means off — the CLI's `--cache-entries 0`.
        let cache = cfg
            .cache
            .as_ref()
            .filter(|c| c.entries > 0)
            .map(|c| Arc::new(LaneCache::new(c.clone())));
        // Stream sessions exist exactly where the backend can hand out
        // its model — carried state needs the real recurrence, not just
        // a `score_batch` surface.
        let sessions =
            backend.session_model().map(|ae| Arc::new(SessionTable::new(ae, cfg.sessions)));
        let batcher = {
            let cfg2 = cfg.clone();
            let out = batch_tx.clone();
            let metrics = metrics.clone();
            let cancels = cancels.clone();
            std::thread::Builder::new()
                .name(format!("bat:{name}"))
                .spawn(move || batcher::run_batcher(rx, out, cfg2, metrics, cancels))
                .expect("spawn batcher")
        };
        let workers = WorkerSet {
            lane: name.clone(),
            backend,
            metrics: metrics.clone(),
            threshold: cfg.threshold,
            cancels: cancels.clone(),
            cache: cache.clone(),
            sessions: sessions.clone(),
            pin_base: cfg.pin_base_core,
            batch_tx: Mutex::new(Some(batch_tx)),
            batch_rx,
            alive: Arc::new(AtomicUsize::new(0)),
            pending_retire: Arc::new(AtomicUsize::new(0)),
            next_wid: AtomicUsize::new(0),
            handles: Mutex::new(Vec::new()),
        };
        for _ in 0..cfg.workers {
            workers.spawn_worker();
        }
        let front = CompletionRouter::start(&name, cancels);
        Lane {
            name,
            tx,
            metrics,
            threshold: cfg.threshold,
            queue_capacity: cfg.queue_capacity.max(1),
            policy: cfg.autoscale,
            next_id: AtomicU64::new(0),
            accepting: RwLock::new(true),
            batcher: Mutex::new(Some(batcher)),
            workers,
            front,
            cache,
            sessions,
            scale_ups: AtomicU64::new(0),
            scale_downs: AtomicU64::new(0),
        }
    }

    /// The model name this lane serves.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// This lane's metrics sink (counters, histograms, autoscaler gauges).
    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// The anomaly threshold applied to this lane's scores.
    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// Capacity of the bounded admission queue, in requests.
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    /// The autoscaling policy this lane was configured with, if any.
    pub fn autoscale_policy(&self) -> Option<&AutoscalePolicy> {
        self.policy.as_ref()
    }

    /// Worker threads currently serving this lane (net of retirements
    /// already requested but not yet consumed).
    pub fn workers(&self) -> usize {
        self.workers.effective_workers()
    }

    /// Grow the worker pool by one thread; returns the new effective
    /// count. Safe (but pointless) after shutdown — the fresh worker
    /// sees a disconnected queue and exits immediately.
    pub fn add_worker(&self) -> usize {
        self.workers.spawn_worker();
        self.workers.effective_workers()
    }

    /// Gracefully retire one worker after its current batch. Refused
    /// (returns `false`) when it would leave the lane below one worker,
    /// or while the dispatch queue is full — saturation is never a
    /// scale-down moment. Returns whether a retirement was issued.
    pub fn retire_worker(&self) -> bool {
        self.workers.retire_worker()
    }

    /// Pipeline replicas backing this lane's scorer, when the backend
    /// executes on a replica pool ([`Backend::pipeline_replicas`]).
    pub fn pipeline_replicas(&self) -> Option<usize> {
        self.workers.backend.pipeline_replicas()
    }

    /// Resize the backend's pipeline-replica pool (no-op for backends
    /// without one).
    pub fn set_pipeline_replicas(&self, replicas: usize) {
        self.workers.backend.set_pipeline_replicas(replicas);
    }

    /// `(scale-ups, scale-downs)` applied to this lane by an autoscaler.
    pub fn scale_counts(&self) -> (u64, u64) {
        (self.scale_ups.load(Ordering::Relaxed), self.scale_downs.load(Ordering::Relaxed))
    }

    /// Record an applied autoscaling decision (called by [`Autoscaler`]).
    pub(crate) fn record_scale(&self, up: bool) {
        if up {
            self.scale_ups.fetch_add(1, Ordering::Relaxed);
        } else {
            self.scale_downs.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The shared admission path of both submit surfaces: gate check,
    /// bounded enqueue, and the accounting that makes every call land in
    /// exactly one of `submitted` / `shed` / `rejected_closed`.
    fn submit_inner(
        &self,
        id: u64,
        window: Window,
        key: Option<CacheKey>,
        stream: Option<u64>,
        reply: std::sync::mpsc::Sender<Response>,
    ) -> Result<(), SubmitError> {
        // Held across the send so a concurrent shutdown cannot slot its
        // `Shutdown` message between our gate check and our enqueue.
        // `try_read`, not `read`: while shutdown holds the write lock
        // (draining a backlogged queue), submit must fail fast as Closed,
        // not stall for the drain.
        let Ok(accepting) = self.accepting.try_read() else {
            self.metrics.on_rejected_closed();
            return Err(SubmitError::Closed);
        };
        if !*accepting {
            self.metrics.on_rejected_closed();
            return Err(SubmitError::Closed);
        }
        let req = Request { id, window, submitted: Instant::now(), key, stream, reply };
        match self.tx.try_send(BatcherMsg::Req(req)) {
            Ok(()) => {
                self.metrics.on_submit();
                Ok(())
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.on_shed();
                Err(SubmitError::Overloaded)
            }
            Err(TrySendError::Disconnected(_)) => {
                // Teardown race (batcher already gone): count it, so
                // requests turned away here don't vanish from the
                // submitted/shed accounting.
                self.metrics.on_rejected_closed();
                Err(SubmitError::Closed)
            }
        }
    }

    /// Submit a window. Fails fast with [`SubmitError::Overloaded`] when
    /// the bounded admission queue is full (the load-shedding path) and
    /// [`SubmitError::Closed`] after shutdown — never blocks, never
    /// queues unboundedly.
    pub fn try_submit(&self, window: Window) -> Result<Receiver<Response>, SubmitError> {
        let started = Instant::now();
        let (reply, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if let Some(cache) = &self.cache {
            // Same fast-fail gate rule as submit_inner, checked up front:
            // a closed lane never answers from its cache.
            if !self.gate_open() {
                self.metrics.on_rejected_closed();
                return Err(SubmitError::Closed);
            }
            let key = window_key(&window);
            if let Some(score) = cache.lookup(&key) {
                self.metrics.on_cache_hit();
                let _ = reply.send(self.cached_response(id, score, started));
                return Ok(rx);
            }
            // Blocking submits only ever *join* a flight — a blocking
            // leader has no completion hook, so a worker panic would
            // strand its followers. A blocking miss with no open flight
            // takes the normal admission path (two concurrent blocking
            // misses may both score; bit-identity makes that harmless).
            if cache.attach(&key, || Follower::Blocking { id, reply: reply.clone() }) {
                self.metrics.on_coalesced();
                return Ok(rx);
            }
            self.submit_inner(id, window, Some(key), None, reply)?;
            return Ok(rx);
        }
        self.submit_inner(id, window, None, None, reply)?;
        Ok(rx)
    }

    /// Whether the admission gate is open right now (same fast-fail rule
    /// as `submit_inner`: a write-locked gate means teardown in progress).
    fn gate_open(&self) -> bool {
        match self.accepting.try_read() {
            Ok(g) => *g,
            Err(_) => false,
        }
    }

    /// A response synthesized from a cached score: zero queue/service
    /// time (the request never entered the lane), real e2e wall time.
    fn cached_response(&self, id: u64, score: f64, started: Instant) -> Response {
        Response {
            id,
            score,
            is_anomaly: score > self.threshold,
            queue_us: 0.0,
            service_us: 0.0,
            e2e_us: started.elapsed().as_secs_f64() * 1e6,
        }
    }

    /// Single-flight entries currently open on this lane (leaders
    /// submitted, outcome not yet fanned out). Zero when uncached.
    pub fn coalescing_inflight(&self) -> usize {
        self.cache.as_ref().map_or(0, |c| c.flights())
    }

    /// Nonblocking submit: returns a [`Ticket`] immediately instead of a
    /// `Receiver` the caller must park a thread on. Admission, batching,
    /// backpressure, and shedding are byte-for-byte the blocking path
    /// ([`Lane::try_submit`]) — a shed submission fails `Overloaded`
    /// before any ticket is issued — but completion is delivered by the
    /// lane's single router thread into the ticket's shared slot, so one
    /// client thread can hold thousands of requests in flight. See
    /// [`super::front`] for the ticket lifecycle.
    pub fn submit_async(&self, window: Window) -> Result<Ticket, SubmitError> {
        let started = Instant::now();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let Some(cache) = self.cache.clone() else {
            return self.submit_async_direct(id, window, None, None);
        };
        // Same fast-fail gate rule as submit_inner, checked up front: a
        // closed lane never answers from its cache.
        if !self.gate_open() {
            self.metrics.on_rejected_closed();
            return Err(SubmitError::Closed);
        }
        let key = window_key(&window);
        if let Some(score) = cache.lookup(&key) {
            self.metrics.on_cache_hit();
            let (ticket, slot) = Ticket::raw(id, self.front.lane_name());
            slot.complete(Ok(self.cached_response(id, score, started)));
            return Ok(ticket);
        }
        // Single-flight election under the map lock: exactly one of N
        // concurrent same-key submits leads; the rest attach raw tickets
        // the leader's outcome will complete.
        let mut follower = None;
        let leads = cache.lead_or_attach(&key, || {
            let (ticket, slot) = Ticket::raw(id, self.front.lane_name());
            follower = Some(ticket);
            Follower::Async { id, slot }
        });
        if !leads {
            self.metrics.on_coalesced();
            return Ok(follower.expect("attaching built a follower ticket"));
        }
        match self.submit_async_direct(id, window, Some(key.clone()), None) {
            Ok(ticket) => {
                // Fan the leader's outcome — Ok, Cancelled, or the exit
                // drain's Closed after a worker panic — out to followers.
                // `observe` fires even if completion raced this attach.
                let fan = cache.clone();
                ticket.observe(move |outcome| fan.release(&key, outcome));
                Ok(ticket)
            }
            Err(e) => {
                // The leader never entered the lane (shed/closed):
                // poison any followers that raced in behind it.
                cache.release(&key, &Err(e.clone()));
                Err(e)
            }
        }
    }

    /// The uncached async submit: issue a router slot, then run the
    /// shared admission path.
    fn submit_async_direct(
        &self,
        id: u64,
        window: Window,
        key: Option<CacheKey>,
        stream: Option<u64>,
    ) -> Result<Ticket, SubmitError> {
        // Register the completion slot before the request can enter the
        // queue, so the reply can never beat the registration.
        let (ticket, reply) = match self.front.issue(id) {
            Ok(pair) => pair,
            Err(e) => {
                // Router already shut down: same accounting as the
                // gate-closed path.
                self.metrics.on_rejected_closed();
                return Err(e);
            }
        };
        match self.submit_inner(id, window, key, stream, reply) {
            Ok(()) => Ok(ticket),
            Err(e) => {
                self.front.revoke(id);
                Err(e)
            }
        }
    }

    /// Async submissions currently in flight through the completion
    /// router (accepted via [`Lane::submit_async`], reply not yet
    /// delivered). Dropped tickets still count until their response
    /// arrives — the router forgets a slot at delivery, never leaks it.
    pub fn async_inflight(&self) -> usize {
        self.front.inflight()
    }

    /// Open (or reopen with fresh state — the documented reset semantic)
    /// stream session `stream`, scoring a sliding window of `window`
    /// samples (`0` → the lane's [`SessionConfig::window`]). Opening past
    /// the table's capacity evicts the least-recently-stepped session.
    /// Fails with [`SubmitError::UnknownStream`] on a window-only lane
    /// and [`SubmitError::Closed`] after shutdown.
    pub fn open_stream(&self, stream: u64, window: usize) -> Result<(), SubmitError> {
        let Some(table) = &self.sessions else {
            return Err(SubmitError::UnknownStream(stream));
        };
        if !self.gate_open() {
            return Err(SubmitError::Closed);
        }
        table.open(stream, window);
        self.metrics.set_sessions(table.len());
        Ok(())
    }

    /// Close stream session `stream`, releasing its table slot. Closing
    /// an unknown (or never-opened) session is a no-op.
    pub fn close_stream(&self, stream: u64) {
        if let Some(table) = &self.sessions {
            table.close(stream);
            self.metrics.set_sessions(table.len());
        }
    }

    /// Feed one `F`-feature sample to an open session: the O(1)
    /// incremental path. Admission, batching, backpressure, and shedding
    /// are exactly the window path's (the sample rides the same bounded
    /// queue — session steps join the admission accounting law); the
    /// batcher groups same-lane steps into one batched
    /// [`step_sessions_batch`] call, and the [`Ticket`] resolves to the
    /// session's updated sliding-window score with the per-session
    /// recalibrated threshold applied.
    ///
    /// Fails fast with [`SubmitError::UnknownStream`] when the session
    /// is not open (never opened / closed / evicted) and
    /// [`SubmitError::TooLarge`] on a width-mismatched sample.
    pub fn submit_sample_async(
        &self,
        stream: u64,
        sample: Vec<f32>,
    ) -> Result<Ticket, SubmitError> {
        let Some(table) = &self.sessions else {
            return Err(SubmitError::UnknownStream(stream));
        };
        if sample.len() != table.features() {
            return Err(SubmitError::TooLarge);
        }
        if !table.contains(stream) {
            return Err(SubmitError::UnknownStream(stream));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let window = Window { data: vec![sample], anomaly: None };
        // Steps never touch the cache: carried state makes every step of
        // a stream distinct even when sample bytes repeat.
        self.submit_async_direct(id, window, None, Some(stream))
    }

    /// This lane's session table, when the backend supports streams —
    /// exposed for lifecycle inspection (open count, capacity) in tests
    /// and reports.
    pub fn session_table(&self) -> Option<&SessionTable> {
        self.sessions.as_deref()
    }

    /// Submit and wait. A lane torn down while the request is in flight
    /// yields [`SubmitError::Closed`] instead of a panic.
    pub fn score_blocking(&self, window: Window) -> Result<Response, SubmitError> {
        self.try_submit(window)?.recv().map_err(|_| SubmitError::Closed)
    }

    /// Graceful shutdown: stop admitting, drain in-flight work, join all
    /// lane threads (batcher first, then every worker — including ones
    /// added by an autoscaler). Idempotent.
    pub fn shutdown(&self) {
        {
            let mut accepting = self.accepting.write().unwrap();
            if *accepting {
                *accepting = false;
                // Blocking send under the write lock: the batcher is
                // still draining, and every accepted request already
                // sits ahead of this marker in the queue.
                let _ = self.tx.send(BatcherMsg::Shutdown);
            }
        }
        if let Some(h) = self.batcher.lock().unwrap().take() {
            let _ = h.join();
        }
        // With the batcher gone, dropping our retained producer endpoint
        // disconnects the batch queue; every worker drains what was
        // dispatched and exits.
        self.workers.shutdown();
        // Workers drained ⇒ every async reply is already in the router's
        // channel; the router routes them all, poisons any slot whose
        // request died with a panicking worker, and exits.
        self.front.shutdown();
    }
}

impl Drop for Lane {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Decrements the lane's alive count on *any* worker exit — return,
/// retirement, or a panic unwinding out of `Backend::score_batch`. Before
/// this guard, a panicking backend left `alive` stuck high forever:
/// `effective_workers` over-counted and the autoscaler kept sizing a
/// phantom pool. Panic exits are additionally surfaced through the
/// [`ServerMetrics::worker_panics`] counter.
struct WorkerExitGuard {
    alive: Arc<AtomicUsize>,
    metrics: Arc<ServerMetrics>,
}

impl Drop for WorkerExitGuard {
    fn drop(&mut self) {
        self.alive.fetch_sub(1, Ordering::Relaxed);
        if std::thread::panicking() {
            self.metrics.on_worker_panic();
        }
    }
}

// Nine parameters because the worker IS the junction of every lane
// subsystem (backend, queue, metrics, cancellation, cache, sessions,
// lifecycle); a params struct would only add noise at the single call
// site.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    backend: Arc<dyn Backend>,
    rx: Arc<Mutex<Receiver<WorkerMsg>>>,
    metrics: Arc<ServerMetrics>,
    threshold: f64,
    cancels: CancelSet,
    cache: Option<Arc<LaneCache>>,
    sessions: Option<Arc<SessionTable>>,
    alive: Arc<AtomicUsize>,
    pending_retire: Arc<AtomicUsize>,
) {
    let _exit = WorkerExitGuard { alive, metrics: metrics.clone() };
    loop {
        let wait_start = Instant::now();
        let guard = rx.lock().unwrap();
        let msg = guard.recv();
        metrics.on_worker_idle(wait_start.elapsed().as_nanos() as u64);
        let mut batch = match msg {
            Ok(WorkerMsg::Batch(b)) => b,
            Ok(WorkerMsg::Retire) => {
                pending_retire.fetch_sub(1, Ordering::Relaxed);
                break;
            }
            Err(_) => break,
        };
        // Session-step batches run to completion while the dispatch lock
        // is still held: a step is O(1) per sample, and serializing step
        // batches keeps same-stream samples applying in dispatch order
        // across workers (state carry makes order semantic — two workers
        // racing consecutive steps of one stream would be a data race on
        // meaning, if not on memory). Window batches drop the lock and
        // score concurrently, exactly as before.
        let step_batch = batch.first().is_some_and(|r| r.stream.is_some());
        if !step_batch {
            drop(guard);
        }
        // Last cancellation point: a request cancelled after the batcher
        // dispatched its batch is dropped here, just before scoring. One
        // lock acquisition for the whole batch — the guard is held
        // across the retain so the hot path doesn't pay per-element
        // contention against cancel writers.
        {
            let mut marks = cancels.lock().unwrap();
            if !marks.is_empty() {
                batch.retain(|req| {
                    let cancelled = marks.remove(&req.id);
                    if cancelled {
                        metrics.on_cancelled();
                    }
                    !cancelled
                });
            }
        }
        if batch.is_empty() {
            continue;
        }
        let dispatch = Instant::now();
        if step_batch {
            // Admission only accepts samples on lanes with a table; a
            // `None` here is unreachable, but dropping the batch beats
            // panicking the worker.
            let Some(table) = &sessions else { continue };
            let reqs: Vec<(u64, &[f32])> = batch
                .iter()
                .map(|r| (r.stream.expect("step batch"), r.window.data[0].as_slice()))
                .collect();
            let (scored, resets) = table.step_many(&reqs, threshold);
            if resets > 0 {
                metrics.on_stream_resets(resets);
                metrics.set_sessions(table.len());
            }
            let service_us = dispatch.elapsed().as_secs_f64() * 1e6;
            metrics.on_batch(batch.len(), service_us);
            for (req, (score, is_anomaly)) in batch.into_iter().zip(scored) {
                let e2e_us = req.submitted.elapsed().as_secs_f64() * 1e6;
                let queue_us = e2e_us - service_us;
                let resp = Response {
                    id: req.id,
                    score,
                    is_anomaly,
                    queue_us: queue_us.max(0.0),
                    service_us,
                    e2e_us,
                };
                metrics.on_response(&resp);
                let _ = req.reply.send(resp);
            }
            continue;
        }
        let windows: Vec<&Window> = batch.iter().map(|r| &r.window).collect();
        let scores = backend.score_batch(&windows);
        let service_us = dispatch.elapsed().as_secs_f64() * 1e6;
        metrics.on_batch(batch.len(), service_us);
        for (req, score) in batch.into_iter().zip(scores) {
            let e2e_us = req.submitted.elapsed().as_secs_f64() * 1e6;
            let queue_us = e2e_us - service_us;
            let resp = Response {
                id: req.id,
                score,
                is_anomaly: score > threshold,
                queue_us: queue_us.max(0.0),
                service_us,
                e2e_us,
            };
            metrics.on_response(&resp);
            // Populate the cache BEFORE replying: by the time any waiter
            // (or coalesced follower) observes this response, a repeat of
            // the same window is already a hit — the miss→hit sequence
            // in the integration tests is deterministic because of this
            // ordering.
            if let (Some(cache), Some(key)) = (&cache, &req.key) {
                let evicted = cache.insert(key.clone(), score);
                if evicted > 0 {
                    metrics.on_cache_evictions(evicted);
                }
            }
            let _ = req.reply.send(resp);
        }
    }
}

/// A registry of concurrently-served models: one [`Lane`] per model name,
/// each with its own backend, batching policy, bounded queue, and
/// metrics — plus an optional fleet [`Autoscaler`] driving lanes whose
/// config carries an [`AutoscalePolicy`].
///
/// ```
/// use std::sync::Arc;
/// use lstm_ae_accel::model::{LstmAutoencoder, Topology};
/// use lstm_ae_accel::server::{ModelRegistry, QuantBackend, ServerConfig};
/// use lstm_ae_accel::workload::TelemetryGen;
///
/// let mut registry = ModelRegistry::new();
/// let topo = Topology::from_name("F32-D2").unwrap();
/// let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(topo.clone(), 1)));
/// registry.register(&topo.name, backend, ServerConfig::default());
///
/// let mut gen = TelemetryGen::new(topo.features, 2);
/// let response = registry.score_blocking("F32-D2", gen.benign_window(4)).unwrap();
/// assert!(response.score.is_finite() && response.score >= 0.0);
/// registry.shutdown();
/// ```
pub struct ModelRegistry {
    lanes: BTreeMap<String, Arc<Lane>>,
    autoscaler: Mutex<Option<Autoscaler>>,
}

/// One registry-wide load sample (see [`ModelRegistry::fleet_load`]):
/// what a shard reports about itself in a control-plane heartbeat.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FleetLoad {
    /// Requests accepted but not yet completed or cancelled, summed over
    /// lanes (queued + batching + scoring + awaiting pickup).
    pub inflight: u64,
    /// Cumulative admission sheds over all lanes.
    pub shed: u64,
    /// Completed-weighted mean of per-lane p50 e2e latency, µs.
    pub p50_us: f64,
    /// Completed-weighted mean of per-lane p99 e2e latency, µs.
    pub p99_us: f64,
}

impl ModelRegistry {
    /// An empty registry (no lanes, no autoscaler).
    pub fn new() -> ModelRegistry {
        ModelRegistry { lanes: BTreeMap::new(), autoscaler: Mutex::new(None) }
    }

    /// Register a model under `name` and spawn its lane. Panics on a
    /// duplicate name — two backends for one model is a config error.
    pub fn register(&mut self, name: &str, backend: Arc<dyn Backend>, cfg: ServerConfig) {
        assert!(!self.lanes.contains_key(name), "model {name:?} already registered");
        self.lanes.insert(name.to_string(), Arc::new(Lane::start(name, backend, cfg)));
    }

    /// Look up a lane by registered name, falling back to the canonical
    /// topology name so `"F64-D6"` finds `"LSTM-AE-F64-D6"`.
    pub fn lane(&self, model: &str) -> Option<&Lane> {
        if let Some(l) = self.lanes.get(model) {
            return Some(l.as_ref());
        }
        let canon = Topology::from_name(model).ok()?.name;
        self.lanes.get(&canon).map(|l| l.as_ref())
    }

    /// Registered model names, in registry (lexicographic) order.
    pub fn models(&self) -> impl Iterator<Item = &str> {
        self.lanes.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    /// Submit a window to a model's lane (see [`Lane::try_submit`]).
    pub fn submit(&self, model: &str, window: Window) -> Result<Receiver<Response>, SubmitError> {
        self.lane(model)
            .ok_or_else(|| SubmitError::UnknownModel(model.to_string()))?
            .try_submit(window)
    }

    /// Nonblocking submit to a model's lane through the async front (see
    /// [`Lane::submit_async`]): returns a [`Ticket`] immediately; combine
    /// tickets across lanes with a [`super::CompletionSet`] for
    /// first-of-N fan-in.
    pub fn submit_async(&self, model: &str, window: Window) -> Result<Ticket, SubmitError> {
        self.lane(model)
            .ok_or_else(|| SubmitError::UnknownModel(model.to_string()))?
            .submit_async(window)
    }

    /// Submit to a model's lane and wait for the response.
    pub fn score_blocking(&self, model: &str, window: Window) -> Result<Response, SubmitError> {
        self.lane(model)
            .ok_or_else(|| SubmitError::UnknownModel(model.to_string()))?
            .score_blocking(window)
    }

    /// Open a stream session on a model's lane (see
    /// [`Lane::open_stream`]).
    pub fn open_stream(&self, model: &str, stream: u64, window: usize) -> Result<(), SubmitError> {
        self.lane(model)
            .ok_or_else(|| SubmitError::UnknownModel(model.to_string()))?
            .open_stream(stream, window)
    }

    /// Feed one sample to an open session on a model's lane (see
    /// [`Lane::submit_sample_async`]).
    pub fn submit_sample(
        &self,
        model: &str,
        stream: u64,
        sample: Vec<f32>,
    ) -> Result<Ticket, SubmitError> {
        self.lane(model)
            .ok_or_else(|| SubmitError::UnknownModel(model.to_string()))?
            .submit_sample_async(stream, sample)
    }

    /// Close a stream session on a model's lane; unknown model or
    /// session is a no-op (close must be safe to fire at teardown).
    pub fn close_stream(&self, model: &str, stream: u64) {
        if let Some(lane) = self.lane(model) {
            lane.close_stream(stream);
        }
    }

    /// Per-model metrics rolled up into one fleet report, including each
    /// lane's current worker count, pipeline replicas, the scaling
    /// decisions an [`Autoscaler`] has applied (`scale +/-`), and the
    /// streaming columns (open `sessions` gauge, cumulative stream
    /// `resets`).
    pub fn fleet_report(&self) -> String {
        let mut t = Table::new("Fleet report (per-model lanes)").header(&[
            "Model",
            "submitted",
            "shed",
            "completed",
            "flagged",
            "mean batch",
            "p50 µs",
            "p95 µs",
            "rps",
            "workers",
            "repl",
            "scale +/-",
            "cache h/c",
            "sessions",
            "resets",
        ]);
        let (mut sub, mut shed, mut comp, mut anom) = (0u64, 0u64, 0u64, 0u64);
        let (mut hits, mut coal) = (0u64, 0u64);
        let (mut sess, mut resets) = (0usize, 0u64);
        for lane in self.lanes.values() {
            let m = lane.metrics();
            let (p50, p95, _) = m.e2e_percentiles_us();
            let (ups, downs) = lane.scale_counts();
            t.row(vec![
                lane.name().to_string(),
                m.submitted().to_string(),
                m.shed().to_string(),
                m.completed().to_string(),
                m.anomalies().to_string(),
                format!("{:.2}", m.mean_batch_size()),
                format!("{p50:.0}"),
                format!("{p95:.0}"),
                format!("{:.0}", m.throughput_rps()),
                lane.workers().to_string(),
                lane.pipeline_replicas().map_or_else(|| "-".to_string(), |r| r.to_string()),
                format!("{ups}/{downs}"),
                format!("{}/{}", m.cache_hits(), m.coalesced()),
                m.sessions().to_string(),
                m.stream_resets().to_string(),
            ]);
            sub += m.submitted();
            shed += m.shed();
            comp += m.completed();
            anom += m.anomalies();
            hits += m.cache_hits();
            coal += m.coalesced();
            sess += m.sessions();
            resets += m.stream_resets();
        }
        // Cache and stream totals are always in the footer (even at
        // zero) so soak harnesses can grep one stable line for the hit,
        // session, and reset counts.
        format!(
            "{}fleet: {sub} submitted, {shed} shed, {comp} completed, {anom} flagged, \
             {hits} cache hits, {coal} coalesced, {sess} sessions, \
             {resets} stream resets across {} lanes\n",
            t.render(),
            self.lanes.len()
        )
    }

    /// Aggregate load snapshot across every lane — the payload of a
    /// control-plane heartbeat ([`crate::net::ShardServer`] answers each
    /// `HealthProbe` with one): accepted-but-unfinished requests,
    /// cumulative sheds, and completed-weighted p50/p99 end-to-end
    /// latency in µs (0.0 until anything completes).
    pub fn fleet_load(&self) -> FleetLoad {
        let mut load = FleetLoad::default();
        let mut weight = 0.0f64;
        for lane in self.lanes.values() {
            let m = lane.metrics();
            // Counter reads race (Relaxed), so the difference saturates
            // rather than wrapping when a completion lands between reads.
            load.inflight +=
                m.submitted().saturating_sub(m.completed().saturating_add(m.cancelled()));
            load.shed += m.shed();
            let done = m.completed() as f64;
            if done > 0.0 {
                let (p50, _, p99) = m.e2e_percentiles_us();
                load.p50_us += p50 * done;
                load.p99_us += p99 * done;
                weight += done;
            }
        }
        if weight > 0.0 {
            load.p50_us /= weight;
            load.p99_us /= weight;
        }
        load
    }

    /// Start the fleet autoscaler over every lane whose config carries an
    /// [`AutoscalePolicy`], sampling on `tick`. `worker_budget` caps the
    /// fleet-wide worker-thread total (scale-ups are skipped at the cap),
    /// so an adaptive fleet can be compared against a static one at equal
    /// thread budget. Returns the number of lanes under control; 0 when
    /// no lane has a policy or an autoscaler is already running.
    pub fn start_autoscaler(&self, tick: Duration, worker_budget: Option<usize>) -> usize {
        let watched: Vec<Arc<Lane>> = self
            .lanes
            .values()
            .filter(|l| l.autoscale_policy().is_some())
            .cloned()
            .collect();
        if watched.is_empty() {
            return 0;
        }
        let mut guard = self.autoscaler.lock().unwrap();
        if guard.is_some() {
            return 0;
        }
        let n = watched.len();
        *guard = Some(Autoscaler::start(watched, tick, worker_budget));
        n
    }

    /// Stop the fleet autoscaler, if one is running (idempotent). Lane
    /// worker/replica counts stay wherever the last tick left them.
    pub fn stop_autoscaler(&self) {
        if let Some(a) = self.autoscaler.lock().unwrap().take() {
            a.stop();
        }
    }

    /// Shut every lane down (graceful, idempotent). The autoscaler, if
    /// running, is stopped first so it cannot resize lanes mid-teardown.
    pub fn shutdown(&self) {
        self.stop_autoscaler();
        for lane in self.lanes.values() {
            lane.shutdown();
        }
    }

    /// A registry serving all four paper topologies (§4.1) concurrently
    /// on quantized golden-model backends. Deterministic seeding: model
    /// `i` in Table-1 order uses `base_seed + i`, so tests can rebuild
    /// bit-identical reference models. Deep (D6) lanes hold a longer
    /// batching deadline, a larger `max_batch`, and `replicas` pipeline
    /// replicas; shallow (D2) lanes stay latency-tight.
    pub fn paper_fleet(base_seed: u64, mode: ExecMode, replicas: usize) -> ModelRegistry {
        Self::paper_fleet_with(base_seed, mode, replicas, None)
    }

    /// [`Self::paper_fleet`] with a per-lane autoscaling policy: every
    /// lane gets a clone of `autoscale`, making the whole fleet eligible
    /// for [`Self::start_autoscaler`].
    pub fn paper_fleet_with(
        base_seed: u64,
        mode: ExecMode,
        replicas: usize,
        autoscale: Option<AutoscalePolicy>,
    ) -> ModelRegistry {
        Self::paper_fleet_opts(
            base_seed,
            mode,
            replicas,
            autoscale,
            PipelineOptions::default(),
            None,
        )
    }

    /// [`Self::paper_fleet_with`] plus fleet-wide engine options. When
    /// `engine.pin_base_core` is set, each lane that actually builds a
    /// pipeline pool is assigned a disjoint run of cores starting where
    /// the previous pooled lane's replicas end (`depth × replicas` cores
    /// per lane, wrapping modulo the online core count inside the
    /// pipeline), so two lanes' stage workers never contend for a pin;
    /// every lane's batch-engine *worker* threads then take the next
    /// `workers` cores from the same counter
    /// ([`ServerConfig::pin_base_core`]), extending the pinning to the
    /// non-pipelined scoring paths. `cache` applies the same score-cache
    /// config to every lane (`None` runs the fleet uncached — the
    /// default everywhere else).
    pub fn paper_fleet_opts(
        base_seed: u64,
        mode: ExecMode,
        replicas: usize,
        autoscale: Option<AutoscalePolicy>,
        engine: PipelineOptions,
        cache: Option<CacheConfig>,
    ) -> ModelRegistry {
        let mut reg = ModelRegistry::new();
        let mut next_core = engine.pin_base_core;
        for (i, topo) in Topology::paper_models().into_iter().enumerate() {
            let ae = LstmAutoencoder::random(topo.clone(), base_seed + i as u64);
            // Only lanes that will build a pool consume core budget.
            let pooled = match mode {
                ExecMode::Pipelined => true,
                ExecMode::Auto => topo.depth >= PIPELINE_MIN_DEPTH,
                ExecMode::Sequential | ExecMode::Batched => false,
            };
            let lane_engine = PipelineOptions {
                pin_base_core: if pooled { next_core } else { None },
                ..engine
            };
            if pooled {
                next_core = next_core.map(|c| c + topo.depth * replicas.max(1));
            }
            // `replicas` is passed unconditionally: `with_engine_options`
            // only builds the pool when `mode` can route to the pipeline,
            // so shallow Auto lanes stay pool-free while Pipelined mode
            // gets its replicas at every depth.
            let backend =
                Arc::new(QuantBackend::with_engine_options(ae, mode, replicas, lane_engine));
            let mut cfg = ServerConfig {
                autoscale: autoscale.clone(),
                cache: cache.clone(),
                ..Self::paper_lane_config(&topo, replicas)
            };
            if engine.pin_base_core.is_some() {
                cfg.pin_base_core = next_core;
                next_core = next_core.map(|c| c + cfg.workers);
            }
            reg.register(&topo.name, backend, cfg);
        }
        reg
    }

    /// The per-model lane policy [`Self::paper_fleet`] applies (exported
    /// so tests/examples stay in sync with it): deep models
    /// (`depth ≥ PIPELINE_MIN_DEPTH`) trade deadline for batch size and
    /// get replica-sized worker pools; shallow models stay latency-tight.
    pub fn paper_lane_config(topo: &Topology, replicas: usize) -> ServerConfig {
        let deep = topo.depth >= PIPELINE_MIN_DEPTH;
        ServerConfig {
            max_batch: if deep { 16 } else { 8 },
            max_wait: Duration::from_micros(if deep { 2000 } else { 300 }),
            workers: if deep { replicas.max(2) } else { 2 },
            queue_capacity: 1024,
            threshold: 0.05,
            autoscale: None,
            cache: None,
            sessions: SessionConfig::default(),
            pin_base_core: None,
        }
    }
}

impl super::ServingSurface for ModelRegistry {
    fn submit_async(&self, model: &str, window: Window) -> Result<Ticket, SubmitError> {
        ModelRegistry::submit_async(self, model, window)
    }

    /// The in-process surface keeps its dedicated blocking path (a plain
    /// `Receiver` wait, no router slot) rather than the trait default.
    fn score_blocking(&self, model: &str, window: Window) -> Result<Response, SubmitError> {
        ModelRegistry::score_blocking(self, model, window)
    }

    fn open_stream(&self, model: &str, stream: u64, window: usize) -> Result<(), SubmitError> {
        ModelRegistry::open_stream(self, model, stream, window)
    }

    fn submit_sample(
        &self,
        model: &str,
        stream: u64,
        sample: Vec<f32>,
    ) -> Result<Ticket, SubmitError> {
        ModelRegistry::submit_sample(self, model, stream, sample)
    }

    fn close_stream(&self, model: &str, stream: u64) {
        ModelRegistry::close_stream(self, model, stream)
    }

    fn fleet_report(&self) -> String {
        ModelRegistry::fleet_report(self)
    }
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for ModelRegistry {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TelemetryGen;

    /// Backend whose scoring blocks until the test's gate sender is
    /// dropped — makes queue-full conditions deterministic.
    struct GatedBackend {
        gate: Mutex<Receiver<()>>,
    }

    impl Backend for GatedBackend {
        fn name(&self) -> String {
            "gated".into()
        }

        fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
            // Blocks until the test releases (drops) the gate sender;
            // afterwards recv fails fast and scoring is immediate.
            let _ = self.gate.lock().unwrap().recv();
            vec![0.0; windows.len()]
        }
    }

    fn tiny_window() -> Window {
        Window { data: vec![vec![0.0f32]], anomaly: None }
    }

    #[test]
    fn bounded_lane_sheds_when_backend_stalls_and_recovers() {
        let (gate_tx, gate_rx) = channel::<()>();
        let backend = Arc::new(GatedBackend { gate: Mutex::new(gate_rx) });
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(1),
            workers: 1,
            queue_capacity: 2,
            threshold: 1.0,
            ..Default::default()
        };
        let lane = Lane::start("gated", backend, cfg);
        // Worker blocks on the first batch; the batch queue (cap 2), the
        // batcher's open flush, and the admission queue (cap 2) fill
        // behind it — within a bounded number of submissions one MUST be
        // shed. 32 is far above that bound.
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for _ in 0..32 {
            match lane.try_submit(tiny_window()) {
                Ok(rx) => accepted.push(rx),
                Err(SubmitError::Overloaded) => shed += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(shed > 0, "bounded queues must shed under a stalled backend");
        assert!(!accepted.is_empty());
        assert_eq!(lane.metrics().shed(), shed);
        assert_eq!(lane.metrics().submitted(), accepted.len() as u64);
        // Release the gate: every accepted request completes (recovery).
        drop(gate_tx);
        for rx in accepted {
            let r = rx.recv().expect("accepted work survives overload");
            assert_eq!(r.score, 0.0);
        }
        // And the lane accepts fresh traffic again.
        assert!(lane.score_blocking(tiny_window()).is_ok());
        lane.shutdown();
    }

    /// Panics when handed the poison marker window (`data[0][0] == 666`),
    /// scores 0.0 otherwise — the injected backend failure for the
    /// worker-panic regression tests.
    struct PanickingBackend;

    impl Backend for PanickingBackend {
        fn name(&self) -> String {
            "panicking".into()
        }

        fn score_batch(&self, windows: &[&Window]) -> Vec<f64> {
            if windows.iter().any(|w| w.data[0][0] == 666.0) {
                panic!("injected backend failure (expected by the worker-panic tests)");
            }
            vec![0.0; windows.len()]
        }
    }

    fn poison_window() -> Window {
        Window { data: vec![vec![666.0f32]], anomaly: None }
    }

    /// Spin until `cond` holds or ~5 s elapse (worker exit and metric
    /// updates land asynchronously with the test thread).
    fn wait_for(mut cond: impl FnMut() -> bool) -> bool {
        let deadline = Instant::now() + Duration::from_secs(5);
        while !cond() {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        true
    }

    #[test]
    fn worker_panic_decrements_alive_and_is_counted() {
        // Regression guard: a backend panic used to unwind worker_loop
        // past its alive-count decrement, so effective_workers
        // over-counted forever and the autoscaler sized a phantom pool.
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(1),
            workers: 2,
            queue_capacity: 64,
            threshold: 1.0,
            ..Default::default()
        };
        let lane = Lane::start("panicky", Arc::new(PanickingBackend), cfg);
        assert_eq!(lane.workers(), 2);
        let rx = lane.try_submit(poison_window()).expect("admitted");
        // The panicking worker dies without replying; its requests are
        // dropped, so the blocking receiver errors rather than hanging.
        assert!(rx.recv().is_err(), "poisoned request never gets a response");
        assert!(
            wait_for(|| lane.workers() == 1 && lane.metrics().worker_panics() == 1),
            "panicked worker must leave the alive count and be counted \
             (workers {}, panics {})",
            lane.workers(),
            lane.metrics().worker_panics(),
        );
        // The surviving worker keeps the lane serving.
        let r = lane.score_blocking(tiny_window()).expect("lane survives a worker panic");
        assert_eq!(r.score, 0.0);
        lane.shutdown();
        assert_eq!(lane.metrics().worker_panics(), 1);
    }

    #[test]
    fn admission_accounting_conserves_across_shed_drain_and_shutdown() {
        // Every submit call lands in exactly one of submitted / shed /
        // rejected_closed, and after a full drain submitted == completed
        // (conservation: nothing vanishes, not even during teardown).
        let (gate_tx, gate_rx) = channel::<()>();
        let backend = Arc::new(GatedBackend { gate: Mutex::new(gate_rx) });
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(1),
            workers: 1,
            queue_capacity: 2,
            threshold: 1.0,
            ..Default::default()
        };
        let lane = Lane::start("conserve", backend, cfg);
        let attempts = 16u64;
        let mut accepted = Vec::new();
        let mut shed = 0u64;
        for _ in 0..attempts {
            match lane.try_submit(tiny_window()) {
                Ok(rx) => accepted.push(rx),
                Err(SubmitError::Overloaded) => shed += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert_eq!(lane.metrics().rejected_closed(), 0, "no teardown yet");
        drop(gate_tx);
        for rx in &accepted {
            rx.recv().expect("accepted work completes");
        }
        lane.shutdown();
        let closed_attempts = 5u64;
        for _ in 0..closed_attempts {
            assert_eq!(lane.try_submit(tiny_window()).unwrap_err(), SubmitError::Closed);
        }
        let m = lane.metrics();
        assert_eq!(m.submitted(), accepted.len() as u64);
        assert_eq!(m.shed(), shed);
        assert_eq!(
            m.rejected_closed(),
            closed_attempts,
            "requests rejected during/after teardown must be counted, not vanish"
        );
        assert_eq!(
            m.submitted() + m.shed() + m.rejected_closed(),
            attempts + closed_attempts,
            "every admission attempt lands in exactly one bucket"
        );
        assert_eq!(m.completed(), m.submitted(), "drained lane: in-flight is zero");
    }

    #[test]
    fn async_submit_scores_like_blocking_and_clears_router_slots() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(topo.clone(), 3)));
        let reference = LstmAutoencoder::random(topo, 3);
        let lane = Lane::start("async", backend, ServerConfig::default());
        let mut gen = TelemetryGen::new(32, 9);
        let mut tickets = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..12 {
            let w = gen.benign_window(6);
            wants.push(reference.score_quant(&w.data));
            tickets.push(lane.submit_async(w).expect("admitted"));
        }
        assert!(lane.async_inflight() <= 12);
        for (t, want) in tickets.iter().zip(&wants) {
            let r = t.wait().expect("accepted async work completes");
            assert_eq!(r.score.to_bits(), want.to_bits(), "async == sequential bits");
            assert_eq!(r.id, t.id());
        }
        assert!(
            wait_for(|| lane.async_inflight() == 0),
            "delivered slots must leave the router map"
        );
        assert_eq!(lane.metrics().completed(), 12);
        lane.shutdown();
        // Post-shutdown async submits are counted Closed rejections.
        assert_eq!(lane.submit_async(gen.benign_window(4)).unwrap_err(), SubmitError::Closed);
        assert_eq!(lane.metrics().rejected_closed(), 1);
    }

    #[test]
    fn cancel_removes_queued_requests_and_accounting_conserves() {
        // One worker blocked on a gated batch; everything submitted
        // behind it is still queued (admission queue or batch queue) and
        // must be actively removable.
        let (gate_tx, gate_rx) = channel::<()>();
        let backend = Arc::new(GatedBackend { gate: Mutex::new(gate_rx) });
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(1),
            workers: 1,
            queue_capacity: 64,
            threshold: 1.0,
            ..Default::default()
        };
        let lane = Lane::start("cancel", backend, cfg);
        // First request occupies the worker behind the gate...
        let head = lane.submit_async(tiny_window()).expect("admitted");
        // ...then a backlog of cancellable requests queues behind it.
        let queued: Vec<Ticket> =
            (0..8).map(|_| lane.submit_async(tiny_window()).expect("admitted")).collect();
        let mut cancelled = 0u64;
        for t in &queued {
            if t.cancel() {
                cancelled += 1;
                // Cancel resolves immediately — before the gate opens.
                assert_eq!(t.poll().unwrap().unwrap_err(), SubmitError::Cancelled);
            }
        }
        assert!(cancelled > 0, "queued requests must be cancellable");
        drop(gate_tx);
        assert!(head.wait().is_ok(), "the in-worker request is past cancellation");
        for t in &queued {
            // Survivors complete; cancelled tickets keep their outcome.
            match t.wait() {
                Ok(_) | Err(SubmitError::Cancelled) => {}
                Err(e) => panic!("unexpected outcome {e}"),
            }
        }
        lane.shutdown();
        let m = lane.metrics();
        assert_eq!(m.submitted(), 9);
        assert_eq!(m.cancelled(), cancelled, "every removed request is counted");
        assert_eq!(
            m.completed() + m.cancelled(),
            m.submitted(),
            "conservation: accepted work is scored or counted cancelled, never lost"
        );
        assert!(wait_for(|| lane.async_inflight() == 0), "cancel must not leak router slots");
    }

    #[test]
    fn submit_after_shutdown_is_closed_not_a_panic() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(topo, 1)));
        let lane = Lane::start("m", backend, ServerConfig::default());
        let mut gen = TelemetryGen::new(32, 1);
        assert!(lane.score_blocking(gen.benign_window(4)).is_ok());
        lane.shutdown();
        assert_eq!(lane.try_submit(gen.benign_window(4)).unwrap_err(), SubmitError::Closed);
        assert_eq!(lane.score_blocking(gen.benign_window(4)).unwrap_err(), SubmitError::Closed);
    }

    #[test]
    fn registry_routes_by_name_with_canonical_fallback() {
        let mut reg = ModelRegistry::new();
        let topo = Topology::from_name("F32-D2").unwrap();
        let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(topo.clone(), 2)));
        reg.register(&topo.name, backend, ServerConfig::default());
        let mut gen = TelemetryGen::new(32, 2);
        // Canonical and short names hit the same lane.
        assert!(reg.score_blocking("LSTM-AE-F32-D2", gen.benign_window(4)).is_ok());
        assert!(reg.score_blocking("F32-D2", gen.benign_window(4)).is_ok());
        assert_eq!(reg.lane("F32-D2").unwrap().metrics().completed(), 2);
        match reg.submit("F64-D6", gen.benign_window(4)) {
            Err(SubmitError::UnknownModel(m)) => assert_eq!(m, "F64-D6"),
            other => panic!("want UnknownModel, got {other:?}"),
        }
        reg.shutdown();
    }

    #[test]
    fn metrics_stay_correct_across_worker_churn() {
        // Scale the worker pool up and down while traffic flows: every
        // accepted request completes exactly once, the shed counter stays
        // zero, occupancy respects max_batch, and the queue-depth gauge
        // returns to zero when the lane drains.
        let topo = Topology::from_name("F32-D2").unwrap();
        let backend = Arc::new(QuantBackend::new(LstmAutoencoder::random(topo, 4)));
        let cfg = ServerConfig { max_batch: 4, queue_capacity: 4096, ..Default::default() };
        let lane = Lane::start("churn", backend, cfg);
        let mut gen = TelemetryGen::new(32, 7);
        assert_eq!(lane.workers(), 2);

        let mut drain = |n: usize| {
            let rxs: Vec<_> = (0..n)
                .map(|_| lane.try_submit(gen.benign_window(4)).expect("queue sized"))
                .collect();
            for rx in rxs {
                rx.recv().expect("accepted work completes");
            }
        };
        drain(50);
        assert_eq!(lane.add_worker(), 3);
        drain(50);
        assert!(lane.retire_worker(), "3 workers → retirement must be issued");
        assert_eq!(lane.workers(), 2);
        drain(50);
        // Retiring down to the floor is refused: a lane keeps draining.
        assert!(lane.retire_worker());
        assert!(!lane.retire_worker(), "must never retire the last worker");
        drain(25);

        let m = lane.metrics();
        assert_eq!(m.submitted(), 175);
        assert_eq!(m.completed(), 175);
        assert_eq!(m.shed(), 0);
        assert_eq!(m.queue_depth(), 0, "drained lane has an empty admission queue");
        assert!(m.max_batch_seen() <= 4);
        assert!(m.batched_windows() == 175, "every window dispatched exactly once");
        assert!(m.worker_idle_ns() > 0, "workers waited between batches");
        lane.shutdown();
        assert_eq!(lane.metrics().completed(), 175, "shutdown drains, never drops");
    }

    #[test]
    fn stream_samples_flow_and_match_the_session_reference() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let ae = LstmAutoencoder::random(topo.clone(), 5);
        let reference = LstmAutoencoder::random(topo, 5);
        let backend = Arc::new(QuantBackend::new(ae));
        let cfg = ServerConfig {
            sessions: SessionConfig { capacity: 8, window: 4 },
            ..Default::default()
        };
        let lane = Lane::start("stream", backend, cfg);
        lane.open_stream(7, 0).expect("quant backends accept stream opens");
        assert_eq!(lane.session_table().unwrap().len(), 1);
        let mut state = SessionState::new(&reference, 4);
        let mut gen = TelemetryGen::new(32, 11);
        for _ in 0..6 {
            let sample = gen.benign_window(1).data.remove(0);
            let want = step_session(&reference, &mut state, &sample);
            let r = lane.submit_sample_async(7, sample).unwrap().wait().unwrap();
            assert_eq!(r.score.to_bits(), want.to_bits(), "lane step == direct session step");
        }
        // Width mismatches are rejected at admission, not in the worker.
        assert_eq!(lane.submit_sample_async(7, vec![0.0; 3]).unwrap_err(), SubmitError::TooLarge);
        // Samples after close fail fast with UnknownStream.
        lane.close_stream(7);
        assert_eq!(
            lane.submit_sample_async(7, vec![0.0; 32]).unwrap_err(),
            SubmitError::UnknownStream(7)
        );
        lane.shutdown();
        let m = lane.metrics();
        assert_eq!(m.completed(), 6);
        assert_eq!(m.submitted(), 6, "steps ride the same admission accounting");
        assert_eq!(m.stream_resets(), 0);
    }

    #[test]
    fn paper_fleet_serves_all_four_topologies() {
        let reg = ModelRegistry::paper_fleet(11, ExecMode::Auto, 2);
        assert_eq!(reg.len(), 4);
        let names: Vec<String> = reg.models().map(String::from).collect();
        for topo in Topology::paper_models() {
            assert!(names.contains(&topo.name), "missing {}", topo.name);
            let mut gen = TelemetryGen::new(topo.features, 3);
            let r = reg.score_blocking(&topo.name, gen.benign_window(6)).unwrap();
            assert!(r.score.is_finite() && r.score >= 0.0);
        }
        let report = reg.fleet_report();
        assert!(report.contains("LSTM-AE-F64-D6"), "{report}");
        assert!(report.contains("4 lanes"), "{report}");
        reg.shutdown();
    }
}
