//! The shard router: N remote shards composed into one fleet-wide
//! `submit(model, window)` surface.
//!
//! The router owns one [`ShardClient`] per shard process and routes each
//! submission in two steps:
//!
//! 1. **Static map** — which shards serve this model at all (by default
//!    every shard serves every model, the `fleet serve` deployment; a
//!    custom map pins models to shard subsets).
//! 2. **Power-of-two choices** — among the live shards serving the
//!    model, pick two at random and submit to the one with fewer
//!    requests in flight. Classic load balancing: nearly the quality of
//!    join-shortest-queue at the cost of two counter reads, and robust
//!    to the stale-load herding a pure least-loaded pick suffers.
//!
//! **Backpressure** crosses the wire unchanged: a shard lane's shed
//! arrives as a `Shed` frame and resolves the ticket to
//! `Err(`[`SubmitError::Overloaded`]`)` — the same signal, one hop out.
//!
//! **Failover**: a dead shard (connection EOF, write failure) is sticky
//! — its client fails fast and the router routes around it, counting
//! every avoided/re-issued submission in
//! [`ServerMetrics::shard_failovers`]. Tickets that were in flight on
//! the dead connection resolve `Err(Closed)` (never hang); the
//! closed-loop drivers re-offer those, so a shard death loses zero
//! tickets end to end (`tests/integration_shard.rs` pins that down).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::model::Topology;
use crate::net::{ShardClient, WireError};
use crate::util::rng::SplitMix64;
use crate::workload::Window;

use super::{ServerMetrics, SubmitError, SubmitSurface, Ticket};

/// Client-side router over N shard connections, implementing
/// [`SubmitSurface`] so every driver that runs against a local
/// [`super::ModelRegistry`] runs unchanged against a remote fleet.
pub struct ShardRouter {
    shards: Vec<Arc<ShardClient>>,
    /// Canonical model name → indices into `shards`. Empty means every
    /// shard serves every model.
    map: BTreeMap<String, Vec<usize>>,
    metrics: Arc<ServerMetrics>,
    /// Counter feeding the SplitMix64 draw behind each power-of-two pick
    /// (cheap, lock-free, deterministic per submission index).
    picks: AtomicU64,
}

impl ShardRouter {
    /// Connect to every address (comma-split lists come from the
    /// `fleet connect --shards` flag) with every shard serving every
    /// model. Fails if any connection or handshake fails — a fleet that
    /// starts degraded is a config error, unlike one that degrades later.
    pub fn connect<S: AsRef<str>>(addrs: &[S]) -> Result<ShardRouter, WireError> {
        let mut shards = Vec::with_capacity(addrs.len());
        for a in addrs {
            shards.push(Arc::new(ShardClient::connect(a.as_ref())?));
        }
        Ok(Self::over(shards, BTreeMap::new()))
    }

    /// A router over already-connected clients with an explicit
    /// model → shard-subset map (empty = all shards serve all models).
    /// Map keys should be canonical topology names; lookups fall back
    /// through [`Topology::from_name`] like the registry's do.
    pub fn over(shards: Vec<Arc<ShardClient>>, map: BTreeMap<String, Vec<usize>>) -> ShardRouter {
        assert!(!shards.is_empty(), "a shard router needs at least one shard");
        for idxs in map.values() {
            assert!(idxs.iter().all(|&i| i < shards.len()), "shard index out of range");
        }
        ShardRouter {
            shards,
            map,
            metrics: Arc::new(ServerMetrics::new()),
            picks: AtomicU64::new(0),
        }
    }

    /// Shards this router was built over (dead ones included).
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Shards whose connection is still up.
    pub fn live_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.is_alive()).count()
    }

    /// The shard client at `index` (router construction order).
    pub fn shard(&self, index: usize) -> &ShardClient {
        &self.shards[index]
    }

    /// Router-level metrics: `submitted` counts accepted submissions,
    /// `shard_failovers` counts submissions that had to route around (or
    /// re-issue after) a dead shard.
    pub fn metrics(&self) -> &ServerMetrics {
        &self.metrics
    }

    /// Shard indices statically mapped to `model` (before liveness).
    fn candidates(&self, model: &str) -> Vec<usize> {
        if self.map.is_empty() {
            return (0..self.shards.len()).collect();
        }
        if let Some(idxs) = self.map.get(model) {
            return idxs.clone();
        }
        match Topology::from_name(model) {
            Ok(t) => self.map.get(&t.name).cloned().unwrap_or_default(),
            Err(_) => Vec::new(),
        }
    }

    /// Power-of-two-choices pick among `live` (indices into `shards`):
    /// draw two distinct candidates, submit to the lighter-loaded one.
    fn pick(&self, live: &[usize]) -> usize {
        if live.len() == 1 {
            return live[0];
        }
        let mut rng = SplitMix64::new(self.picks.fetch_add(1, Ordering::Relaxed));
        let a = live[(rng.next_u64() % live.len() as u64) as usize];
        let mut b = live[(rng.next_u64() % (live.len() - 1) as u64) as usize];
        if b == a {
            b = live[live.len() - 1];
        }
        if self.shards[a].inflight() <= self.shards[b].inflight() {
            a
        } else {
            b
        }
    }

    /// Fleet reports of every live shard, concatenated (each shard rolls
    /// up its own lanes; the router has no global view by design).
    pub fn fleet_report(&self) -> String {
        let mut out = String::new();
        for shard in &self.shards {
            match shard.fleet_report(Duration::from_secs(5)) {
                Ok(text) => {
                    out.push_str(&format!("shard {}:\n{text}", shard.addr()));
                }
                Err(_) => out.push_str(&format!("shard {}: unreachable\n", shard.addr())),
            }
        }
        out
    }

    /// Close every shard connection (in-flight tickets resolve
    /// `Err(Closed)`). Idempotent.
    pub fn shutdown(&self) {
        for shard in &self.shards {
            shard.shutdown();
        }
    }
}

impl SubmitSurface for ShardRouter {
    /// Route a submission: static map → live filter (dead shards are
    /// skipped and counted as failovers) → power-of-two pick → submit,
    /// falling through the remaining live shards if the picked
    /// connection dies under the write. `Err(Closed)` only when every
    /// shard serving the model is dead; `Err(UnknownModel)` when the
    /// static map serves it nowhere.
    fn submit_async(&self, model: &str, window: Window) -> Result<Ticket, SubmitError> {
        let cands = self.candidates(model);
        if cands.is_empty() {
            return Err(SubmitError::UnknownModel(model.to_string()));
        }
        let live: Vec<usize> =
            cands.iter().copied().filter(|&i| self.shards[i].is_alive()).collect();
        if live.is_empty() {
            return Err(SubmitError::Closed);
        }
        if live.len() < cands.len() {
            // Routed around at least one dead shard.
            self.metrics.on_shard_failover();
        }
        let first = self.pick(&live);
        let mut order = vec![first];
        order.extend(live.iter().copied().filter(|&i| i != first));
        for (attempt, &i) in order.iter().enumerate() {
            if attempt > 0 {
                // The previous pick died under us: re-issue elsewhere.
                self.metrics.on_shard_failover();
            }
            // The client serializes straight off the borrow, so routing
            // (and failover retries) never deep-copy the T×F samples.
            match self.shards[i].submit_async(model, &window) {
                Ok(ticket) => {
                    self.metrics.on_submit();
                    return Ok(ticket);
                }
                // Connection death: try the next live shard.
                Err(SubmitError::Closed) => continue,
                // Per-request verdicts (e.g. TooLarge) are terminal —
                // every shard would answer the same, and retrying them
                // would fabricate failovers on healthy connections.
                Err(e) => return Err(e),
            }
        }
        Err(SubmitError::Closed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Socket-free routing tests live here; the full loopback behaviour
    // (bit-identity, failover under a killed shard) is pinned by
    // `tests/integration_shard.rs`.

    #[test]
    fn candidates_honor_static_map_with_canonical_fallback() {
        // An empty registry is fine: these connections only handshake.
        let reg = Arc::new(crate::server::ModelRegistry::new());
        let srv_a = crate::net::ShardServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let srv_b = crate::net::ShardServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let ca = Arc::new(ShardClient::connect(&srv_a.local_addr().to_string()).unwrap());
        let cb = Arc::new(ShardClient::connect(&srv_b.local_addr().to_string()).unwrap());
        let map = BTreeMap::from([
            ("LSTM-AE-F32-D2".to_string(), vec![0]),
            ("LSTM-AE-F64-D6".to_string(), vec![0, 1]),
        ]);
        let router = ShardRouter::over(vec![ca, cb], map);
        assert_eq!(router.candidates("LSTM-AE-F32-D2"), vec![0]);
        // Short name falls back to the canonical topology name.
        assert_eq!(router.candidates("F64-D6"), vec![0, 1]);
        assert!(router.candidates("no-such-model").is_empty());
        // An unmapped model routes nowhere: UnknownModel, not a panic.
        let w = crate::workload::Window { data: vec![vec![0.0]], anomaly: None };
        assert!(matches!(
            router.submit_async("no-such-model", w),
            Err(SubmitError::UnknownModel(_))
        ));
        router.shutdown();
        srv_a.shutdown();
        srv_b.shutdown();
    }

    #[test]
    fn pick_prefers_the_lighter_shard_and_stays_in_range() {
        let reg = Arc::new(crate::server::ModelRegistry::new());
        let srv = crate::net::ShardServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let addr = srv.local_addr().to_string();
        let shards: Vec<Arc<ShardClient>> =
            (0..3).map(|_| Arc::new(ShardClient::connect(&addr).unwrap())).collect();
        let router = ShardRouter::over(shards, BTreeMap::new());
        let live: Vec<usize> = vec![0, 1, 2];
        for _ in 0..200 {
            let p = router.pick(&live);
            assert!(p < 3);
        }
        assert_eq!(router.pick(&[2]), 2, "singleton pick is the shard itself");
        router.shutdown();
        srv.shutdown();
    }
}
