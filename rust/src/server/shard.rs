//! The shard router: N remote shards composed into one fleet-wide
//! `submit(model, window)` surface, with a live control plane.
//!
//! The router owns one slot per shard address. A slot holds the current
//! [`ShardClient`] connection (if any) plus the shard's membership
//! state, and routes each submission in two steps:
//!
//! 1. **Static map** — which shards serve this model at all (by default
//!    every shard serves every model, the `fleet serve` deployment; a
//!    custom map pins models to shard subsets).
//! 2. **Power-of-two choices** — among the routable shards serving the
//!    model, draw two distinct candidates and submit to the
//!    healthier-looking one. Classic load balancing: nearly the quality
//!    of join-shortest-queue at the cost of two reads, and robust to the
//!    stale-load herding a pure least-loaded pick suffers. When both
//!    candidates have heartbeat samples the compare is health-weighted —
//!    expected drain time `(backlog + 1) × p99 EWMA` — otherwise it
//!    falls back to raw local in-flight counts, so a shard that just
//!    joined (no samples yet) is never scored zero and flooded.
//!
//! **Backpressure** crosses the wire unchanged: a shard lane's shed
//! arrives as a `Shed` frame and resolves the ticket to
//! `Err(`[`SubmitError::Overloaded`]`)` — the same signal, one hop out.
//!
//! # Control plane
//!
//! A health thread ticks every [`RouterConfig::heartbeat_ms`] and walks
//! the fleet, driving each slot through the membership state machine:
//!
//! ```text
//!          fresh heartbeat                    missed ≥ suspect_after
//!   ┌─────────────────────── Suspect ◄──────────────────────────┐
//!   ▼                          │ missed ≥ dead_after            │
//! Live ──────────────────────► │ (or the connection died)     Live
//!   │  Leave frame             ▼                                ▲
//!   ▼                        Dead ────► Reconnecting ───────────┘
//! Draining ──── in-flight=0 ───┘  backoff   dial ok: fresh client,
//!              (clean close)      capped,   new generation
//!                                 jittered
//! ```
//!
//! - Each tick sends one `HealthProbe` per connected shard; the shard
//!   answers with a `Heartbeat` carrying its in-flight count, shed
//!   delta, and p50/p99 latency EWMAs. Fresh replies reset the miss
//!   counter and feed the routing EWMAs; silence accumulates misses.
//! - **Suspect** shards take no new work but nothing is poisoned — a
//!   slow-but-alive shard re-promotes on its next fresh heartbeat, and
//!   every response it produced while Suspect still counts. If no Live
//!   shard serves a model, Suspect ones are used as a last resort.
//! - **Dead** demotions close the connection, poisoning in-flight
//!   tickets with `Err(Closed)` — the no-hanging-tickets invariant; the
//!   closed-loop drivers re-offer those, so a death loses zero tickets.
//! - Dead slots are redialed with capped exponential backoff + jitter;
//!   a restarted process rejoins with zero operator action (the rejoin
//!   is observable: `shard_reconnects` metrics tick and the slot's
//!   generation bumps).
//! - A shard announcing `Leave` drains gracefully: no new work, its
//!   in-flight tickets complete, then the connection closes cleanly.
//!
//! Membership is dynamic the other way too: [`ShardRouter::add_shard`]
//! admits a new shard into a running fleet.
//!
//! # Sticky session routing
//!
//! Streaming sessions (the stream half of [`super::ServingSurface`])
//! carry per-session LSTM
//! state *on the shard*, so unlike windows they cannot hop shards per
//! sample. [`ShardRouter::open_stream`] picks a home shard with the same
//! health-weighted pair draw and records `session → (slot, generation)`;
//! every [`ShardRouter::submit_sample`] goes to that home while it stays
//! routable on the same process generation. When the home dies (or came
//! back as a new process — the generation bump), the router re-opens the
//! session on a fresh shard and retries there: the carried state is
//! **reset to zero** — the first scores after failover are what a brand
//! new session would produce — and the `stream_resets` counter ticks so
//! the loss of history is observable, not silent.
//!
//! # Why routing is not cache-aware
//!
//! Shards may run per-lane score caches (`--cache-entries`), and one
//! could imagine key-affinity routing — hash the window, pin it to a
//! shard — to concentrate hits. The router deliberately does **not** do
//! this. Key affinity fights both pillars above: it overrides the
//! power-of-two health-weighted choice (a hot key would keep hammering
//! its home shard no matter how backlogged), and it breaks down exactly
//! when the control plane matters most — on suspect/dead demotion the
//! affinity map would need rehashing, turning every failover into a
//! fleet-wide cache invalidation. Instead caches live server-side, one
//! per lane: each shard warms independently, a repeat-heavy trace still
//! hits on every shard it lands on (duplicating some resident bytes,
//! bounded by `--cache-bytes`), and routing stays a pure load/health
//! decision that keeps working unchanged through failover and rejoin.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::model::Topology;
use crate::net::{ShardClient, WireError};
use crate::util::rng::SplitMix64;
use crate::workload::Window;

use super::{ServerMetrics, ServingSurface, SubmitError, Ticket};

/// First redial delay after a shard dies; doubles per failed attempt up
/// to [`RouterConfig::reconnect_max_backoff_ms`].
const RECONNECT_INITIAL_BACKOFF_MS: u64 = 100;

/// Smoothing factor for the router-side heartbeat EWMAs (in-flight and
/// p99) behind the health-weighted pick.
const HEALTH_EWMA_ALPHA: f64 = 0.3;

/// A shard slot's membership state, as driven by the health tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardState {
    /// Connected and answering probes: full routing weight.
    Live = 0,
    /// Missed probes past [`RouterConfig::suspect_after`]: no new work
    /// (unless no Live shard serves the model), nothing poisoned —
    /// re-promotes on the next fresh heartbeat.
    Suspect = 1,
    /// Announced `Leave`: no new work; the connection closes cleanly
    /// once its in-flight count reaches zero.
    Draining = 2,
    /// Connection closed (death or drain completion); in-flight tickets
    /// were poisoned `Err(Closed)` on the death path. Awaiting redial.
    Dead = 3,
    /// A redial is in flight right now.
    Reconnecting = 4,
}

impl ShardState {
    fn from_u8(v: u8) -> ShardState {
        match v {
            0 => ShardState::Live,
            1 => ShardState::Suspect,
            2 => ShardState::Draining,
            4 => ShardState::Reconnecting,
            _ => ShardState::Dead,
        }
    }
}

impl std::fmt::Display for ShardState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ShardState::Live => "live",
            ShardState::Suspect => "suspect",
            ShardState::Draining => "draining",
            ShardState::Dead => "dead",
            ShardState::Reconnecting => "reconnecting",
        })
    }
}

/// Health/reconnect tuning for a [`ShardRouter`]. The defaults detect a
/// silent shard in ~1.5 s (6 × 250 ms) and redial from 100 ms up to 5 s.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Health-tick period in ms: one probe per connected shard per tick.
    pub heartbeat_ms: u64,
    /// Consecutive missed probes before Live→Suspect.
    pub suspect_after: u32,
    /// Consecutive missed probes before demotion to Dead.
    pub dead_after: u32,
    /// Cap on the exponential redial backoff, ms.
    pub reconnect_max_backoff_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            heartbeat_ms: 250,
            suspect_after: 3,
            dead_after: 6,
            reconnect_max_backoff_ms: 5_000,
        }
    }
}

impl RouterConfig {
    /// Start a [`RouterConfigBuilder`] from the defaults. Prefer this
    /// over struct literals: the builder validates the cross-field
    /// invariants (`suspect_after <= dead_after`, nonzero periods) at
    /// [`RouterConfigBuilder::build`] instead of panicking later inside
    /// [`ShardRouter::over_with`].
    pub fn builder() -> RouterConfigBuilder {
        RouterConfigBuilder { cfg: RouterConfig::default() }
    }
}

/// Typed builder for [`RouterConfig`] — see [`RouterConfig::builder`].
///
/// ```
/// use lstm_ae_accel::server::RouterConfig;
/// let cfg = RouterConfig::builder().heartbeat_ms(50).suspect_after(2).dead_after(4).build();
/// assert_eq!(cfg.dead_after, 4);
/// ```
#[derive(Clone, Debug)]
pub struct RouterConfigBuilder {
    cfg: RouterConfig,
}

impl RouterConfigBuilder {
    /// Health-tick period in ms (must stay ≥ 1).
    pub fn heartbeat_ms(mut self, ms: u64) -> Self {
        self.cfg.heartbeat_ms = ms;
        self
    }

    /// Consecutive missed probes before Live→Suspect (≥ 1, ≤ dead_after).
    pub fn suspect_after(mut self, n: u32) -> Self {
        self.cfg.suspect_after = n;
        self
    }

    /// Consecutive missed probes before demotion to Dead.
    pub fn dead_after(mut self, n: u32) -> Self {
        self.cfg.dead_after = n;
        self
    }

    /// Cap on the exponential redial backoff, ms (must stay ≥ 1).
    pub fn reconnect_max_backoff_ms(mut self, ms: u64) -> Self {
        self.cfg.reconnect_max_backoff_ms = ms;
        self
    }

    /// Validate and produce the [`RouterConfig`].
    ///
    /// Panics on configurations the health loop cannot run: a zero
    /// heartbeat period or backoff cap, `suspect_after == 0`, or
    /// `suspect_after > dead_after`.
    pub fn build(self) -> RouterConfig {
        assert!(self.cfg.heartbeat_ms >= 1, "RouterConfig: heartbeat_ms must be >= 1");
        assert!(
            1 <= self.cfg.suspect_after && self.cfg.suspect_after <= self.cfg.dead_after,
            "RouterConfig: need 1 <= suspect_after <= dead_after"
        );
        assert!(
            self.cfg.reconnect_max_backoff_ms >= 1,
            "RouterConfig: reconnect_max_backoff_ms must be >= 1"
        );
        self.cfg
    }
}

/// A fleet-wide signal snapshot from [`ShardRouter::fleet_sample`]: the
/// inputs the fleet-tier autoscaler's `decide()` works from, aggregated
/// over non-retired slots.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetSample {
    /// Shards currently Live with an open connection.
    pub live: usize,
    /// Cumulative fleet-wide shed count folded from heartbeats
    /// (monotone; the scaler differences consecutive samples).
    pub shed_total: u64,
    /// This router's in-flight submissions summed across the fleet.
    pub inflight: u64,
    /// Worst per-shard p99 EWMA across live shards, µs (0 until any
    /// shard has a heartbeat sample).
    pub p99_us: f64,
}

/// Mutable per-slot health bookkeeping, guarded by one mutex. Lock
/// order: a holder of this lock may take the slot's `client` lock, never
/// the reverse (the submit path takes only `client`; the reader threads
/// take neither).
struct SlotCtl {
    /// Ticks since the last fresh heartbeat.
    missed: u32,
    /// Last probe sequence sent on the current connection.
    probe_seq: u64,
    /// Highest heartbeat sequence consumed on the current connection.
    seen_seq: u64,
    /// Bumped on every successful reconnect — "same addr, new process".
    generation: u64,
    /// Failed redials since the shard died (monotone per outage).
    attempts: u64,
    /// Next redial delay, ms (doubles per failure, capped).
    backoff_ms: u64,
    /// Redial not before this instant; `None` means due immediately.
    next_attempt: Option<Instant>,
}

impl SlotCtl {
    fn new() -> SlotCtl {
        SlotCtl {
            missed: 0,
            probe_seq: 0,
            seen_seq: 0,
            generation: 0,
            attempts: 0,
            backoff_ms: RECONNECT_INITIAL_BACKOFF_MS,
            next_attempt: None,
        }
    }
}

/// One shard address's slot in the registry: the current connection (if
/// any), the published membership state, and lock-free EWMA mirrors for
/// the hot routing path.
struct ShardSlot {
    addr: String,
    /// Published [`ShardState`]; transitions happen under `ctl`, reads
    /// are lock-free.
    state: AtomicU8,
    /// Intentionally retired (the fleet autoscaler drained this shard and
    /// will reap its process): once Dead, the health tick must NOT redial
    /// it — the address is gone for good, not recovering.
    retired: AtomicBool,
    /// f64 bits; NaN = no heartbeat sample yet on this connection.
    inflight_ewma: AtomicU64,
    p99_ewma: AtomicU64,
    /// The live connection. `None` while Dead/Reconnecting.
    client: RwLock<Option<Arc<ShardClient>>>,
    ctl: Mutex<SlotCtl>,
}

impl ShardSlot {
    fn new(addr: String, client: Arc<ShardClient>) -> ShardSlot {
        ShardSlot {
            addr,
            state: AtomicU8::new(ShardState::Live as u8),
            retired: AtomicBool::new(false),
            inflight_ewma: AtomicU64::new(f64::NAN.to_bits()),
            p99_ewma: AtomicU64::new(f64::NAN.to_bits()),
            client: RwLock::new(Some(client)),
            ctl: Mutex::new(SlotCtl::new()),
        }
    }

    fn state(&self) -> ShardState {
        ShardState::from_u8(self.state.load(Ordering::Acquire))
    }

    fn set_state(&self, s: ShardState) {
        self.state.store(s as u8, Ordering::Release);
    }

    fn client(&self) -> Option<Arc<ShardClient>> {
        self.client.read().unwrap().clone()
    }

    fn client_alive(&self) -> bool {
        self.client.read().unwrap().as_ref().is_some_and(|c| c.is_alive())
    }

    fn local_inflight(&self) -> usize {
        self.client.read().unwrap().as_ref().map_or(0, |c| c.inflight())
    }

    /// Heartbeat-fed EWMAs, or `None` until this connection has a usable
    /// sample (p99 must be positive: a shard that never completed
    /// anything reports 0, which would score it "free" and flood it).
    fn ewmas(&self) -> Option<(f64, f64)> {
        let inf = f64::from_bits(self.inflight_ewma.load(Ordering::Relaxed));
        let p99 = f64::from_bits(self.p99_ewma.load(Ordering::Relaxed));
        if inf.is_finite() && p99.is_finite() && p99 > 0.0 {
            Some((inf, p99))
        } else {
            None
        }
    }

    /// Fold one heartbeat into the EWMAs (first sample seeds). Single
    /// writer — the health thread — so load/store pairs don't race.
    fn fold_ewmas(&self, inflight: f64, p99_us: f64) {
        let fold = |cell: &AtomicU64, x: f64| {
            let prev = f64::from_bits(cell.load(Ordering::Relaxed));
            let next =
                if prev.is_finite() { prev + HEALTH_EWMA_ALPHA * (x - prev) } else { x };
            cell.store(next.to_bits(), Ordering::Relaxed);
        };
        fold(&self.inflight_ewma, inflight);
        fold(&self.p99_ewma, p99_us);
    }

    fn clear_ewmas(&self) {
        self.inflight_ewma.store(f64::NAN.to_bits(), Ordering::Relaxed);
        self.p99_ewma.store(f64::NAN.to_bits(), Ordering::Relaxed);
    }
}

/// State shared between the router, its health thread, and redial
/// threads.
struct RouterShared {
    /// Grow-only: slots keep their index for the static map's lifetime.
    slots: RwLock<Vec<Arc<ShardSlot>>>,
    metrics: Arc<ServerMetrics>,
    cfg: RouterConfig,
    /// Cumulative fleet-wide shed count, folded from every fresh
    /// heartbeat's `shed_delta` by the health tick — the pressure signal
    /// the fleet-tier autoscaler samples (it differences consecutive
    /// reads itself, like the per-lane tracks do).
    fleet_shed: AtomicU64,
    stop: Mutex<bool>,
    tick: Condvar,
}

impl RouterShared {
    fn is_stopping(&self) -> bool {
        *self.stop.lock().unwrap()
    }
}

/// Model candidates for one submission: either "every shard" (the empty
/// static map) or a borrowed index slice — nothing allocated either way.
enum Cands<'a> {
    All(usize),
    Slice(&'a [usize]),
}

impl Cands<'_> {
    fn len(&self) -> usize {
        match self {
            Cands::All(n) => *n,
            Cands::Slice(s) => s.len(),
        }
    }

    fn get(&self, k: usize) -> usize {
        match self {
            Cands::All(_) => k,
            Cands::Slice(s) => s[k],
        }
    }
}

/// Draw two distinct ordinals in `0..n` without bias: `a` uniform, `b`
/// a uniform *offset* from `a` — every ordered pair with `a != b` is
/// equally likely (the naive "redraw over `n-1` and patch collisions"
/// under-selects the last element).
fn draw_pair(seed: u64, n: usize) -> (usize, usize) {
    debug_assert!(n >= 2);
    let mut rng = SplitMix64::new(seed);
    let a = (rng.next_u64() % n as u64) as usize;
    let b = (a + 1 + (rng.next_u64() % (n as u64 - 1)) as usize) % n;
    (a, b)
}

/// Client-side registry/router over N shard slots, implementing
/// [`ServingSurface`] so every driver that runs against a local
/// [`super::ModelRegistry`] runs unchanged against a remote fleet.
pub struct ShardRouter {
    shared: Arc<RouterShared>,
    /// Canonical model name → indices into the slot vector. Empty means
    /// every shard serves every model.
    map: BTreeMap<String, Vec<usize>>,
    /// Counter feeding the SplitMix64 draw behind each power-of-two pick
    /// (cheap, lock-free, deterministic per submission index).
    picks: AtomicU64,
    /// Sticky session routes: `(model, stream) → home shard`. Samples
    /// follow the route while its slot stays routable on the recorded
    /// process generation; failover re-opens elsewhere (state reset).
    streams: Mutex<HashMap<(String, u64), StreamRoute>>,
    health: Mutex<Option<JoinHandle<()>>>,
}

/// Where one streaming session lives and how it was opened.
#[derive(Clone, Copy)]
struct StreamRoute {
    /// Slot index of the session's home shard.
    slot: usize,
    /// The slot's reconnect generation at open time: a later bump means
    /// "same address, new process" — the session state is gone there.
    generation: u64,
    /// Requested score window, replayed verbatim on failover re-opens.
    window: u32,
}

impl ShardRouter {
    /// Connect to every address (comma-split lists come from the
    /// `fleet connect --shards` flag) with every shard serving every
    /// model and default health tuning. Fails if any connection or
    /// handshake fails — a fleet that *starts* degraded is a config
    /// error, unlike one that degrades later.
    pub fn connect<S: AsRef<str>>(addrs: &[S]) -> Result<ShardRouter, WireError> {
        Self::connect_with(addrs, RouterConfig::default())
    }

    /// [`Self::connect`] with explicit health/reconnect tuning.
    pub fn connect_with<S: AsRef<str>>(
        addrs: &[S],
        cfg: RouterConfig,
    ) -> Result<ShardRouter, WireError> {
        let mut shards = Vec::with_capacity(addrs.len());
        for a in addrs {
            shards.push(Arc::new(ShardClient::connect(a.as_ref())?));
        }
        Ok(Self::over_with(shards, BTreeMap::new(), cfg))
    }

    /// A router over already-connected clients with an explicit
    /// model → shard-subset map (empty = all shards serve all models).
    /// Map keys should be canonical topology names; lookups fall back
    /// through [`Topology::from_name`] like the registry's do.
    pub fn over(shards: Vec<Arc<ShardClient>>, map: BTreeMap<String, Vec<usize>>) -> ShardRouter {
        Self::over_with(shards, map, RouterConfig::default())
    }

    /// [`Self::over`] with explicit health/reconnect tuning.
    pub fn over_with(
        shards: Vec<Arc<ShardClient>>,
        map: BTreeMap<String, Vec<usize>>,
        cfg: RouterConfig,
    ) -> ShardRouter {
        assert!(!shards.is_empty(), "a shard router needs at least one shard");
        assert!(cfg.heartbeat_ms >= 1, "heartbeat period must be nonzero");
        assert!(
            1 <= cfg.suspect_after && cfg.suspect_after <= cfg.dead_after,
            "need 1 <= suspect_after <= dead_after"
        );
        for idxs in map.values() {
            assert!(idxs.iter().all(|&i| i < shards.len()), "shard index out of range");
        }
        let slots: Vec<Arc<ShardSlot>> = shards
            .into_iter()
            .map(|c| Arc::new(ShardSlot::new(c.addr().to_string(), c)))
            .collect();
        let shared = Arc::new(RouterShared {
            slots: RwLock::new(slots),
            metrics: Arc::new(ServerMetrics::new()),
            cfg,
            fleet_shed: AtomicU64::new(0),
            stop: Mutex::new(false),
            tick: Condvar::new(),
        });
        let health = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("shard-health".to_string())
                .spawn(move || health_loop(shared))
                .expect("spawn health loop")
        };
        ShardRouter {
            shared,
            map,
            picks: AtomicU64::new(0),
            streams: Mutex::new(HashMap::new()),
            health: Mutex::new(Some(health)),
        }
    }

    /// Admit a new shard into the running fleet: dial it, handshake, and
    /// append a Live slot — submissions can route to it immediately.
    /// Returns the new slot's index. Only valid with the empty static
    /// map (every shard serves every model); a pinned map names slot
    /// indices, which a post-hoc join can't extend coherently.
    ///
    /// Idempotent: an address that already holds a Live, Suspect, or
    /// Reconnecting slot returns that slot's index without dialing — a
    /// duplicate slot would double-route and double-count heartbeats
    /// against one process. (A Dead or Draining slot does *not* absorb
    /// the re-add: its redial owns that address's recovery.)
    pub fn add_shard(&self, addr: &str) -> Result<usize, WireError> {
        assert!(
            self.map.is_empty(),
            "add_shard requires the every-shard-serves-every-model map"
        );
        let existing = |slots: &[Arc<ShardSlot>]| {
            slots.iter().position(|s| {
                s.addr == addr
                    && matches!(
                        s.state(),
                        ShardState::Live | ShardState::Suspect | ShardState::Reconnecting
                    )
            })
        };
        if let Some(i) = existing(&self.shared.slots.read().unwrap()) {
            return Ok(i);
        }
        let client = Arc::new(ShardClient::connect(addr)?);
        let mut slots = self.shared.slots.write().unwrap();
        // Re-check under the write lock: a concurrent add_shard may have
        // admitted the address between our read scan and the dial.
        if let Some(i) = existing(&slots) {
            client.shutdown();
            return Ok(i);
        }
        slots.push(Arc::new(ShardSlot::new(addr.to_string(), client)));
        Ok(slots.len() - 1)
    }

    /// Drain and permanently retire the slot at `index` (the fleet
    /// autoscaler's scale-down hook): sends the drain request over the
    /// wire — the shard broadcasts `Leave`, the health tick demotes the
    /// slot to Draining, and once its in-flight count reaches zero the
    /// connection closes and the slot lands Dead — and marks the slot
    /// retired so the health tick never redials the intentionally-gone
    /// address. In-flight tickets complete normally; zero are lost.
    pub fn retire_shard(&self, index: usize) -> Result<(), SubmitError> {
        let slots = self.shared.slots.read().unwrap();
        let slot = slots.get(index).ok_or(SubmitError::Closed)?;
        slot.retired.store(true, Ordering::Release);
        let client = slot.client().ok_or(SubmitError::Closed)?;
        client.request_leave("retired by fleet autoscaler")
    }

    /// Whether the slot at `index` was retired by [`Self::retire_shard`]
    /// (the health tick stops redialing it once Dead).
    pub fn shard_retired(&self, index: usize) -> bool {
        self.shared.slots.read().unwrap()[index].retired.load(Ordering::Acquire)
    }

    /// One fleet-wide signal sample for the fleet-tier autoscaler:
    /// aggregates over non-retired slots only, so a draining shard's
    /// tail never argues for more capacity.
    pub fn fleet_sample(&self) -> FleetSample {
        let slots = self.shared.slots.read().unwrap();
        let mut s = FleetSample::default();
        for slot in slots.iter() {
            if slot.retired.load(Ordering::Acquire) {
                continue;
            }
            if slot.state() == ShardState::Live && slot.client_alive() {
                s.live += 1;
            }
            s.inflight += slot.local_inflight() as u64;
            if let Some((_, p99)) = slot.ewmas() {
                s.p99_us = s.p99_us.max(p99);
            }
        }
        s.shed_total = self.shared.fleet_shed.load(Ordering::Relaxed);
        s
    }

    /// Shard slots this router manages (dead ones included).
    pub fn len(&self) -> usize {
        self.shared.slots.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Shards currently Live with an open connection.
    pub fn live_shards(&self) -> usize {
        self.shared
            .slots
            .read()
            .unwrap()
            .iter()
            .filter(|s| s.state() == ShardState::Live && s.client_alive())
            .count()
    }

    /// The membership state of the slot at `index`.
    pub fn shard_state(&self, index: usize) -> ShardState {
        self.shared.slots.read().unwrap()[index].state()
    }

    /// The address the slot at `index` dials.
    pub fn shard_addr(&self, index: usize) -> String {
        self.shared.slots.read().unwrap()[index].addr.clone()
    }

    /// Our in-flight submissions on the slot at `index` (0 when down).
    pub fn shard_inflight(&self, index: usize) -> usize {
        self.shared.slots.read().unwrap()[index].local_inflight()
    }

    /// The slot's current connection, if it has one. Each successful
    /// reconnect installs a *new* client — hold the `Arc` only briefly.
    pub fn shard_client(&self, index: usize) -> Option<Arc<ShardClient>> {
        self.shared.slots.read().unwrap()[index].client()
    }

    /// How many times the slot at `index` has successfully reconnected
    /// ("same addr, new process" — rejoin made observable).
    pub fn shard_generation(&self, index: usize) -> u64 {
        self.shared.slots.read().unwrap()[index].ctl.lock().unwrap().generation
    }

    /// Router-level metrics: `submitted` counts accepted submissions,
    /// `shard_failovers` counts submissions that had to route around (or
    /// re-issue after) an unroutable shard, and the control-plane block
    /// (`health_probes`, `heartbeats`, `shard_suspects`, `shard_deaths`,
    /// `shard_reconnects`/`..._attempts`, membership gauges) makes the
    /// health loop observable.
    pub fn metrics(&self) -> &ServerMetrics {
        &self.shared.metrics
    }

    /// Shard indices statically mapped to `model` (before liveness);
    /// `n` is the current slot count. Borrow-only: the hot path never
    /// clones the map's index vectors.
    fn candidates(&self, model: &str, n: usize) -> Cands<'_> {
        const EMPTY: &[usize] = &[];
        if self.map.is_empty() {
            return Cands::All(n);
        }
        if let Some(idxs) = self.map.get(model) {
            return Cands::Slice(idxs);
        }
        match Topology::from_name(model) {
            Ok(t) => Cands::Slice(self.map.get(&t.name).map_or(EMPTY, Vec::as_slice)),
            Err(_) => Cands::Slice(EMPTY),
        }
    }

    /// The healthier of two slots: expected drain time (`(backlog + 1) ×
    /// p99 EWMA`) when both have heartbeat samples, raw local in-flight
    /// otherwise. Backlog is the max of our local count and the shard's
    /// own reported EWMA — the shard may be loaded by *other* routers.
    fn lighter(&self, slots: &[Arc<ShardSlot>], a: usize, b: usize) -> usize {
        let (sa, sb) = (&slots[a], &slots[b]);
        let (la, lb) = (sa.local_inflight(), sb.local_inflight());
        match (sa.ewmas(), sb.ewmas()) {
            (Some((ia, pa)), Some((ib, pb))) => {
                let ca = ((la as f64).max(ia) + 1.0) * pa;
                let cb = ((lb as f64).max(ib) + 1.0) * pb;
                if ca <= cb {
                    a
                } else {
                    b
                }
            }
            _ => {
                if la <= lb {
                    a
                } else {
                    b
                }
            }
        }
    }

    /// One routable slot index for `model`: Live candidates first
    /// (Suspect as a last resort), health-weighted pair draw among them.
    /// The session-open path's pick — rare enough that collecting the
    /// pool allocates, unlike the allocation-free window hot path.
    fn pick_routable(&self, slots: &[Arc<ShardSlot>], model: &str) -> Result<usize, SubmitError> {
        let cands = self.candidates(model, slots.len());
        let total = cands.len();
        if total == 0 {
            return Err(SubmitError::UnknownModel(model.to_string()));
        }
        let mut live: Vec<usize> = Vec::new();
        let mut suspect: Vec<usize> = Vec::new();
        for k in 0..total {
            let i = cands.get(k);
            if !slots[i].client_alive() {
                continue;
            }
            match slots[i].state() {
                ShardState::Live => live.push(i),
                ShardState::Suspect => suspect.push(i),
                _ => {}
            }
        }
        let pool = if live.is_empty() { suspect } else { live };
        match pool.len() {
            0 => Err(SubmitError::Closed),
            1 => Ok(pool[0]),
            n => {
                let (a, b) = draw_pair(self.picks.fetch_add(1, Ordering::Relaxed), n);
                Ok(self.lighter(slots, pool[a], pool[b]))
            }
        }
    }

    /// Open streaming session `stream` on `model`: pick a home shard,
    /// open there, and record the sticky route every later
    /// [`Self::submit_sample`] follows. Re-opening an existing session
    /// moves/resets it like a local table re-open would.
    pub fn open_stream(&self, model: &str, stream: u64, window: usize) -> Result<(), SubmitError> {
        let window = u32::try_from(window).map_err(|_| SubmitError::TooLarge)?;
        let slots = self.shared.slots.read().unwrap();
        let idx = self.pick_routable(&slots, model)?;
        let client = slots[idx].client().ok_or(SubmitError::Closed)?;
        client.open_stream(model, stream, window)?;
        let generation = slots[idx].ctl.lock().unwrap().generation;
        self.streams
            .lock()
            .unwrap()
            .insert((model.to_string(), stream), StreamRoute { slot: idx, generation, window });
        Ok(())
    }

    /// Feed one sample to the session's home shard. If the home is no
    /// longer routable on the generation the session was opened under —
    /// it died, or came back as a new process — the session is re-opened
    /// on a fresh shard with **zeroed state** (the documented failover
    /// reset semantic), `stream_resets` ticks, and the sample is scored
    /// there.
    pub fn submit_sample(
        &self,
        model: &str,
        stream: u64,
        sample: Vec<f32>,
    ) -> Result<Ticket, SubmitError> {
        let key = (model.to_string(), stream);
        let Some(mut route) = self.streams.lock().unwrap().get(&key).copied() else {
            return Err(SubmitError::UnknownStream(stream));
        };
        let slots = self.shared.slots.read().unwrap();
        let sticky_ok = route.slot < slots.len() && {
            let slot = &slots[route.slot];
            let st = slot.state();
            (st == ShardState::Live || st == ShardState::Suspect)
                && slot.client_alive()
                && slot.ctl.lock().unwrap().generation == route.generation
        };
        if sticky_ok {
            if let Some(client) = slots[route.slot].client() {
                match client.submit_sample(model, stream, &sample) {
                    // Died under the write: fall through to failover.
                    Err(SubmitError::Closed) => {}
                    Ok(ticket) => {
                        self.shared.metrics.on_submit();
                        return Ok(ticket);
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        let idx = self.pick_routable(&slots, model)?;
        let client = slots[idx].client().ok_or(SubmitError::Closed)?;
        client.open_stream(model, stream, route.window)?;
        self.shared.metrics.on_stream_resets(1);
        self.shared.metrics.on_shard_failover();
        route.slot = idx;
        route.generation = slots[idx].ctl.lock().unwrap().generation;
        self.streams.lock().unwrap().insert(key, route);
        let ticket = client.submit_sample(model, stream, &sample)?;
        self.shared.metrics.on_submit();
        Ok(ticket)
    }

    /// Close a session: drop the sticky route and tell its home shard
    /// (best-effort — a dead home already lost the state).
    pub fn close_stream(&self, model: &str, stream: u64) {
        let route = self.streams.lock().unwrap().remove(&(model.to_string(), stream));
        if let Some(route) = route {
            let slots = self.shared.slots.read().unwrap();
            if route.slot < slots.len() {
                if let Some(client) = slots[route.slot].client() {
                    let _ = client.close_stream(model, stream);
                }
            }
        }
    }

    /// Sessions that lost carried state to failover or shard restarts,
    /// fleet-wide from this router's perspective: its own failover
    /// re-opens plus every live connection's `reset`-flagged scores
    /// (shard-side re-opens). Counts on connections that have since been
    /// replaced are gone, so this is a lower bound across reconnects.
    pub fn stream_resets(&self) -> u64 {
        let local = self.shared.metrics.stream_resets();
        let slots = self.shared.slots.read().unwrap();
        local
            + slots.iter().filter_map(|s| s.client()).map(|c| c.stream_resets()).sum::<u64>()
    }

    /// Fleet reports of every serving shard, queried concurrently (one
    /// scoped thread per shard, so a single hung connection costs its
    /// own 5 s timeout — not 5 s × fleet). Known-dead shards are skipped
    /// outright; every line carries the slot's membership state.
    pub fn fleet_report(&self) -> String {
        let slots = self.shared.slots.read().unwrap();
        let rows: Vec<(String, ShardState, Option<Result<String, SubmitError>>)> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = slots
                    .iter()
                    .map(|slot| {
                        let addr = slot.addr.clone();
                        let state = slot.state();
                        let client = slot.client();
                        scope.spawn(move || {
                            let text = match (state, client) {
                                (ShardState::Dead | ShardState::Reconnecting, _) => None,
                                (_, None) => None,
                                (_, Some(c)) => Some(c.fleet_report(Duration::from_secs(5))),
                            };
                            (addr, state, text)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
        let mut out = String::new();
        for (addr, state, text) in rows {
            match text {
                Some(Ok(t)) => out.push_str(&format!("shard {addr} [{state}]:\n{t}")),
                Some(Err(_)) => {
                    out.push_str(&format!("shard {addr} [{state}]: unreachable\n"));
                }
                None => out.push_str(&format!("shard {addr} [{state}]: down, skipped\n")),
            }
        }
        out
    }

    /// Stop the health thread (joining any in-flight redials), then
    /// close every shard connection (in-flight tickets resolve
    /// `Err(Closed)`). Idempotent.
    pub fn shutdown(&self) {
        *self.shared.stop.lock().unwrap() = true;
        self.shared.tick.notify_all();
        if let Some(h) = self.health.lock().unwrap().take() {
            let _ = h.join();
        }
        for slot in self.shared.slots.read().unwrap().iter() {
            if let Some(client) = slot.client.write().unwrap().take() {
                client.shutdown();
            }
            slot.set_state(ShardState::Dead);
        }
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl ServingSurface for ShardRouter {
    /// Route a submission: static map → routable filter (dead, draining,
    /// and — while any Live candidate exists — suspect shards are
    /// skipped, counted as failovers) → power-of-two pick → submit,
    /// falling through the remaining routable shards if the picked
    /// connection dies under the write. `Err(Closed)` only when nothing
    /// serving the model is routable; `Err(UnknownModel)` when the
    /// static map serves it nowhere. Allocation-free up to the accepted
    /// ticket itself.
    fn submit_async(&self, model: &str, window: Window) -> Result<Ticket, SubmitError> {
        let slots = self.shared.slots.read().unwrap();
        let cands = self.candidates(model, slots.len());
        let total = cands.len();
        if total == 0 {
            return Err(SubmitError::UnknownModel(model.to_string()));
        }
        let (mut n_live, mut n_suspect) = (0usize, 0usize);
        for k in 0..total {
            let slot = &slots[cands.get(k)];
            if !slot.client_alive() {
                continue;
            }
            match slot.state() {
                ShardState::Live => n_live += 1,
                ShardState::Suspect => n_suspect += 1,
                _ => {}
            }
        }
        // Suspect shards are a last resort: routable only when no Live
        // candidate serves the model (graceful degradation beats Closed).
        let (n_route, allow_suspect) =
            if n_live > 0 { (n_live, false) } else { (n_suspect, true) };
        if n_route == 0 {
            return Err(SubmitError::Closed);
        }
        if n_route < total {
            // Routed around at least one unroutable shard.
            self.shared.metrics.on_shard_failover();
        }
        let routable = |slot: &ShardSlot| {
            let st = slot.state();
            (st == ShardState::Live || (allow_suspect && st == ShardState::Suspect))
                && slot.client_alive()
        };
        // Resolve the drawn ordinals to slot indices in one scan (states
        // can flip between the count and this scan; any shortfall just
        // falls through to the sweep below).
        let first = if n_route == 1 {
            (0..total).map(|k| cands.get(k)).find(|&i| routable(&slots[i]))
        } else {
            let (a_k, b_k) = draw_pair(self.picks.fetch_add(1, Ordering::Relaxed), n_route);
            let (mut ia, mut ib) = (None, None);
            let mut r = 0usize;
            for k in 0..total {
                let i = cands.get(k);
                if !routable(&slots[i]) {
                    continue;
                }
                if r == a_k {
                    ia = Some(i);
                }
                if r == b_k {
                    ib = Some(i);
                }
                r += 1;
                if ia.is_some() && ib.is_some() {
                    break;
                }
            }
            match (ia, ib) {
                (Some(a), Some(b)) => Some(self.lighter(slots.as_slice(), a, b)),
                (one, other) => one.or(other),
            }
        };
        let Some(first) = first else {
            return Err(SubmitError::Closed);
        };
        // The client serializes straight off the borrow, so routing (and
        // failover retries) never deep-copy the T×F samples.
        match try_one(&slots[first], model, &window) {
            Some(Ok(ticket)) => {
                self.shared.metrics.on_submit();
                return Ok(ticket);
            }
            Some(Err(e)) => return Err(e),
            None => {}
        }
        for k in 0..total {
            let i = cands.get(k);
            if i == first || !routable(&slots[i]) {
                continue;
            }
            // The previous pick died under the write: re-issue elsewhere.
            self.shared.metrics.on_shard_failover();
            match try_one(&slots[i], model, &window) {
                Some(Ok(ticket)) => {
                    self.shared.metrics.on_submit();
                    return Ok(ticket);
                }
                Some(Err(e)) => return Err(e),
                None => {}
            }
        }
        Err(SubmitError::Closed)
    }

    fn open_stream(&self, model: &str, stream: u64, window: usize) -> Result<(), SubmitError> {
        ShardRouter::open_stream(self, model, stream, window)
    }

    fn submit_sample(
        &self,
        model: &str,
        stream: u64,
        sample: Vec<f32>,
    ) -> Result<Ticket, SubmitError> {
        ShardRouter::submit_sample(self, model, stream, sample)
    }

    fn close_stream(&self, model: &str, stream: u64) {
        ShardRouter::close_stream(self, model, stream)
    }

    fn fleet_report(&self) -> String {
        ShardRouter::fleet_report(self)
    }
}

/// Submit to one slot. `None` means "connection died under us — try the
/// next candidate"; per-request verdicts (e.g. `TooLarge`) are terminal,
/// every shard would answer the same, and retrying them would fabricate
/// failovers on healthy connections.
fn try_one(
    slot: &ShardSlot,
    model: &str,
    window: &Window,
) -> Option<Result<Ticket, SubmitError>> {
    let client = slot.client()?;
    match client.submit_async(model, window) {
        Err(SubmitError::Closed) => None,
        other => Some(other),
    }
}

fn health_loop(shared: Arc<RouterShared>) {
    let mut redials: Vec<JoinHandle<()>> = Vec::new();
    loop {
        {
            let stopped = shared.stop.lock().unwrap();
            let period = Duration::from_millis(shared.cfg.heartbeat_ms);
            let (stopped, _) = shared.tick.wait_timeout(stopped, period).unwrap();
            if *stopped {
                break;
            }
        }
        let (done, pending): (Vec<_>, Vec<_>) =
            redials.into_iter().partition(|h| h.is_finished());
        for h in done {
            let _ = h.join();
        }
        redials = pending;
        health_tick(&shared, &mut redials);
    }
    for h in redials {
        let _ = h.join();
    }
}

/// One health tick: walk every slot, consume heartbeats, drive the
/// state machine, send the next probes, launch due redials, and refresh
/// the membership gauges.
fn health_tick(shared: &Arc<RouterShared>, redials: &mut Vec<JoinHandle<()>>) {
    // Snapshot the slot list so the walk never holds the registry lock
    // (a demotion joins a reader thread — too slow to hold locks across).
    let slots: Vec<Arc<ShardSlot>> = shared.slots.read().unwrap().clone();
    let (mut live, mut suspect, mut draining, mut down) = (0, 0, 0, 0);
    for slot in &slots {
        match slot.state() {
            ShardState::Dead => {
                down += 1;
                // A retired slot's process was drained and reaped on
                // purpose — redialing the gone address forever would be
                // pure churn.
                if slot.retired.load(Ordering::Acquire) {
                    continue;
                }
                let due = {
                    let ctl = slot.ctl.lock().unwrap();
                    match ctl.next_attempt {
                        Some(t) => Instant::now() >= t,
                        None => true,
                    }
                };
                if due && !shared.is_stopping() {
                    slot.set_state(ShardState::Reconnecting);
                    let slot = slot.clone();
                    let shared = shared.clone();
                    let handle = std::thread::Builder::new()
                        .name(format!("shard-redial:{}", slot.addr))
                        .spawn(move || reconnect_attempt(slot, shared))
                        .expect("spawn shard redial");
                    redials.push(handle);
                }
            }
            ShardState::Reconnecting => down += 1,
            _ => {
                let client = slot.client();
                let mut ctl = slot.ctl.lock().unwrap();
                let Some(client) = client else {
                    demote_dead(slot, &mut ctl, &shared.metrics);
                    down += 1;
                    continue;
                };
                if !client.is_alive() {
                    // Hard connection death (EOF, write failure): don't
                    // wait out the probe thresholds.
                    demote_dead(slot, &mut ctl, &shared.metrics);
                    down += 1;
                    continue;
                }
                let fresh = match client.last_heartbeat() {
                    Some(hb) if hb.seq > ctl.seen_seq => Some(hb),
                    _ => None,
                };
                if let Some(hb) = fresh {
                    ctl.seen_seq = hb.seq;
                    ctl.missed = 0;
                    shared.metrics.on_heartbeat();
                    shared.fleet_shed.fetch_add(hb.shed_delta, Ordering::Relaxed);
                    slot.fold_ewmas(hb.inflight as f64, hb.p99_us);
                    if slot.state() == ShardState::Suspect {
                        // Slow-but-alive shard answered again: re-promote.
                        slot.set_state(ShardState::Live);
                    }
                } else if ctl.probe_seq > 0 {
                    ctl.missed += 1;
                }
                if client.is_draining() {
                    slot.set_state(ShardState::Draining);
                }
                if slot.state() == ShardState::Draining {
                    if client.inflight() == 0 {
                        // Drained: close cleanly (nothing left to poison)
                        // and hand the slot to the redial path — if the
                        // process restarts, it rejoins like any other.
                        client.shutdown();
                        *slot.client.write().unwrap() = None;
                        slot.clear_ewmas();
                        ctl.missed = 0;
                        ctl.next_attempt = Some(
                            Instant::now() + Duration::from_millis(ctl.backoff_ms),
                        );
                        slot.set_state(ShardState::Dead);
                        down += 1;
                        continue;
                    }
                    draining += 1;
                } else if ctl.missed >= shared.cfg.dead_after {
                    demote_dead(slot, &mut ctl, &shared.metrics);
                    down += 1;
                    continue;
                } else {
                    if ctl.missed >= shared.cfg.suspect_after
                        && slot.state() == ShardState::Live
                    {
                        slot.set_state(ShardState::Suspect);
                        shared.metrics.on_shard_suspect();
                    }
                    match slot.state() {
                        ShardState::Suspect => suspect += 1,
                        _ => live += 1,
                    }
                }
                // One probe per tick; a healthy shard's reply lands well
                // before the next tick. A failed write flips the client
                // dead and the next tick demotes — no extra handling.
                ctl.probe_seq += 1;
                if client.send_probe(ctl.probe_seq).is_ok() {
                    shared.metrics.on_health_probe();
                }
            }
        }
    }
    shared.metrics.set_shard_states(live, suspect, draining, down);
}

/// Demote a slot to Dead: close the connection — poisoning every
/// in-flight ticket with `Err(Closed)`, so no caller hangs — and arm an
/// immediate first redial.
fn demote_dead(slot: &ShardSlot, ctl: &mut SlotCtl, metrics: &ServerMetrics) {
    if let Some(client) = slot.client.write().unwrap().take() {
        client.shutdown();
    }
    slot.clear_ewmas();
    ctl.missed = 0;
    ctl.backoff_ms = RECONNECT_INITIAL_BACKOFF_MS;
    ctl.next_attempt = None;
    slot.set_state(ShardState::Dead);
    metrics.on_shard_death();
}

/// One redial against a dead slot, run on its own short-lived thread so
/// a slow dial never stalls the health tick. Success installs a fresh
/// client (new generation, EWMAs reset — the rejoiner is compared on raw
/// in-flight until it has samples); failure doubles the backoff (capped)
/// and schedules the next attempt with jitter, so a fleet of routers
/// doesn't redial a restarted shard in lockstep.
fn reconnect_attempt(slot: Arc<ShardSlot>, shared: Arc<RouterShared>) {
    shared.metrics.on_shard_reconnect_attempt();
    let dialed =
        if shared.is_stopping() { None } else { ShardClient::connect(&slot.addr).ok() };
    let mut ctl = slot.ctl.lock().unwrap();
    match dialed {
        Some(client) if !shared.is_stopping() => {
            *slot.client.write().unwrap() = Some(Arc::new(client));
            slot.clear_ewmas();
            ctl.generation += 1;
            ctl.missed = 0;
            ctl.probe_seq = 0;
            ctl.seen_seq = 0;
            ctl.attempts = 0;
            ctl.backoff_ms = RECONNECT_INITIAL_BACKOFF_MS;
            ctl.next_attempt = None;
            slot.set_state(ShardState::Live);
            shared.metrics.on_shard_reconnect();
        }
        Some(client) => {
            // Raced shutdown: never install into a closing router.
            client.shutdown();
            slot.set_state(ShardState::Dead);
        }
        None => {
            ctl.attempts += 1;
            let jitter = SplitMix64::new(ctl.attempts ^ ((slot.addr.len() as u64) << 32))
                .next_u64()
                % (ctl.backoff_ms / 2 + 1);
            ctl.next_attempt =
                Some(Instant::now() + Duration::from_millis(ctl.backoff_ms + jitter));
            ctl.backoff_ms = (ctl.backoff_ms.saturating_mul(2))
                .min(shared.cfg.reconnect_max_backoff_ms.max(RECONNECT_INITIAL_BACKOFF_MS));
            slot.set_state(ShardState::Dead);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Socket-free routing tests live here; the full loopback behaviour
    // (bit-identity, failover and rejoin under a killed shard) is pinned
    // by `tests/integration_shard.rs`.

    fn cand_indices(router: &ShardRouter, model: &str) -> Vec<usize> {
        let c = router.candidates(model, router.len());
        (0..c.len()).map(|k| c.get(k)).collect()
    }

    #[test]
    fn candidates_honor_static_map_with_canonical_fallback() {
        // An empty registry is fine: these connections only handshake.
        let reg = Arc::new(crate::server::ModelRegistry::new());
        let srv_a = crate::net::ShardServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let srv_b = crate::net::ShardServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let ca = Arc::new(ShardClient::connect(&srv_a.local_addr().to_string()).unwrap());
        let cb = Arc::new(ShardClient::connect(&srv_b.local_addr().to_string()).unwrap());
        let map = BTreeMap::from([
            ("LSTM-AE-F32-D2".to_string(), vec![0]),
            ("LSTM-AE-F64-D6".to_string(), vec![0, 1]),
        ]);
        let router = ShardRouter::over(vec![ca, cb], map);
        assert_eq!(cand_indices(&router, "LSTM-AE-F32-D2"), vec![0]);
        // Short name falls back to the canonical topology name.
        assert_eq!(cand_indices(&router, "F64-D6"), vec![0, 1]);
        assert!(cand_indices(&router, "no-such-model").is_empty());
        // An unmapped model routes nowhere: UnknownModel, not a panic.
        let w = crate::workload::Window { data: vec![vec![0.0]], anomaly: None };
        assert!(matches!(
            router.submit_async("no-such-model", w),
            Err(SubmitError::UnknownModel(_))
        ));
        router.shutdown();
        srv_a.shutdown();
        srv_b.shutdown();
    }

    #[test]
    fn distinct_pair_draw_is_unbiased() {
        // The old draw sampled b from live[0..len-1] and patched
        // collisions to the *last* element, over-selecting it. The fixed
        // draw must give every index — and every unordered pair —
        // near-uniform frequency. Deterministic: seeds are sequential,
        // exactly like the router's picks counter.
        const DRAWS: u64 = 30_000;
        let mut appear = [0u64; 3];
        let mut pairs: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        for seed in 0..DRAWS {
            let (a, b) = draw_pair(seed, 3);
            assert_ne!(a, b, "pair must be distinct");
            assert!(a < 3 && b < 3, "draw out of range: ({a}, {b})");
            appear[a] += 1;
            appear[b] += 1;
            *pairs.entry((a.min(b), a.max(b))).or_default() += 1;
        }
        // Each index sits in 2/3 of pairs: expect 20 000 (±5%, ~12σ).
        for (i, &c) in appear.iter().enumerate() {
            assert!((19_000..=21_000).contains(&c), "index {i} appeared {c}× in 30k draws");
        }
        // Each unordered pair: expect 10 000 (±10%).
        assert_eq!(pairs.len(), 3);
        for (&pair, &c) in &pairs {
            assert!((9_000..=11_000).contains(&c), "pair {pair:?} drawn {c}×");
        }
        // n = 2 degenerates to "the other one", both orders reachable.
        let mut orders = std::collections::BTreeSet::new();
        for seed in 0..64 {
            orders.insert(draw_pair(seed, 2));
        }
        assert_eq!(orders.into_iter().collect::<Vec<_>>(), vec![(0, 1), (1, 0)]);
    }

    #[test]
    fn slots_expose_state_addr_and_inflight() {
        let reg = Arc::new(crate::server::ModelRegistry::new());
        let srv = crate::net::ShardServer::bind("127.0.0.1:0", reg).unwrap();
        let addr = srv.local_addr().to_string();
        let router = ShardRouter::connect(&[addr.clone()]).unwrap();
        assert_eq!(router.len(), 1);
        assert!(!router.is_empty());
        assert_eq!(router.shard_state(0), ShardState::Live);
        assert_eq!(router.shard_addr(0), addr);
        assert_eq!(router.shard_inflight(0), 0);
        assert_eq!(router.shard_generation(0), 0);
        assert_eq!(router.live_shards(), 1);
        let report = router.fleet_report();
        assert!(report.contains("[live]"), "{report}");
        router.shutdown();
        assert_eq!(router.live_shards(), 0);
        assert_eq!(router.shard_state(0), ShardState::Dead);
        srv.shutdown();
    }

    #[test]
    fn add_shard_is_idempotent_for_routable_addresses() {
        let reg = Arc::new(crate::server::ModelRegistry::new());
        let srv_a = crate::net::ShardServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        let srv_b = crate::net::ShardServer::bind("127.0.0.1:0", reg).unwrap();
        let addr_a = srv_a.local_addr().to_string();
        let addr_b = srv_b.local_addr().to_string();
        let router = ShardRouter::connect(&[addr_a.clone()]).unwrap();
        assert_eq!(router.add_shard(&addr_b).unwrap(), 1);
        assert_eq!(router.len(), 2);
        // Re-admitting either address must return the existing slot, not
        // append a duplicate that would double-route to one process.
        assert_eq!(router.add_shard(&addr_a).unwrap(), 0);
        assert_eq!(router.add_shard(&addr_b).unwrap(), 1);
        assert_eq!(router.len(), 2);
        router.shutdown();
        srv_a.shutdown();
        srv_b.shutdown();
    }

    #[test]
    fn router_config_builder_validates() {
        let cfg = RouterConfig::builder()
            .heartbeat_ms(25)
            .suspect_after(2)
            .dead_after(4)
            .reconnect_max_backoff_ms(500)
            .build();
        assert_eq!(cfg.heartbeat_ms, 25);
        assert_eq!(cfg.suspect_after, 2);
        assert_eq!(cfg.dead_after, 4);
        assert_eq!(cfg.reconnect_max_backoff_ms, 500);
        let bad = std::panic::catch_unwind(|| {
            RouterConfig::builder().suspect_after(5).dead_after(2).build()
        });
        assert!(bad.is_err(), "suspect_after > dead_after must fail build()");
    }

    #[test]
    fn router_config_defaults_are_sane() {
        let cfg = RouterConfig::default();
        assert!(cfg.suspect_after >= 1);
        assert!(cfg.dead_after >= cfg.suspect_after);
        assert!(cfg.heartbeat_ms >= 1);
        assert!(cfg.reconnect_max_backoff_ms >= RECONNECT_INITIAL_BACKOFF_MS);
    }
}
