//! # lstm-ae-accel
//!
//! Reproduction of *"Exploiting temporal parallelism for LSTM Autoencoder
//! acceleration on FPGA"* (CS.AR 2026) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! - **L1/L2 (build time, Python)** — a fused Pallas LSTM-cell kernel and a
//!   `lax.scan`-based LSTM-Autoencoder model, trained on synthetic telemetry
//!   and AOT-lowered to HLO text under `artifacts/`.
//! - **L3 (this crate)** — the paper's system contribution: a cycle-accurate
//!   **dataflow accelerator simulator** with temporal parallelism across LSTM
//!   layers ([`accel::dataflow`]), the **dataflow-balancing methodology** via
//!   hardware reuse factors ([`accel::reuse`], paper Eqs 5–8), an analytical
//!   latency model ([`accel::latency`], Eqs 1–4), FPGA resource and energy
//!   models ([`accel::resources`], [`accel::energy`]), a **temporal-pipeline
//!   execution engine** that runs the §3.1 dataflow in software — per-layer
//!   worker threads over bounded FIFOs plus zero-alloc batched Q8.24
//!   kernels ([`engine`]), CPU/GPU baselines
//!   ([`baselines`]), a PJRT runtime that executes the AOT artifacts
//!   ([`runtime`]), and an end-to-end anomaly-detection service ([`server`])
//!   — a multi-model fabric with bounded admission, dynamic batching,
//!   metrics-driven per-lane autoscaling ([`server::autoscale`]), and a
//!   cross-process shard fabric ([`net`], [`server::shard`]) that
//!   stretches the same `submit(model, window)` surface over TCP
//!   (`fleet serve` / `fleet connect` in the CLI).
//!
//! ## Quick start
//!
//! ```no_run
//! use lstm_ae_accel::model::Topology;
//! use lstm_ae_accel::accel::{reuse::BalancedConfig, dataflow::DataflowSim};
//!
//! // The paper's LSTM-AE-F32-D2 model: 32 -> 16 -> 32 features.
//! let topo = Topology::from_name("LSTM-AE-F32-D2").unwrap();
//! // Balance the dataflow with the paper's RH_m = 1 (Table 1).
//! let cfg = BalancedConfig::balance(&topo, 1);
//! // Cycle-accurate simulation of a 64-timestep sequence.
//! let run = DataflowSim::new(&cfg).run_sequence(64);
//! println!("latency = {:.3} ms", run.total_ms(300.0e6));
//! ```
//!
//! See `README.md` for the repo tour, `ARCHITECTURE.md` for the serving
//! dataflow diagram, and `EXPERIMENTS.md` for paper-vs-measured results.

// The docs CI job runs `cargo doc --no-deps` with `-D warnings`; broken
// intra-doc links are denied outright so the documented serving surface
// (README → rustdoc pointers) cannot silently rot.
#![deny(rustdoc::broken_intra_doc_links)]

pub mod util;
pub mod fixed;
pub mod activations;
pub mod model;
pub mod engine;
pub mod accel;
pub mod baselines;
pub mod runtime;
pub mod workload;
pub mod server;
pub mod net;
pub mod report;

/// Paper's target clock for the FPGA designs (§4.1): 300 MHz.
pub const FPGA_CLOCK_HZ: f64 = 300.0e6;

/// Convert clock cycles at `hz` to milliseconds.
pub fn cycles_to_ms(cycles: u64, hz: f64) -> f64 {
    cycles as f64 / hz * 1e3
}
