//! Tiny dense linear algebra: Gaussian elimination and linear least
//! squares via normal equations. Used to calibrate the CPU/GPU analytical
//! baseline models against the paper's published tables (DESIGN.md §6).

/// Solve `A x = b` for square `A` (row-major, n×n) by Gaussian elimination
/// with partial pivoting. Returns `None` if singular.
pub fn solve(a: &[f64], b: &[f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    let mut m = a.to_vec();
    let mut rhs = b.to_vec();
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in col + 1..n {
            if m[r * n + col].abs() > m[piv * n + col].abs() {
                piv = r;
            }
        }
        if m[piv * n + col].abs() < 1e-12 {
            return None;
        }
        if piv != col {
            for c in 0..n {
                m.swap(col * n + c, piv * n + c);
            }
            rhs.swap(col, piv);
        }
        // Eliminate below.
        for r in col + 1..n {
            let factor = m[r * n + col] / m[col * n + col];
            for c in col..n {
                m[r * n + c] -= factor * m[col * n + c];
            }
            rhs[r] -= factor * rhs[col];
        }
    }
    // Back substitution.
    let mut x = vec![0.0; n];
    for row in (0..n).rev() {
        let mut acc = rhs[row];
        for c in row + 1..n {
            acc -= m[row * n + c] * x[c];
        }
        x[row] = acc / m[row * n + row];
    }
    Some(x)
}

/// Least squares `min ‖X β − y‖²` via normal equations `XᵀX β = Xᵀy`.
/// `x` is row-major with `k` columns; returns β (length k).
pub fn lstsq(x: &[f64], y: &[f64], k: usize) -> Option<Vec<f64>> {
    let n = y.len();
    assert_eq!(x.len(), n * k);
    let mut xtx = vec![0.0; k * k];
    let mut xty = vec![0.0; k];
    for i in 0..n {
        for a in 0..k {
            xty[a] += x[i * k + a] * y[i];
            for b in 0..k {
                xtx[a * k + b] += x[i * k + a] * x[i * k + b];
            }
        }
    }
    solve(&xtx, &xty, k)
}

/// R² of a fit (1 − SS_res / SS_tot).
pub fn r_squared(pred: &[f64], y: &[f64]) -> f64 {
    assert_eq!(pred.len(), y.len());
    let mean = y.iter().sum::<f64>() / y.len() as f64;
    let ss_tot: f64 = y.iter().map(|v| (v - mean).powi(2)).sum();
    let ss_res: f64 = pred.iter().zip(y).map(|(p, v)| (p - v).powi(2)).sum();
    1.0 - ss_res / ss_tot.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::props;

    #[test]
    fn solves_known_system() {
        // 2x + y = 5; x - y = 1 → x = 2, y = 1.
        let x = solve(&[2.0, 1.0, 1.0, -1.0], &[5.0, 1.0], 2).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-12);
        assert!((x[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn detects_singular() {
        assert!(solve(&[1.0, 2.0, 2.0, 4.0], &[1.0, 2.0], 2).is_none());
    }

    #[test]
    fn lstsq_recovers_exact_linear_model() {
        props("lstsq_exact", 64, |g| {
            let beta = [g.f64_in(-3.0, 3.0), g.f64_in(-3.0, 3.0), g.f64_in(-3.0, 3.0)];
            let n = 30;
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for _ in 0..n {
                let a = g.f64_in(-5.0, 5.0);
                let b = g.f64_in(-5.0, 5.0);
                xs.extend_from_slice(&[1.0, a, b]);
                ys.push(beta[0] + beta[1] * a + beta[2] * b);
            }
            let fit = lstsq(&xs, &ys, 3).unwrap();
            for (f, t) in fit.iter().zip(&beta) {
                assert!((f - t).abs() < 1e-8, "fit {f} true {t}");
            }
        });
    }

    #[test]
    fn r_squared_perfect_and_mean() {
        let y = [1.0, 2.0, 3.0];
        assert!((r_squared(&y, &y) - 1.0).abs() < 1e-12);
        let mean_pred = [2.0, 2.0, 2.0];
        assert!(r_squared(&mean_pred, &y).abs() < 1e-12);
    }
}
