//! Minimal JSON reader/writer (RFC 8259 subset, no serde in the vendor
//! set). Used for `artifacts/manifest.json`, experiment configs, and
//! machine-readable bench reports.
//!
//! Supported: objects, arrays, strings (with \uXXXX escapes, no surrogate
//! pairs — manifest content is ASCII), numbers (f64), booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — bench reports diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialization ---------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"models":[{"name":"LSTM-AE-F32-D2","layers":[32,16,32],"T":[1,2,4],"hlo":"f32d2_T4.hlo.txt"}],"version":1,"quant":{"frac_bits":24,"word":32},"ok":true,"none":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.get("models").unwrap().as_arr().unwrap()[0]
                .get("name")
                .unwrap()
                .as_str()
                .unwrap(),
            "LSTM-AE-F32-D2"
        );
        assert_eq!(v.get("quant").unwrap().get("frac_bits").unwrap().as_u64(), Some(24));
        // Reparse of serialization is identical.
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn parses_numbers() {
        for (s, want) in [("0", 0.0), ("-12.5", -12.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"b\"A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"b\"A"));
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"unterminated"] {
            assert!(Json::parse(s).is_err(), "{s:?} should fail");
        }
    }

    #[test]
    fn pretty_print_parses_back() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::num(1.0), Json::num(2.5)])),
            ("b", Json::obj(vec![("c", Json::Null)])),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\n"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"α→β\"").unwrap();
        assert_eq!(v.as_str(), Some("α→β"));
    }
}
