//! Measurement harness for the benches (criterion is not in the offline
//! vendor set): warmup + timed repetitions with summary statistics, plus
//! a `black_box` to keep the optimizer honest.

use std::time::Instant;

use super::stats::Summary;

/// Opaque identity function the optimizer cannot see through.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub per_iter: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.per_iter;
        format!(
            "{:<42} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_secs(s.mean),
            fmt_secs(s.p50),
            fmt_secs(s.p95),
        )
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Time `f` with `warmup` untimed runs then `samples` timed batches; each
/// sample runs `f` `batch` times and the per-iteration time is the batch
/// mean. Keeps total runtime bounded while giving stable percentiles.
pub fn bench(
    name: &str,
    warmup: usize,
    samples: usize,
    batch: usize,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let start = Instant::now();
        for _ in 0..batch {
            f();
        }
        per_iter.push(start.elapsed().as_secs_f64() / batch as f64);
    }
    BenchResult {
        name: name.to_string(),
        per_iter: Summary::of(&per_iter),
        iters: samples * batch,
    }
}

/// Convenience: auto-pick batch size so each sample is ≥ ~2 ms, then run
/// `samples` samples. Good default for microbenchmarks.
pub fn bench_auto(name: &str, samples: usize, mut f: impl FnMut()) -> BenchResult {
    // Estimate cost with a couple of probes.
    let start = Instant::now();
    f();
    f();
    let est = (start.elapsed().as_secs_f64() / 2.0).max(1e-9);
    let batch = ((2e-3 / est).ceil() as usize).clamp(1, 1_000_000);
    bench(name, 2, samples, batch, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let r = bench("spin", 1, 5, 100, || {
            let mut acc = 0u64;
            for i in 0..100 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.per_iter.mean > 0.0);
        assert_eq!(r.iters, 500);
    }

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with("s"));
    }
}
