//! CPU core pinning for pipeline-stage workers (no `libc` crate in the
//! offline vendor set, same constraint as the `SO_REUSEADDR` helper in
//! `net::server`).
//!
//! The temporal pipeline hands each layer's tokens to the next layer over
//! a bounded FIFO; when the OS scheduler migrates those worker threads,
//! the layer *i* → *i+1* handoff keeps bouncing cache lines between
//! whichever cores the two threads last ran on. Pinning layer *i* to core
//! `base + i` (mod the online set) makes neighbouring stages neighbouring
//! cores, so handoff lines stay in a shared L2/L3 slice — the software
//! analog of the accelerator's fixed module placement.
//!
//! On Linux this goes straight to the `sched_setaffinity(2)` /
//! `sched_getaffinity(2)` syscalls via `extern "C"` (glibc wrappers; pid
//! 0 = the calling thread). Elsewhere both calls degrade gracefully:
//! pinning reports `false` and the core count falls back to
//! `std::thread::available_parallelism`, so every caller treats pinning
//! as a best-effort hint, never a correctness dependency.

/// Widest CPU mask we build: 16 × 64 = 1024 cores, matching the kernel's
/// default `CONFIG_NR_CPUS` ceiling on common distros.
const MASK_WORDS: usize = 16;

#[cfg(target_os = "linux")]
mod imp {
    use super::MASK_WORDS;

    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
    }

    pub fn pin_to_core(core: usize) -> bool {
        if core >= MASK_WORDS * 64 {
            return false;
        }
        let mut mask = [0u64; MASK_WORDS];
        mask[core / 64] = 1u64 << (core % 64);
        let rc = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
        rc == 0
    }

    pub fn online_cores() -> Option<usize> {
        let mut mask = [0u64; MASK_WORDS];
        let rc = unsafe { sched_getaffinity(0, std::mem::size_of_val(&mask), mask.as_mut_ptr()) };
        if rc != 0 {
            return None;
        }
        let n: usize = mask.iter().map(|w| w.count_ones() as usize).sum();
        (n > 0).then_some(n)
    }
}

#[cfg(not(target_os = "linux"))]
mod imp {
    pub fn pin_to_core(_core: usize) -> bool {
        false
    }

    pub fn online_cores() -> Option<usize> {
        None
    }
}

/// Pin the **calling thread** to one CPU core. Returns `true` on success;
/// `false` on non-Linux targets, out-of-range cores, or a kernel refusal
/// (e.g. a cpuset that excludes `core`). Callers must treat a `false` as
/// "run unpinned", never as an error — placement is a scheduling hint and
/// results are bit-identical either way.
pub fn pin_to_core(core: usize) -> bool {
    imp::pin_to_core(core)
}

/// Number of cores the current thread may run on (its affinity mask on
/// Linux, `available_parallelism` elsewhere or on syscall failure; never
/// 0). Pinning plans wrap their core assignments modulo this.
pub fn available_cores() -> usize {
    imp::online_cores()
        .or_else(|| std::thread::available_parallelism().ok().map(|n| n.get()))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_cores_is_positive() {
        assert!(available_cores() >= 1);
    }

    #[test]
    fn out_of_range_core_is_rejected() {
        assert!(!pin_to_core(MASK_WORDS * 64));
        assert!(!pin_to_core(usize::MAX));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn pinning_to_an_online_core_succeeds_and_computes() {
        // Pin a scratch thread (not the test harness thread) so the test
        // leaves no affinity behind, then prove the pinned thread still
        // computes normally.
        let handle = std::thread::spawn(|| {
            let ok = pin_to_core(0);
            let sum: u64 = (0..1000u64).sum();
            (ok, sum)
        });
        let (ok, sum) = handle.join().unwrap();
        assert!(ok, "pinning to core 0 must succeed on Linux");
        assert_eq!(sum, 499_500);
    }
}
