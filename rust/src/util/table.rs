//! Paper-style ASCII table rendering for the bench harness and CLI.
//! Produces aligned, pipe-delimited tables that mirror the layout of the
//! paper's Tables 1–3 so paper-vs-measured comparison is eyeballable.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// An ASCII table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), ..Default::default() }
    }

    /// Set the header; all columns default to right-aligned except col 0.
    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self.aligns = (0..cols.len())
            .map(|i| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        self
    }

    pub fn align(mut self, col: usize, a: Align) -> Self {
        if col < self.aligns.len() {
            self.aligns[col] = a;
        }
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Insert a horizontal separator row.
    pub fn separator(&mut self) -> &mut Self {
        self.rows.push(Vec::new());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let rule = |out: &mut String| {
            out.push('+');
            for w in &widths {
                out.push_str(&"-".repeat(w + 2));
                out.push('+');
            }
            out.push('\n');
        };
        rule(&mut out);
        out.push('|');
        for (i, h) in self.header.iter().enumerate() {
            out.push_str(&pad(h, widths[i], Align::Left));
            out.push('|');
        }
        out.push('\n');
        rule(&mut out);
        for row in &self.rows {
            if row.is_empty() {
                rule(&mut out);
                continue;
            }
            out.push('|');
            for i in 0..ncols {
                out.push_str(&pad(&row[i], widths[i], self.aligns[i]));
                out.push('|');
            }
            out.push('\n');
        }
        rule(&mut out);
        out
    }
}

fn pad(s: &str, w: usize, a: Align) -> String {
    let len = s.chars().count();
    let fill = w.saturating_sub(len);
    match a {
        Align::Left => format!(" {}{} ", s, " ".repeat(fill)),
        Align::Right => format!(" {}{} ", " ".repeat(fill), s),
    }
}

/// Format a latency in ms the way the paper does (3 decimals).
pub fn ms(v: f64) -> String {
    format!("{v:.3}")
}

/// Format a speedup the way the paper does: `(x12.7)`.
pub fn speedup(v: f64) -> String {
    format!("(x{v:.1})")
}

/// Format a percentage with 2 decimals (Table 1 style).
pub fn pct(v: f64) -> String {
    format!("{v:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo").header(&["Model", "FPGA", "CPU"]);
        t.row(vec!["F32-D2".into(), "0.033".into(), "0.420 (x12.7)".into()]);
        t.row(vec!["F64-D6-long".into(), "0.060".into(), "1.208 (x20.1)".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        // All data lines equal width.
        let widths: Vec<usize> =
            s.lines().filter(|l| l.starts_with('|')).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
        assert!(s.contains("F64-D6-long"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(ms(0.0334), "0.033");
        assert_eq!(speedup(12.72), "(x12.7)");
        assert_eq!(pct(26.113), "26.11");
    }
}
