//! Tiny CLI argument parser (clap is not in the offline vendor set).
//! Supports `--flag`, `--key value`, `--key=value`, and positionals.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), String::from("true"));
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list of usize, e.g. `--timesteps 1,2,4,64`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_mixed_styles() {
        let a = parse(&["simulate", "--model", "F32-D2", "--timesteps=64", "--verbose"]);
        assert_eq!(a.positional, vec!["simulate"]);
        assert_eq!(a.get("model"), Some("F32-D2"));
        assert_eq!(a.get_usize("timesteps", 0), 64);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("model", "all"), "all");
        assert_eq!(a.get_f64("rate", 1.5), 1.5);
        assert_eq!(a.get_usize_list("t", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn usize_list() {
        let a = parse(&["--t", "1,2, 4,64"]);
        assert_eq!(a.get_usize_list("t", &[]), vec![1, 2, 4, 64]);
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = parse(&["--fast", "--model", "X"]);
        assert!(a.has("fast"));
        assert_eq!(a.get("model"), Some("X"));
    }
}
