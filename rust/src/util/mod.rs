//! From-scratch substrates: deterministic PRNGs, a minimal JSON
//! reader/writer, a property-testing mini-framework, paper-style ASCII
//! tables, summary statistics, a tiny CLI argument parser, and a
//! core-pinning helper.
//!
//! The offline vendor set ships only `xla` + `anyhow`, so everything a
//! well-maintained systems repo would normally pull from crates.io
//! (rand, serde_json, proptest, clap, criterion's stats) is implemented
//! here and tested like any other module.

pub mod rng;
pub mod json;
pub mod prop;
pub mod table;
pub mod stats;
pub mod cli;
pub mod timer;
pub mod linalg;
pub mod affinity;
