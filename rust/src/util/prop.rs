//! Mini property-testing framework (proptest is not in the offline vendor
//! set). Provides seeded case generation, a configurable case count, and
//! failure reporting with the generating seed so failures reproduce.
//!
//! ```no_run
//! # // no_run: doctest binaries land outside the crate's rpath and the
//! # // xla shared objects (libstdc++ bundle) cannot be located; the
//! # // same pattern is exercised for real all over the test suite.
//! use lstm_ae_accel::util::prop::{props, Gen};
//! props("add_commutes", 256, |g: &mut Gen| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Xoshiro256;

/// Per-case generator handed to the property body.
pub struct Gen {
    rng: Xoshiro256,
    pub case: usize,
}

impl Gen {
    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Vector of f32 drawn uniformly from [lo, hi].
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Run `f` for `cases` generated cases under the default seed. Panics (with
/// the case index and seed) on the first failing case.
pub fn props(name: &str, cases: usize, f: impl Fn(&mut Gen)) {
    props_seeded(name, 0xC0FFEE, cases, f)
}

/// As [`props`] with an explicit seed — printed on failure for replay.
pub fn props_seeded(name: &str, seed: u64, cases: usize, f: impl Fn(&mut Gen)) {
    for case in 0..cases {
        // Derive an independent stream per case so a failure replays alone.
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Xoshiro256::seeded(case_seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}, \
                 case_seed {case_seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        props("trivial", 64, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failures_with_seed() {
        props("fails", 64, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 90, "x={x}");
        });
    }

    #[test]
    fn cases_are_deterministic() {
        // Same case seed -> same draw stream.
        let mut g1 = Gen { rng: Xoshiro256::seeded(123), case: 0 };
        let mut g2 = Gen { rng: Xoshiro256::seeded(123), case: 0 };
        for _ in 0..32 {
            assert_eq!(g1.u64_below(1000), g2.u64_below(1000));
        }
    }
}
