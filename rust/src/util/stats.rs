//! Summary statistics for measurements: mean, stddev, percentiles, and a
//! streaming histogram used by the server's latency metrics.

/// Simple batch summary over a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Log-bucketed streaming histogram: fixed memory, ~4% relative bucket
/// width; good enough for latency percentiles in the serving metrics.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    /// counts[i] covers [base * growth^i, base * growth^(i+1))
    counts: Vec<u64>,
    base: f64,
    log_growth: f64,
    underflow: u64,
    total: u64,
    sum: f64,
    max_seen: f64,
}

impl LogHistogram {
    /// `base` = smallest resolvable value (e.g. 1e-7 s), 256 buckets with 4%
    /// growth cover ~5 orders of magnitude.
    pub fn new(base: f64, growth: f64, buckets: usize) -> Self {
        Self {
            counts: vec![0; buckets],
            base,
            log_growth: growth.ln(),
            underflow: 0,
            total: 0,
            sum: 0.0,
            max_seen: 0.0,
        }
    }

    /// Default for latencies in seconds: 100 ns .. ~3000 s.
    pub fn for_latency() -> Self {
        Self::new(1e-7, 1.04, 620)
    }

    pub fn record(&mut self, v: f64) {
        self.total += 1;
        self.sum += v;
        if v > self.max_seen {
            self.max_seen = v;
        }
        if v < self.base {
            self.underflow += 1;
            return;
        }
        let idx = ((v / self.base).ln() / self.log_growth) as usize;
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max_seen
    }

    /// Percentile estimate (bucket lower edge interpolation).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.base;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * (self.log_growth * (i as f64 + 0.5)).exp();
            }
        }
        self.max_seen
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.total += other.total;
        self.sum += other.sum;
        self.max_seen = self.max_seen.max(other.max_seen);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_tracks_percentiles_within_bucket_error() {
        let mut h = LogHistogram::for_latency();
        let mut r = Xoshiro256::seeded(1);
        let mut xs: Vec<f64> = (0..50_000).map(|_| r.uniform(1e-4, 1e-1)).collect();
        for &x in &xs {
            h.record(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.95, 0.99] {
            let exact = percentile_sorted(&xs, q);
            let est = h.percentile(q);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.06, "q={q} exact={exact} est={est} rel={rel}");
        }
    }

    #[test]
    fn histogram_merge_equals_combined() {
        let mut a = LogHistogram::for_latency();
        let mut b = LogHistogram::for_latency();
        let mut all = LogHistogram::for_latency();
        let mut r = Xoshiro256::seeded(2);
        for i in 0..10_000 {
            let v = r.uniform(1e-5, 1e-2);
            if i % 2 == 0 {
                a.record(v)
            } else {
                b.record(v)
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.percentile(0.5) - all.percentile(0.5)).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_defaultish() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }
}
