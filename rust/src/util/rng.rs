//! Deterministic pseudo-random number generation.
//!
//! Two generators:
//! - [`SplitMix64`] — tiny, used for seeding and cheap draws.
//! - [`Xoshiro256`] (xoshiro256**) — the workhorse for workload and
//!   property-test generation. Both are reproducible across platforms
//!   (pure integer arithmetic), which the test suite relies on.

/// SplitMix64: 64-bit state, full-period, good avalanche. Primary use is
/// seeding [`Xoshiro256`] so that nearby seeds give independent streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** by Blackman & Vigna. 256-bit state, period 2^256 − 1.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference implementation's advice.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our needs;
    /// modulo bias is < 2^-32 for n << 2^64 which is fine for sim workloads,
    /// but we still use widening multiply to avoid it entirely).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; generation is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times for Poisson
    /// request traces).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-300).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 0 from the reference implementation.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn deterministic_across_clones() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = a.clone();
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Xoshiro256::seeded(7);
        for _ in 0..10_000 {
            let x = r.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Xoshiro256::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::seeded(3);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256::seeded(11);
        let lambda = 4.0;
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "seed 5 should permute");
    }
}
