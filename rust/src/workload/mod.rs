//! Workload substrate: synthetic multivariate telemetry with injected
//! anomalies (the unsupervised-anomaly-detection setting the paper
//! motivates — network traffic monitoring, arrhythmia detection, gait
//! recognition, §1–2), plus Poisson request traces for the serving
//! experiments.
//!
//! Benign signal model: **low-rank** telemetry — `K = 4` latent
//! low-frequency sinusoids (periods 8–64 steps) mixed into `F` features
//! by a fixed matrix, plus Gaussian noise. Low rank is what makes the
//! LSTM-AE's bottleneck learnable, and is how real fleet telemetry
//! behaves (a few physical drivers, many correlated sensors). Mirrored
//! by `python/compile/datagen.py`, which trains on the same family.
//! Anomalies are windows with one of: amplitude spikes, level drift,
//! sensor dropout, or correlation-breaking scramble.

pub mod trace;

use crate::util::rng::Xoshiro256;

/// Kinds of injected anomaly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AnomalyKind {
    /// Short large-amplitude spikes on a few features.
    Spike,
    /// Slow additive drift of the mean level.
    Drift,
    /// A group of features drops to zero (sensor failure).
    Dropout,
    /// Phases scrambled — the cross-feature correlation breaks.
    PhaseScramble,
}

impl AnomalyKind {
    pub fn all() -> [AnomalyKind; 4] {
        [AnomalyKind::Spike, AnomalyKind::Drift, AnomalyKind::Dropout, AnomalyKind::PhaseScramble]
    }
}

/// Number of latent drivers (shared constant with `datagen.py`).
pub const LATENTS: usize = 4;

/// Generator of benign/anomalous telemetry windows with `features`
/// channels.
pub struct TelemetryGen {
    pub features: usize,
    rng: Xoshiro256,
    /// Per-latent base frequency (radians per timestep) and phase.
    freq: Vec<f64>,
    phase: Vec<f64>,
    /// `features × LATENTS` mixing matrix, row-major.
    mix: Vec<f64>,
    noise_std: f64,
    t_global: u64,
}

/// A labeled window.
#[derive(Clone, Debug)]
pub struct Window {
    /// `[T][F]` samples in [-1, 1]-ish range.
    pub data: Vec<Vec<f32>>,
    pub anomaly: Option<AnomalyKind>,
}

impl TelemetryGen {
    /// Deterministic generator; the python training side uses the same
    /// spectral parameters (seeded identically) so the trained AE sees
    /// this distribution.
    pub fn new(features: usize, seed: u64) -> TelemetryGen {
        let mut rng = Xoshiro256::seeded(seed);
        // Low-frequency latent bank: periods 8..64 timesteps.
        let freq: Vec<f64> = (0..LATENTS)
            .map(|_| 2.0 * std::f64::consts::PI / rng.uniform(8.0, 64.0))
            .collect();
        let phase: Vec<f64> =
            (0..LATENTS).map(|_| rng.uniform(0.0, 2.0 * std::f64::consts::PI)).collect();
        // Mixing matrix: rows L1-normalized, scaled into [0.5, 0.9].
        let mut mix = vec![0.0f64; features * LATENTS];
        for f in 0..features {
            let row: Vec<f64> = (0..LATENTS).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let l1: f64 = row.iter().map(|v| v.abs()).sum::<f64>().max(1e-9);
            let scale = rng.uniform(0.5, 0.9) / l1;
            for k in 0..LATENTS {
                mix[f * LATENTS + k] = row[k] * scale;
            }
        }
        TelemetryGen { features, rng, freq, phase, mix, noise_std: 0.02, t_global: 0 }
    }

    /// Build a generator from an exported telemetry spec
    /// (`artifacts/telemetry_F<F>.json`, written by `python/compile/aot.py`)
    /// so the stream matches the family the model was trained on. `seed`
    /// drives only noise/anomaly draws.
    pub fn from_spec(spec: &crate::util::json::Json, seed: u64) -> anyhow::Result<TelemetryGen> {
        use anyhow::anyhow;
        let features = spec
            .get("features")
            .and_then(crate::util::json::Json::as_usize)
            .ok_or_else(|| anyhow!("spec missing features"))?;
        let latents = spec
            .get("latents")
            .and_then(crate::util::json::Json::as_usize)
            .ok_or_else(|| anyhow!("spec missing latents"))?;
        if latents != LATENTS {
            return Err(anyhow!("spec latents {latents} != built-in {LATENTS}"));
        }
        let arr = |key: &str, want: usize| -> anyhow::Result<Vec<f64>> {
            let v: Vec<f64> = spec
                .get(key)
                .and_then(crate::util::json::Json::as_arr)
                .ok_or_else(|| anyhow!("spec missing {key}"))?
                .iter()
                .filter_map(|x| x.as_f64())
                .collect();
            if v.len() != want {
                return Err(anyhow!("spec {key}: {} values, want {want}", v.len()));
            }
            Ok(v)
        };
        Ok(TelemetryGen {
            features,
            rng: Xoshiro256::seeded(seed),
            freq: arr("freq", latents)?,
            phase: arr("phase", latents)?,
            mix: arr("mix", features * latents)?,
            noise_std: spec.get("noise_std").and_then(|v| v.as_f64()).unwrap_or(0.02),
            t_global: 0,
        })
    }

    /// Load a spec file written by the AOT pipeline.
    pub fn from_spec_file(path: &std::path::Path, seed: u64) -> anyhow::Result<TelemetryGen> {
        let text = std::fs::read_to_string(path)?;
        let json = crate::util::json::Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_spec(&json, seed)
    }

    /// Latent trajectory value for driver `k` at timestep `t`.
    fn latent(&self, k: usize, t: u64) -> f64 {
        let arg = self.freq[k] * t as f64 + self.phase[k];
        arg.sin() + 0.15 * (2.0 * arg).cos()
    }

    fn benign_sample(&mut self, t: u64) -> Vec<f32> {
        let z: Vec<f64> = (0..LATENTS).map(|k| self.latent(k, t)).collect();
        (0..self.features)
            .map(|f| {
                let s: f64 =
                    (0..LATENTS).map(|k| self.mix[f * LATENTS + k] * z[k]).sum();
                (s + self.noise_std * self.rng.normal()) as f32
            })
            .collect()
    }

    /// Next benign window of `t` timesteps (continuous global clock so
    /// windows look like a stream).
    pub fn benign_window(&mut self, t: usize) -> Window {
        let data = (0..t)
            .map(|_| {
                let s = self.benign_sample(self.t_global);
                self.t_global += 1;
                s
            })
            .collect();
        Window { data, anomaly: None }
    }

    /// Next window with an injected anomaly of the given kind.
    pub fn anomalous_window(&mut self, t: usize, kind: AnomalyKind) -> Window {
        let mut w = self.benign_window(t);
        match kind {
            AnomalyKind::Spike => {
                let n_spikes = 1 + self.rng.below(3) as usize;
                for _ in 0..n_spikes {
                    let ti = self.rng.below(t as u64) as usize;
                    let fi = self.rng.below(self.features as u64) as usize;
                    let mag = self.rng.uniform(1.5, 3.0);
                    let sign = if self.rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
                    let mag = mag * sign;
                    w.data[ti][fi] += mag as f32;
                }
            }
            AnomalyKind::Drift => {
                let slope = self.rng.uniform(0.02, 0.05);
                for (ti, row) in w.data.iter_mut().enumerate() {
                    for v in row.iter_mut() {
                        *v += (slope * ti as f64) as f32;
                    }
                }
            }
            AnomalyKind::Dropout => {
                let n_feat = (self.features / 4).max(1);
                let start_f = self.rng.below((self.features - n_feat + 1) as u64) as usize;
                let start_t = self.rng.below((t / 2).max(1) as u64) as usize;
                for row in w.data.iter_mut().skip(start_t) {
                    for v in row.iter_mut().skip(start_f).take(n_feat) {
                        *v = 0.0;
                    }
                }
            }
            AnomalyKind::PhaseScramble => {
                // Re-generate with per-feature randomized latent phases —
                // per-feature marginals look fine, the learned cross-
                // feature correlation structure is broken.
                let t0 = self.t_global;
                let scramble: Vec<f64> =
                    (0..self.features * LATENTS).map(|_| self.rng.uniform(0.0, 6.28)).collect();
                for (ti, row) in w.data.iter_mut().enumerate() {
                    let t = t0 + ti as u64;
                    for (fi, v) in row.iter_mut().enumerate() {
                        let s: f64 = (0..LATENTS)
                            .map(|k| {
                                let arg = self.freq[k] * t as f64
                                    + self.phase[k]
                                    + scramble[fi * LATENTS + k];
                                self.mix[fi * LATENTS + k] * (arg.sin() + 0.15 * (2.0 * arg).cos())
                            })
                            .sum();
                        *v = (s + self.noise_std * self.rng.normal()) as f32;
                    }
                }
            }
        }
        w.anomaly = Some(kind);
        w
    }

    /// A labeled evaluation set: `n` windows with the given anomaly rate.
    pub fn dataset(&mut self, n: usize, t: usize, anomaly_rate: f64) -> Vec<Window> {
        let kinds = AnomalyKind::all();
        (0..n)
            .map(|_| {
                if self.rng.next_f64() < anomaly_rate {
                    let k = kinds[self.rng.below(4) as usize];
                    self.anomalous_window(t, k)
                } else {
                    self.benign_window(t)
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn benign_windows_bounded_and_smooth() {
        let mut g = TelemetryGen::new(32, 1);
        let w = g.benign_window(64);
        assert_eq!(w.data.len(), 64);
        assert_eq!(w.data[0].len(), 32);
        for row in &w.data {
            for &v in row {
                assert!(v.abs() < 1.5, "sample {v} out of range");
            }
        }
        // Smoothness: successive samples move less than amplitude.
        for ti in 1..64 {
            for f in 0..32 {
                let d = (w.data[ti][f] - w.data[ti - 1][f]).abs();
                assert!(d < 0.8, "jump {d} at t={ti} f={f}");
            }
        }
    }

    #[test]
    fn stream_is_continuous_across_windows() {
        let mut g1 = TelemetryGen::new(8, 3);
        let mut g2 = TelemetryGen::new(8, 3);
        let a = g1.benign_window(16);
        let b = g1.benign_window(16);
        let long = g2.benign_window(32);
        // Deterministic: the concatenation of two 16-windows equals the
        // 32-window up to noise draws (same seed, same draw order).
        assert_eq!(a.data[0], long.data[0]);
        assert_eq!(b.data[15], long.data[31]);
    }

    #[test]
    fn anomalies_differ_from_benign() {
        let mut g = TelemetryGen::new(16, 5);
        for kind in AnomalyKind::all() {
            let w = g.anomalous_window(32, kind);
            assert_eq!(w.anomaly, Some(kind));
        }
    }

    #[test]
    fn dataset_rate_roughly_respected() {
        let mut g = TelemetryGen::new(8, 7);
        let ds = g.dataset(1000, 8, 0.3);
        let anomalous = ds.iter().filter(|w| w.anomaly.is_some()).count();
        assert!((250..350).contains(&anomalous), "{anomalous}");
    }

    #[test]
    fn dropout_zeroes_a_block() {
        let mut g = TelemetryGen::new(16, 9);
        let w = g.anomalous_window(32, AnomalyKind::Dropout);
        let zeros = w.data.iter().flatten().filter(|&&v| v == 0.0).count();
        assert!(zeros >= 16, "expected a zeroed block, got {zeros} zeros");
    }
}
