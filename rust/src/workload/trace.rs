//! Request traces for the serving experiments: Poisson (open-loop) and
//! closed-loop arrival processes over telemetry windows, the multi-model
//! merge used by the fleet driver, and the replay drivers that push those
//! traces through any [`ServingSurface`] — blocking or through the async
//! ticket front ([`replay_async`], [`closed_loop_async`]).
//!
//! Every driver is generic over [`ServingSurface`], so the same
//! closed-loop client that exercises an in-process
//! [`crate::server::ModelRegistry`] drives a cross-process
//! [`crate::server::ShardRouter`] unchanged — the `fleet connect` CLI
//! and the CI loopback soak run [`replay_fleet`] against a live TCP
//! fleet with the exact accounting the in-process tests pin down.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::{TelemetryGen, Window};
use crate::model::Topology;
use crate::server::{CompletionSet, ServingSurface, SubmitError, Ticket};
use crate::util::rng::Xoshiro256;

/// One timed request.
#[derive(Clone, Debug)]
pub struct TimedRequest {
    /// Arrival offset from trace start, seconds.
    pub at_s: f64,
    pub window: Window,
    pub id: u64,
}

/// An open-loop Poisson trace: `rate_rps` requests/second for `n`
/// requests, windows drawn from the telemetry generator with the given
/// anomaly rate.
pub fn poisson_trace(
    gen: &mut TelemetryGen,
    seed: u64,
    rate_rps: f64,
    n: usize,
    t: usize,
    anomaly_rate: f64,
) -> Vec<TimedRequest> {
    assert!(rate_rps > 0.0);
    let mut rng = Xoshiro256::seeded(seed);
    let mut at = 0.0f64;
    let kinds = super::AnomalyKind::all();
    (0..n as u64)
        .map(|id| {
            at += rng.exponential(rate_rps);
            let window = if rng.next_f64() < anomaly_rate {
                gen.anomalous_window(t, kinds[rng.below(4) as usize])
            } else {
                gen.benign_window(t)
            };
            TimedRequest { at_s: at, window, id }
        })
        .collect()
}

/// One independent Poisson stream per model — `total_rate` split evenly,
/// `total_n` divided per lane (at least one request each) — merged into a
/// single arrival-ordered schedule of `(model index, request)`. Windows
/// for model `i` are drawn at that model's feature width with seeds
/// derived from `base_seed + i`, so the schedule is deterministic.
///
/// Shared by the `fleet` CLI subcommand and the multi-model example so
/// the mixed-traffic recipe lives in one place.
pub fn merged_poisson(
    models: &[Topology],
    base_seed: u64,
    total_rate: f64,
    total_n: usize,
    t: usize,
    anomaly_rate: f64,
) -> Vec<(usize, TimedRequest)> {
    assert!(!models.is_empty(), "merged_poisson needs at least one model");
    let per_rate = total_rate / models.len() as f64;
    let per_n = (total_n / models.len()).max(1);
    let mut merged = Vec::with_capacity(per_n * models.len());
    for (mi, topo) in models.iter().enumerate() {
        let mut gen = TelemetryGen::new(topo.features, base_seed + mi as u64);
        let seed = base_seed.wrapping_add(1000) + mi as u64;
        for req in poisson_trace(&mut gen, seed, per_rate, per_n, t, anomaly_rate) {
            merged.push((mi, req));
        }
    }
    merged.sort_by(|a, b| a.1.at_s.total_cmp(&b.1.at_s));
    merged
}

/// A shifting-Poisson trace for autoscaling experiments: one global
/// Poisson arrival stream at `rate_rps`, with a **hot model** that
/// rotates every `rotate_every` requests. Each arrival goes to the
/// current hot model with probability `hot_frac`, else uniformly to one
/// of the others — so the aggregate rate is constant while the per-lane
/// load shifts phase by phase, the workload a static per-lane allocation
/// wastes threads on and an autoscaler can follow.
///
/// Deterministic for a given `base_seed` (arrivals, model choices, and
/// windows all derive from it). Windows for model `i` are drawn at that
/// model's feature width.
// Eight knobs because the trace IS the experiment configuration; callers
// pass literals at the call site, so a params struct would only add noise.
#[allow(clippy::too_many_arguments)]
pub fn rotating_hot_poisson(
    models: &[Topology],
    base_seed: u64,
    rate_rps: f64,
    n: usize,
    t: usize,
    anomaly_rate: f64,
    hot_frac: f64,
    rotate_every: usize,
) -> Vec<(usize, TimedRequest)> {
    assert!(!models.is_empty(), "rotating_hot_poisson needs at least one model");
    assert!(rate_rps > 0.0);
    let mut rng = Xoshiro256::seeded(base_seed.wrapping_add(2000));
    let mut gens: Vec<TelemetryGen> = models
        .iter()
        .enumerate()
        .map(|(i, m)| TelemetryGen::new(m.features, base_seed + i as u64))
        .collect();
    let kinds = super::AnomalyKind::all();
    let period = rotate_every.max(1);
    let mut at = 0.0f64;
    (0..n)
        .map(|i| {
            at += rng.exponential(rate_rps);
            let hot = (i / period) % models.len();
            let mi = if models.len() == 1 || rng.next_f64() < hot_frac {
                hot
            } else {
                // Uniform over the non-hot models.
                let mut j = rng.below(models.len() as u64 - 1) as usize;
                if j >= hot {
                    j += 1;
                }
                j
            };
            let window = if rng.next_f64() < anomaly_rate {
                gens[mi].anomalous_window(t, kinds[rng.below(4) as usize])
            } else {
                gens[mi].benign_window(t)
            };
            (mi, TimedRequest { at_s: at, window, id: i as u64 })
        })
        .collect()
}

/// A Zipf-skewed repeat-heavy trace for score-cache experiments: one
/// global Poisson arrival stream at `total_rate`, each arrival routed
/// uniformly to a model and drawing its window from that model's fixed
/// pool of `pool` pre-generated benign windows with Zipf(`s`) rank
/// probabilities — rank `k` (1-based) arrives with probability
/// `∝ 1/k^s`. At `s ≈ 1.1` the head ranks dominate, so identical
/// windows repeat constantly: the periodic-sensor / retry-storm /
/// dashboard-fan-out shape an exact-match cache exists for.
///
/// Deterministic for a given `base_seed`: pools derive from
/// `base_seed + i` per model (the [`merged_poisson`] convention), the
/// arrival/rank stream from `base_seed + 3000`. Windows for model `i`
/// are drawn at that model's feature width.
pub fn zipf_poisson(
    models: &[Topology],
    base_seed: u64,
    total_rate: f64,
    total_n: usize,
    t: usize,
    pool: usize,
    s: f64,
) -> Vec<(usize, TimedRequest)> {
    assert!(!models.is_empty(), "zipf_poisson needs at least one model");
    assert!(total_rate > 0.0 && pool >= 1);
    let pools: Vec<Vec<Window>> = models
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let mut gen = TelemetryGen::new(m.features, base_seed + i as u64);
            (0..pool).map(|_| gen.benign_window(t)).collect()
        })
        .collect();
    // Zipf CDF over ranks (unnormalized; draws scale by the total mass).
    let mut cdf = Vec::with_capacity(pool);
    let mut acc = 0.0f64;
    for k in 0..pool {
        acc += 1.0 / ((k + 1) as f64).powf(s);
        cdf.push(acc);
    }
    let total = acc;
    let mut rng = Xoshiro256::seeded(base_seed.wrapping_add(3000));
    let mut at = 0.0f64;
    (0..total_n)
        .map(|i| {
            at += rng.exponential(total_rate);
            let mi = rng.below(models.len() as u64) as usize;
            let u = rng.next_f64() * total;
            let rank = cdf.partition_point(|&c| c < u).min(pool - 1);
            (mi, TimedRequest { at_s: at, window: pools[mi][rank].clone(), id: i as u64 })
        })
        .collect()
}

/// A two-phase surge trace for fleet-autoscaling experiments: one global
/// Poisson arrival stream whose rate starts at `surge_rate` for the
/// first `n_surge` requests (the burst that sheds on an undersized
/// fleet — fleet-wide shed deltas argue Up) and then drops to
/// `quiet_rate` for the remaining `n_quiet` (the cool-down during which
/// an oversized fleet sits idle — quiet ticks argue Down). Arrivals are
/// routed uniformly across `models`; windows are benign, drawn per model
/// at its feature width from `base_seed + i` generators (the
/// [`merged_poisson`] convention), so replaying the same trace against
/// fleets of different sizes offers byte-identical windows — the
/// bit-identity comparisons in `tests/integration_fleetscale.rs` depend
/// on that.
///
/// Deterministic for a given `base_seed`; ids are sequential across both
/// phases.
pub fn surge_poisson(
    models: &[Topology],
    base_seed: u64,
    surge_rate: f64,
    quiet_rate: f64,
    n_surge: usize,
    n_quiet: usize,
    t: usize,
) -> Vec<(usize, TimedRequest)> {
    assert!(!models.is_empty(), "surge_poisson needs at least one model");
    assert!(surge_rate > 0.0 && quiet_rate > 0.0);
    let mut rng = Xoshiro256::seeded(base_seed.wrapping_add(4000));
    let mut gens: Vec<TelemetryGen> = models
        .iter()
        .enumerate()
        .map(|(i, m)| TelemetryGen::new(m.features, base_seed + i as u64))
        .collect();
    let mut at = 0.0f64;
    (0..n_surge + n_quiet)
        .map(|i| {
            let rate = if i < n_surge { surge_rate } else { quiet_rate };
            at += rng.exponential(rate);
            let mi = rng.below(models.len() as u64) as usize;
            let window = gens[mi].benign_window(t);
            (mi, TimedRequest { at_s: at, window, id: i as u64 })
        })
        .collect()
}

/// One event in a multi-stream session trace ([`multi_stream_trace`]).
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// Open the session (`window == 0` → the lane's default).
    Open {
        /// Trailing score window in samples.
        window: usize,
    },
    /// One telemetry sample at the stream's model feature width.
    Sample(Vec<f32>),
    /// Close the session, releasing its table slot.
    Close,
}

/// A timed event on one stream of a multi-stream trace.
#[derive(Clone, Debug)]
pub struct TimedStreamEvent {
    /// Arrival offset from trace start, seconds.
    pub at_s: f64,
    /// Session id (unique across the trace).
    pub stream: u64,
    /// Index into the driver's `models` slice.
    pub model: usize,
    pub event: StreamEvent,
}

/// A multi-stream session trace: `streams` concurrent low-rate sessions
/// (stream `i` on model `i % models.len()`), each an independent Poisson
/// arrival process at `rate_hz` samples/second carrying
/// `samples_per_stream` samples between an `Open` and a `Close`. Sample
/// rows come from each stream's own [`TelemetryGen`] (so benign drift
/// accumulates per stream), with anomaly **bursts**: at probability
/// `anomaly_rate` a stream enters a short burst of anomalous samples of
/// one kind — the shape that drives a session's recalibrated threshold,
/// unlike isolated single-sample blips. Deterministic for a given
/// `base_seed`; events come back merged in arrival order, each stream's
/// `Open` strictly before its samples and its `Close` strictly after.
pub fn multi_stream_trace(
    models: &[Topology],
    base_seed: u64,
    streams: usize,
    rate_hz: f64,
    samples_per_stream: usize,
    anomaly_rate: f64,
) -> Vec<TimedStreamEvent> {
    assert!(!models.is_empty(), "multi_stream_trace needs at least one model");
    assert!(rate_hz > 0.0 && streams >= 1);
    let kinds = super::AnomalyKind::all();
    let mut events = Vec::with_capacity(streams * (samples_per_stream + 2));
    for i in 0..streams {
        let mi = i % models.len();
        let mut gen = TelemetryGen::new(models[mi].features, base_seed + 7000 + i as u64);
        let mut rng = Xoshiro256::seeded(base_seed + 9000 + i as u64);
        // Stagger opens uniformly over one mean inter-arrival so a
        // thousand streams don't all open at t = 0.
        let mut at = rng.next_f64() / rate_hz;
        let stream = i as u64;
        events.push(TimedStreamEvent {
            at_s: at,
            stream,
            model: mi,
            event: StreamEvent::Open { window: 0 },
        });
        let mut burst = 0usize;
        let mut kind = kinds[0];
        for _ in 0..samples_per_stream {
            at += rng.exponential(rate_hz);
            let row = if burst > 0 {
                burst -= 1;
                gen.anomalous_window(1, kind).data.remove(0)
            } else if rng.next_f64() < anomaly_rate {
                kind = kinds[rng.below(4) as usize];
                burst = 2;
                gen.anomalous_window(1, kind).data.remove(0)
            } else {
                gen.benign_window(1).data.remove(0)
            };
            events.push(TimedStreamEvent {
                at_s: at,
                stream,
                model: mi,
                event: StreamEvent::Sample(row),
            });
        }
        events.push(TimedStreamEvent {
            at_s: at + 1e-3,
            stream,
            model: mi,
            event: StreamEvent::Close,
        });
    }
    // Stable by arrival time: within a stream, times are strictly
    // increasing, so Open/samples/Close keep their relative order.
    events.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
    events
}

/// Outcome of an open-loop async replay ([`replay_async`]). Admission
/// accounting is exhaustive: `accepted + shed + rejected` equals the
/// trace length, and after the trailing drain `completed + failed`
/// equals `accepted`.
#[derive(Clone, Debug, Default)]
pub struct AsyncReplayStats {
    /// Requests the lanes admitted (a ticket was issued).
    pub accepted: u64,
    /// Requests shed at admission ([`SubmitError::Overloaded`]).
    pub shed: u64,
    /// Requests rejected for any other reason (lane closed mid-replay).
    pub rejected: u64,
    /// Tickets that resolved to a scored response.
    pub completed: u64,
    /// Tickets poisoned `Closed` (possible only after worker loss).
    pub failed: u64,
    /// Responses flagged as anomalies.
    pub flagged: u64,
    /// Peak simultaneously-outstanding tickets — the figure a blocking
    /// replay cannot exceed without one parked thread per request.
    pub max_outstanding: usize,
}

fn reap_replay(stats: &mut AsyncReplayStats, outcome: crate::server::Completion) {
    match outcome {
        Ok(r) => {
            stats.completed += 1;
            if r.is_anomaly {
                stats.flagged += 1;
            }
        }
        Err(_) => stats.failed += 1,
    }
}

/// Replay a merged trace (from [`merged_poisson`] /
/// [`rotating_hot_poisson`]) open-loop through the async ticket front:
/// one submitter thread honors every arrival time and never blocks on a
/// response — completions drain opportunistically between arrivals
/// through a [`CompletionSet`] and fully at the end. `models[i]` names
/// the lane for model index `i` in the trace.
///
/// This is the process-edge analogue of the paper's always-busy pipeline
/// stages: with the blocking surface, an open-loop replay needs a parked
/// thread per in-flight request to keep submitting on time; through
/// tickets the submitter alone sustains the entire backlog
/// (`max_outstanding` reports how deep it got).
pub fn replay_async<S: ServingSurface>(
    surface: &S,
    models: &[String],
    trace: Vec<(usize, TimedRequest)>,
) -> AsyncReplayStats {
    assert!(!models.is_empty(), "replay_async needs at least one model");
    let start = Instant::now();
    let mut set = CompletionSet::new();
    let mut stats = AsyncReplayStats::default();
    for (mi, req) in trace {
        let target = Duration::from_secs_f64(req.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        // Open loop: drain whatever has completed, without blocking.
        while let Some((_, outcome)) = set.try_next() {
            reap_replay(&mut stats, outcome);
        }
        match surface.submit_async(&models[mi], req.window) {
            Ok(ticket) => {
                stats.accepted += 1;
                set.add(mi as u64, ticket);
                stats.max_outstanding = stats.max_outstanding.max(set.pending());
            }
            Err(SubmitError::Overloaded) => stats.shed += 1,
            Err(_) => stats.rejected += 1,
        }
    }
    while let Some((_, outcome)) = set.wait() {
        reap_replay(&mut stats, outcome);
    }
    stats
}

/// Outcome of a closed-loop driver run ([`closed_loop_blocking`] /
/// [`closed_loop_async`]).
#[derive(Clone, Debug, Default)]
pub struct ClosedLoopStats {
    /// Requests that completed with a scored response.
    pub completed: u64,
    /// Tickets poisoned `Closed` (possible only after worker loss).
    pub failed: u64,
    /// Overloaded rejections the driver absorbed by backing off and
    /// retrying (closed loop: shed work is re-offered, not lost).
    pub shed_retries: u64,
    /// Peak simultaneously-outstanding requests, summed across client
    /// threads: `clients` for the blocking driver, up to
    /// `clients × outstanding_per_client` for the async one.
    pub max_outstanding: usize,
    /// Wall-clock time of the whole run.
    pub wall: Duration,
}

/// Per-client telemetry generators, one per model, deterministically
/// seeded so driver runs are reproducible. The drivers draw windows at
/// each model's feature width, so `models` must be canonical topology
/// names (the [`crate::server::ModelRegistry::paper_fleet`] convention)
/// — a name the
/// topology table doesn't know would silently generate wrong-width
/// windows, so it panics instead.
fn client_gens(models: &[String], client: usize, base_seed: u64) -> Vec<TelemetryGen> {
    models
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let features = Topology::from_name(m).map(|t| t.features).unwrap_or_else(|_| {
                panic!(
                    "closed-loop drivers need canonical topology lane names \
                     (to size windows): unknown model {m:?}"
                )
            });
            TelemetryGen::new(features, base_seed + (client * 131 + i) as u64)
        })
        .collect()
}

/// Closed-loop **blocking** driver: `clients` threads round-robin
/// benign windows across `models` (canonical topology names), each
/// holding exactly one request in flight (`score_blocking`), serving
/// exactly `total` requests split evenly across threads (remainder to
/// the first ones). The baseline the async driver is compared against
/// at equal client-thread count.
pub fn closed_loop_blocking<S: ServingSurface>(
    surface: &S,
    models: &[String],
    clients: usize,
    total: usize,
    t: usize,
    base_seed: u64,
) -> ClosedLoopStats {
    assert!(!models.is_empty(), "closed_loop_blocking needs at least one model");
    let clients = clients.max(1);
    // First `total % clients` threads take one extra request, so the run
    // serves exactly `total` — no silently dropped remainder.
    let (base, extra) = ((total / clients) as u64, total % clients);
    let start = Instant::now();
    let mut stats = ClosedLoopStats::default();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || {
                    let quota = base + u64::from(c < extra);
                    let mut gens = client_gens(models, c, base_seed);
                    let (mut completed, mut shed) = (0u64, 0u64);
                    for k in 0..quota as usize {
                        let mi = (c + k) % models.len();
                        loop {
                            let w = gens[mi].benign_window(t);
                            match surface.score_blocking(&models[mi], w) {
                                Ok(_) => {
                                    completed += 1;
                                    break;
                                }
                                Err(SubmitError::Overloaded) => {
                                    shed += 1;
                                    std::thread::sleep(Duration::from_micros(200));
                                }
                                Err(e) => panic!("closed-loop submit: {e}"),
                            }
                        }
                    }
                    (completed, 0u64, shed, 1usize)
                })
            })
            .collect();
        for h in handles {
            let (c, f, sh, mo) = h.join().expect("client thread");
            stats.completed += c;
            stats.failed += f;
            stats.shed_retries += sh;
            stats.max_outstanding += mo;
        }
    });
    stats.wall = start.elapsed();
    stats
}

/// Closed-loop **async** driver: `clients` threads, each keeping up to
/// `outstanding_per_client` tickets in flight through a
/// [`CompletionSet`] (submit until the target is reached, reap one,
/// submit again), serving exactly `total` requests split evenly across
/// threads (remainder to the first ones). The same thread count as
/// [`closed_loop_blocking`] therefore sustains
/// `outstanding_per_client ×` the outstanding work — the fleet-scale
/// property `fleet --async` demonstrates and `benches/hotpath.rs`
/// tracks.
pub fn closed_loop_async<S: ServingSurface>(
    surface: &S,
    models: &[String],
    clients: usize,
    outstanding_per_client: usize,
    total: usize,
    t: usize,
    base_seed: u64,
) -> ClosedLoopStats {
    assert!(!models.is_empty(), "closed_loop_async needs at least one model");
    let clients = clients.max(1);
    let target = outstanding_per_client.max(1);
    // First `total % clients` threads take one extra request, so the run
    // serves exactly `total` — no silently dropped remainder.
    let (base, extra) = ((total / clients) as u64, total % clients);
    let start = Instant::now();
    let mut stats = ClosedLoopStats::default();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || {
                    let quota = base + u64::from(c < extra);
                    let mut gens = client_gens(models, c, base_seed);
                    let mut set = CompletionSet::new();
                    let (mut submitted, mut completed, mut failed, mut shed) =
                        (0u64, 0u64, 0u64, 0u64);
                    let mut max_out = 0usize;
                    let mut k = 0usize;
                    while completed + failed < quota {
                        while set.pending() < target && submitted < quota {
                            let mi = (c + k) % models.len();
                            let w = gens[mi].benign_window(t);
                            match surface.submit_async(&models[mi], w) {
                                Ok(ticket) => {
                                    set.add(mi as u64, ticket);
                                    submitted += 1;
                                    k += 1;
                                    max_out = max_out.max(set.pending());
                                }
                                Err(SubmitError::Overloaded) => {
                                    // Back off into reaping: completions
                                    // free queue slots.
                                    shed += 1;
                                    break;
                                }
                                Err(e) => panic!("closed-loop submit: {e}"),
                            }
                        }
                        match set.wait() {
                            Some((_, Ok(_))) => completed += 1,
                            Some((_, Err(SubmitError::Overloaded))) => {
                                // A remote shard shed after local
                                // acceptance (cross-shard backpressure):
                                // closed loop re-offers, same as a
                                // submit-time shed.
                                submitted -= 1;
                                shed += 1;
                            }
                            Some((_, Err(_))) => failed += 1,
                            // Nothing in flight (every submit shed):
                            // brief backoff before re-offering.
                            None => std::thread::sleep(Duration::from_micros(200)),
                        }
                    }
                    (completed, failed, shed, max_out)
                })
            })
            .collect();
        for h in handles {
            let (c, f, sh, mo) = h.join().expect("client thread");
            stats.completed += c;
            stats.failed += f;
            stats.shed_retries += sh;
            stats.max_outstanding += mo;
        }
    });
    stats.wall = start.elapsed();
    stats
}

/// Outcome of a [`replay_fleet`] run. The accounting is exhaustive and
/// conserved: every trace entry terminates in exactly one of
/// `completed` / `shed` / `rejected_closed`, which
/// [`FleetReplayStats::conserves`] checks — the invariant the CI
/// loopback-soak job fails on.
#[derive(Clone, Debug, Default)]
pub struct FleetReplayStats {
    /// Trace entries driven (the accounting denominator).
    pub offered: u64,
    /// Entries that resolved to a scored response.
    pub completed: u64,
    /// Entries shed by backpressure — at submit time
    /// ([`SubmitError::Overloaded`]) or by a remote shard's `Shed` frame
    /// after local acceptance. Terminal: an open-loop driver reports
    /// shed work, it does not re-offer it.
    pub shed: u64,
    /// Entries lost to a closed lane/connection with no shard left to
    /// fail over to (zero on a healthy run — the soak's red flag).
    pub rejected_closed: u64,
    /// `Closed` outcomes survived by a successful re-offer — a ticket
    /// re-routed to a surviving shard, or a first offer that rode out a
    /// momentarily unroutable fleet on the grace schedule (the zero-loss
    /// failover path; each retried entry still terminates in exactly one
    /// bucket above).
    pub retried_closed: u64,
    /// Responses flagged as anomalies.
    pub flagged: u64,
    /// Peak simultaneously-outstanding tickets.
    pub max_outstanding: usize,
    /// Wall-clock time of the whole replay (pacing + trailing drain).
    pub wall: Duration,
}

impl FleetReplayStats {
    /// The conservation law: `offered == completed + shed +
    /// rejected_closed`. A false return means the fabric lost or
    /// double-counted work — the bug class the soak exists to catch.
    pub fn conserves(&self) -> bool {
        self.offered == self.completed + self.shed + self.rejected_closed
    }
}

/// Replay a merged trace open-loop through any [`ServingSurface`] with
/// full conservation accounting — the driver behind `fleet connect` and
/// the CI loopback soak.
///
/// One submitter honors every arrival time; completions drain between
/// arrivals and fully at the end. When `retry_closed` is set, a ticket
/// that resolves `Err(Closed)` (its shard died with the request in
/// flight) is re-offered through the surface — against a
/// [`crate::server::ShardRouter`] that re-routes to a surviving shard,
/// so killing a shard mid-trace loses zero tickets
/// (`tests/integration_shard.rs` pins that down). A submit-time `Closed`
/// — the whole fleet momentarily unroutable, the kill→restart window on
/// a small fleet — is retried through a short back-off schedule
/// ([`SUBMIT_GRACE_MS`], ~0.9 s) before it counts as lost, which is what
/// lets a trace ride out a full restart cycle with zero
/// `rejected_closed`. Retries are bounded per entry
/// ([`CLOSED_RETRY_BUDGET`]), a re-offer that exhausts its grace is
/// terminal, and one fully failed schedule latches fast-fail, so the
/// retry path can never spin — not even against a fleet that is down
/// for good.
pub fn replay_fleet<S: ServingSurface>(
    surface: &S,
    models: &[String],
    trace: Vec<(usize, TimedRequest)>,
    retry_closed: bool,
) -> FleetReplayStats {
    assert!(!models.is_empty(), "replay_fleet needs at least one model");
    let start = Instant::now();
    let mut d = FleetDriver {
        surface,
        models,
        retry_closed,
        fast_fail: false,
        set: CompletionSet::new(),
        inflight: HashMap::new(),
        stats: FleetReplayStats::default(),
        next_key: 0,
    };
    for (mi, req) in trace {
        d.stats.offered += 1;
        let target = Duration::from_secs_f64(req.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        // Open loop: drain whatever has completed, without blocking.
        while let Some((key, outcome)) = d.set.try_next() {
            d.settle(key, outcome);
        }
        d.offer(mi, req.window);
    }
    // Trailing drain; settled Closed outcomes may re-enter the set, so
    // wait() (which returns None exactly at zero outstanding) is the
    // loop condition.
    while let Some((key, outcome)) = d.set.wait() {
        d.settle(key, outcome);
    }
    debug_assert!(d.inflight.is_empty(), "drained replay leaves no in-flight entries");
    d.stats.wall = start.elapsed();
    d.stats
}

/// Most times one [`replay_fleet`] entry is re-offered after a `Closed`
/// outcome before it is declared lost. A genuine shard death costs one
/// retry (the router re-routes to a survivor); the budget exists for the
/// degenerate fleet whose connections stay up while every lane answers
/// `Closed` — without it, retry-on-Closed would spin forever there.
pub const CLOSED_RETRY_BUDGET: u32 = 8;

/// Back-off schedule (ms) for offering into a fleet that is *momentarily*
/// fully unroutable — every shard dead, draining, or mid-reconnect, the
/// exact shape of a kill→restart cycle on a small fleet. ~0.9 s total:
/// enough for the router's health tick to redial a restarted shard,
/// short enough that a genuinely dead fleet fails the run quickly (and
/// after one fully failed schedule the driver latches fast-fail, so a
/// dead fleet costs the schedule once, not per entry).
const SUBMIT_GRACE_MS: [u64; 5] = [5, 25, 100, 250, 500];

/// One in-flight [`replay_fleet`] entry: model index, the window (kept
/// so a `Closed` outcome can be re-offered verbatim), and how many
/// re-offers it has already consumed. Bounded by the in-flight count —
/// entries leave at terminal outcomes.
struct InflightEntry {
    mi: usize,
    window: Window,
    retries: u32,
}

/// [`replay_fleet`]'s working state: the completion set, the in-flight
/// entries, and the running accounting.
struct FleetDriver<'a, S: ServingSurface> {
    surface: &'a S,
    models: &'a [String],
    retry_closed: bool,
    /// Latched after one fully failed grace schedule: the fleet looks
    /// permanently dead, so later offers fail fast instead of sleeping
    /// through the schedule per entry. Any accepted submit resets it.
    fast_fail: bool,
    set: CompletionSet,
    inflight: HashMap<u64, InflightEntry>,
    stats: FleetReplayStats,
    next_key: u64,
}

impl<S: ServingSurface> FleetDriver<'_, S> {
    /// Submit with churn grace: `Err(Closed)` at submit time means the
    /// whole fleet is unroutable *right now* — which, mid kill→restart,
    /// is a transient the router's redial loop fixes within the
    /// [`SUBMIT_GRACE_MS`] schedule. Returns the final outcome and
    /// whether any grace retry was consumed (so the caller can count the
    /// entry as a survived-`Closed` retry, keeping churn observable).
    fn submit_graced(&mut self, mi: usize, window: &Window) -> (Result<Ticket, SubmitError>, bool) {
        let mut outcome = self.surface.submit_async(&self.models[mi], window.clone());
        let mut graced = false;
        if self.retry_closed && !self.fast_fail {
            for ms in SUBMIT_GRACE_MS {
                if !matches!(outcome, Err(SubmitError::Closed)) {
                    break;
                }
                graced = true;
                std::thread::sleep(Duration::from_millis(ms));
                outcome = self.surface.submit_async(&self.models[mi], window.clone());
            }
        }
        match &outcome {
            // A full schedule without one acceptance: stop paying it.
            Err(SubmitError::Closed) if graced => self.fast_fail = true,
            Ok(_) => self.fast_fail = false,
            _ => {}
        }
        (outcome, graced)
    }

    /// First offer of a trace entry.
    fn offer(&mut self, mi: usize, window: Window) {
        let (outcome, graced) = self.submit_graced(mi, &window);
        match outcome {
            Ok(ticket) => {
                if graced {
                    self.stats.retried_closed += 1;
                }
                let key = self.next_key;
                self.next_key += 1;
                self.inflight.insert(key, InflightEntry { mi, window, retries: 0 });
                self.set.add(key, ticket);
                self.stats.max_outstanding = self.stats.max_outstanding.max(self.set.pending());
            }
            Err(SubmitError::Overloaded) => self.stats.shed += 1,
            Err(_) => self.stats.rejected_closed += 1,
        }
    }

    /// One outcome for the entry under `key`: terminal, or (for `Closed`
    /// with retry enabled and budget left) re-offered through the
    /// surface — against a ShardRouter that re-routes to a surviving
    /// shard. Only `Closed` is retried: it means the serving connection
    /// died, which a re-route can actually fix. A persistent per-request
    /// verdict (Overloaded, UnknownModel, Cancelled, TooLarge) is
    /// terminal — re-offering it would just reproduce the same answer.
    fn settle(&mut self, key: u64, outcome: crate::server::Completion) {
        let entry = self.inflight.remove(&key).expect("every key has an in-flight entry");
        match outcome {
            Ok(r) => {
                self.stats.completed += 1;
                if r.is_anomaly {
                    self.stats.flagged += 1;
                }
            }
            Err(SubmitError::Overloaded) => self.stats.shed += 1,
            Err(SubmitError::Closed)
                if self.retry_closed && entry.retries < CLOSED_RETRY_BUDGET =>
            {
                let (outcome, _) = self.submit_graced(entry.mi, &entry.window);
                match outcome {
                    Ok(ticket) => {
                        self.stats.retried_closed += 1;
                        self.inflight.insert(
                            key,
                            InflightEntry { retries: entry.retries + 1, ..entry },
                        );
                        self.set.add(key, ticket);
                    }
                    Err(SubmitError::Overloaded) => self.stats.shed += 1,
                    Err(_) => self.stats.rejected_closed += 1,
                }
            }
            Err(_) => self.stats.rejected_closed += 1,
        }
    }
}

/// Outcome of a [`replay_streams`] run. `fleet` carries the sample
/// accounting (opens and closes are control traffic, outside the
/// conservation law): every `Sample` event terminates in exactly one of
/// `completed` / `shed` / `rejected_closed`, checked by
/// [`FleetReplayStats::conserves`] exactly like the window driver.
#[derive(Clone, Debug, Default)]
pub struct StreamReplayStats {
    /// Per-sample accounting, conservation law included. `offered`
    /// counts samples only.
    pub fleet: FleetReplayStats,
    /// Sessions the driver had to re-open after
    /// [`SubmitError::UnknownStream`] — the serving side lost the state
    /// (eviction, restart) and the affected stream restarted from zero.
    pub resets: u64,
    /// `Open` events the surface accepted.
    pub opened: u64,
    /// `Close` events driven.
    pub closed: u64,
}

/// One in-flight [`replay_streams`] sample, kept so `Closed` outcomes
/// can be re-offered (the re-offer rides the surface's failover path —
/// against a [`crate::server::ShardRouter`], a reopen on a surviving
/// shard with reset state).
struct StreamEntry {
    stream: u64,
    mi: usize,
    sample: Vec<f32>,
    retries: u32,
}

/// [`replay_streams`]'s working state — the session-aware sibling of
/// [`FleetDriver`], with the same grace schedule and retry budget.
struct StreamDriver<'a, S: ServingSurface> {
    surface: &'a S,
    models: &'a [String],
    retry_closed: bool,
    /// Latched after one fully failed grace schedule, reset by any
    /// accepted submit — see [`FleetDriver::fast_fail`].
    fast_fail: bool,
    set: CompletionSet,
    inflight: HashMap<u64, StreamEntry>,
    stats: StreamReplayStats,
    next_key: u64,
}

impl<S: ServingSurface> StreamDriver<'_, S> {
    /// One submit with driver-side session-loss recovery folded in:
    /// `UnknownStream` re-opens the session at the lane default and
    /// retries once, counted as a reset (the stream's history restarts
    /// from zero — observable, never silent).
    fn submit_once(
        &mut self,
        mi: usize,
        stream: u64,
        sample: &[f32],
    ) -> Result<Ticket, SubmitError> {
        match self.surface.submit_sample(&self.models[mi], stream, sample.to_vec()) {
            Err(SubmitError::UnknownStream(_)) => {
                self.stats.resets += 1;
                self.surface.open_stream(&self.models[mi], stream, 0)?;
                self.surface.submit_sample(&self.models[mi], stream, sample.to_vec())
            }
            other => other,
        }
    }

    /// Submit with the same churn grace as [`FleetDriver::submit_graced`]:
    /// a momentarily unroutable fleet gets the back-off schedule before a
    /// sample counts as lost.
    fn submit_graced(
        &mut self,
        mi: usize,
        stream: u64,
        sample: &[f32],
    ) -> (Result<Ticket, SubmitError>, bool) {
        let mut outcome = self.submit_once(mi, stream, sample);
        let mut graced = false;
        if self.retry_closed && !self.fast_fail {
            for ms in SUBMIT_GRACE_MS {
                if !matches!(outcome, Err(SubmitError::Closed)) {
                    break;
                }
                graced = true;
                std::thread::sleep(Duration::from_millis(ms));
                outcome = self.submit_once(mi, stream, sample);
            }
        }
        match &outcome {
            Err(SubmitError::Closed) if graced => self.fast_fail = true,
            Ok(_) => self.fast_fail = false,
            _ => {}
        }
        (outcome, graced)
    }

    /// Open with the same grace (opens are cheap control traffic, but a
    /// kill→restart window would otherwise orphan every stream opened
    /// during it).
    fn open(&mut self, mi: usize, stream: u64, window: usize) {
        let mut outcome = self.surface.open_stream(&self.models[mi], stream, window);
        if self.retry_closed && !self.fast_fail {
            for ms in SUBMIT_GRACE_MS {
                if !matches!(outcome, Err(SubmitError::Closed)) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(ms));
                outcome = self.surface.open_stream(&self.models[mi], stream, window);
            }
        }
        if outcome.is_ok() {
            self.stats.opened += 1;
        }
    }

    /// First offer of one sample.
    fn offer(&mut self, mi: usize, stream: u64, sample: Vec<f32>) {
        let (outcome, graced) = self.submit_graced(mi, stream, &sample);
        match outcome {
            Ok(ticket) => {
                if graced {
                    self.stats.fleet.retried_closed += 1;
                }
                let key = self.next_key;
                self.next_key += 1;
                self.inflight.insert(key, StreamEntry { stream, mi, sample, retries: 0 });
                self.set.add(key, ticket);
                self.stats.fleet.max_outstanding =
                    self.stats.fleet.max_outstanding.max(self.set.pending());
            }
            Err(SubmitError::Overloaded) => self.stats.fleet.shed += 1,
            Err(_) => self.stats.fleet.rejected_closed += 1,
        }
    }

    /// One outcome for the sample under `key` — the exact settle logic of
    /// [`FleetDriver::settle`], sample-shaped.
    fn settle(&mut self, key: u64, outcome: crate::server::Completion) {
        let entry = self.inflight.remove(&key).expect("every key has an in-flight entry");
        match outcome {
            Ok(r) => {
                self.stats.fleet.completed += 1;
                if r.is_anomaly {
                    self.stats.fleet.flagged += 1;
                }
            }
            Err(SubmitError::Overloaded) => self.stats.fleet.shed += 1,
            Err(SubmitError::Closed)
                if self.retry_closed && entry.retries < CLOSED_RETRY_BUDGET =>
            {
                let (outcome, _) = self.submit_graced(entry.mi, entry.stream, &entry.sample);
                match outcome {
                    Ok(ticket) => {
                        self.stats.fleet.retried_closed += 1;
                        self.inflight
                            .insert(key, StreamEntry { retries: entry.retries + 1, ..entry });
                        self.set.add(key, ticket);
                    }
                    Err(SubmitError::Overloaded) => self.stats.fleet.shed += 1,
                    Err(_) => self.stats.fleet.rejected_closed += 1,
                }
            }
            Err(_) => self.stats.fleet.rejected_closed += 1,
        }
    }
}

/// Replay a multi-stream session trace ([`multi_stream_trace`])
/// open-loop through any [`ServingSurface`] — the driver behind
/// `fleet serve --streams` / `fleet connect --streams` and the streaming
/// half of the CI loopback soak.
///
/// One submitter honors every arrival time; sample completions drain
/// between events and fully at the end. Conservation covers samples
/// (`Open`/`Close` are control traffic): `offered == completed + shed +
/// rejected_closed` on the embedded [`FleetReplayStats`]. With
/// `retry_closed` set, the driver rides out shard churn exactly like
/// [`replay_fleet`] — and additionally recovers `UnknownStream` by
/// re-opening the session (counted in
/// [`StreamReplayStats::resets`]): after a kill −9 restart every stream
/// keeps scoring, from freshly zeroed state.
pub fn replay_streams<S: ServingSurface>(
    surface: &S,
    models: &[String],
    trace: Vec<TimedStreamEvent>,
    retry_closed: bool,
) -> StreamReplayStats {
    assert!(!models.is_empty(), "replay_streams needs at least one model");
    let start = Instant::now();
    let mut d = StreamDriver {
        surface,
        models,
        retry_closed,
        fast_fail: false,
        set: CompletionSet::new(),
        inflight: HashMap::new(),
        stats: StreamReplayStats::default(),
        next_key: 0,
    };
    for ev in trace {
        let target = Duration::from_secs_f64(ev.at_s);
        if let Some(sleep) = target.checked_sub(start.elapsed()) {
            std::thread::sleep(sleep);
        }
        // Open loop: drain whatever has completed, without blocking.
        while let Some((key, outcome)) = d.set.try_next() {
            d.settle(key, outcome);
        }
        match ev.event {
            StreamEvent::Open { window } => d.open(ev.model, ev.stream, window),
            StreamEvent::Sample(sample) => {
                d.stats.fleet.offered += 1;
                d.offer(ev.model, ev.stream, sample);
            }
            StreamEvent::Close => {
                d.surface.close_stream(&d.models[ev.model], ev.stream);
                d.stats.closed += 1;
            }
        }
    }
    while let Some((key, outcome)) = d.set.wait() {
        d.settle(key, outcome);
    }
    debug_assert!(d.inflight.is_empty(), "drained replay leaves no in-flight entries");
    d.stats.fleet.wall = start.elapsed();
    d.stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_close() {
        let mut g = TelemetryGen::new(8, 1);
        let trace = poisson_trace(&mut g, 2, 500.0, 2000, 4, 0.0);
        let span = trace.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((rate - 500.0).abs() < 50.0, "rate {rate}");
        // Arrivals sorted, ids sequential.
        for (i, w) in trace.windows(2).enumerate() {
            assert!(w[1].at_s >= w[0].at_s, "at {i}");
        }
    }

    #[test]
    fn anomaly_rate_respected_in_trace() {
        let mut g = TelemetryGen::new(8, 1);
        let trace = poisson_trace(&mut g, 3, 100.0, 1000, 4, 0.25);
        let anomalous = trace.iter().filter(|r| r.window.anomaly.is_some()).count();
        assert!((180..320).contains(&anomalous), "{anomalous}");
    }

    #[test]
    fn merged_poisson_is_arrival_ordered_and_covers_every_model() {
        let models = Topology::paper_models();
        let merged = merged_poisson(&models, 5, 4000.0, 200, 4, 0.1);
        assert_eq!(merged.len(), 200 / models.len() * models.len());
        for w in merged.windows(2) {
            assert!(w[1].1.at_s >= w[0].1.at_s, "arrivals must be sorted");
        }
        for (mi, topo) in models.iter().enumerate() {
            let cnt = merged.iter().filter(|(i, _)| *i == mi).count();
            assert_eq!(cnt, 200 / models.len(), "{}", topo.name);
            // Windows carry that model's feature width.
            let (_, req) = merged.iter().find(|(i, _)| *i == mi).unwrap();
            assert_eq!(req.window.data[0].len(), topo.features);
        }
    }

    #[test]
    fn rotating_hot_trace_shifts_the_hot_model_each_phase() {
        let models = Topology::paper_models();
        let n = 800;
        let rotate = 200;
        let trace = rotating_hot_poisson(&models, 9, 1000.0, n, 4, 0.0, 0.8, rotate);
        assert_eq!(trace.len(), n);
        // Arrival-ordered (single global stream).
        for w in trace.windows(2) {
            assert!(w[1].1.at_s >= w[0].1.at_s);
        }
        // In each phase the hot model dominates, and the hot model is a
        // different lane each phase.
        for phase in 0..n / rotate {
            let hot = phase % models.len();
            let slice = &trace[phase * rotate..(phase + 1) * rotate];
            let hot_cnt = slice.iter().filter(|(mi, _)| *mi == hot).count();
            assert!(
                hot_cnt > rotate / 2,
                "phase {phase}: hot lane {hot} got {hot_cnt}/{rotate}"
            );
        }
        // Windows carry each model's feature width.
        for (mi, req) in &trace {
            assert_eq!(req.window.data[0].len(), models[*mi].features);
        }
    }

    #[test]
    fn rotating_hot_trace_with_full_hot_fraction_is_single_lane_per_phase() {
        let models = Topology::paper_models();
        let trace = rotating_hot_poisson(&models, 3, 500.0, 100, 2, 0.0, 1.0, 50);
        assert!(trace[..50].iter().all(|(mi, _)| *mi == 0));
        assert!(trace[50..].iter().all(|(mi, _)| *mi == 1));
    }

    #[test]
    fn zipf_trace_is_skewed_ordered_and_repeat_heavy() {
        let models = Topology::paper_models();
        let n = 2000;
        let pool = 64;
        let trace = zipf_poisson(&models, 17, 2000.0, n, 4, pool, 1.1);
        assert_eq!(trace.len(), n);
        // Arrival-ordered (single global stream).
        for w in trace.windows(2) {
            assert!(w[1].1.at_s >= w[0].1.at_s);
        }
        // Windows carry each model's feature width.
        for (mi, req) in &trace {
            assert_eq!(req.window.data[0].len(), models[*mi].features);
        }
        // Zipf head dominance: count occurrences of each distinct window
        // (by raw bits). For s = 1.1 over a pool of 64 the top rank holds
        // ~24% of the per-model mass; 15% is a comfortable floor, while a
        // uniform draw would sit near 1/64 ≈ 1.6%.
        let mut counts: HashMap<Vec<u32>, usize> = HashMap::new();
        for (mi, req) in &trace {
            let mut bits: Vec<u32> = vec![*mi as u32];
            for row in &req.window.data {
                bits.extend(row.iter().map(|v| v.to_bits()));
            }
            *counts.entry(bits).or_insert(0) += 1;
        }
        assert!(
            counts.len() < n,
            "a repeat-heavy trace must reuse windows ({} distinct of {n})",
            counts.len()
        );
        assert!(
            counts.len() > models.len(),
            "the tail must still appear ({} distinct)",
            counts.len()
        );
        let top = *counts.values().max().unwrap();
        assert!(
            top as f64 > 0.15 * (n as f64 / models.len() as f64),
            "head rank must dominate its lane: top {top} of {n} over {} models",
            models.len()
        );
    }

    #[test]
    fn surge_trace_bursts_then_cools_and_repeats_windows_across_replays() {
        let models = Topology::paper_models();
        let (n_surge, n_quiet) = (400usize, 100usize);
        let trace = surge_poisson(&models, 13, 4000.0, 50.0, n_surge, n_quiet, 4);
        assert_eq!(trace.len(), n_surge + n_quiet);
        for w in trace.windows(2) {
            assert!(w[1].1.at_s >= w[0].1.at_s, "arrivals must be sorted");
        }
        // The surge phase must be far denser than the cool-down: compare
        // mean inter-arrival spans (4000 rps vs 50 rps — a 80× gap even
        // under Poisson noise).
        let surge_span = trace[n_surge - 1].1.at_s - trace[0].1.at_s;
        let quiet_span = trace.last().unwrap().1.at_s - trace[n_surge].1.at_s;
        let surge_rate = (n_surge - 1) as f64 / surge_span;
        let quiet_rate = (n_quiet - 1) as f64 / quiet_span;
        assert!(
            surge_rate > 10.0 * quiet_rate,
            "surge {surge_rate:.0} rps vs quiet {quiet_rate:.0} rps"
        );
        // Windows carry each model's feature width.
        for (mi, req) in &trace {
            assert_eq!(req.window.data[0].len(), models[*mi].features);
        }
        // Re-generating the trace offers byte-identical windows — what
        // lets equal-offered-load fleet comparisons pin bit-identity.
        let again = surge_poisson(&models, 13, 4000.0, 50.0, n_surge, n_quiet, 4);
        for ((mi_a, a), (mi_b, b)) in trace.iter().zip(&again) {
            assert_eq!(mi_a, mi_b);
            assert_eq!(a.at_s.to_bits(), b.at_s.to_bits());
            for (ra, rb) in a.window.data.iter().zip(&b.window.data) {
                for (va, vb) in ra.iter().zip(rb) {
                    assert_eq!(va.to_bits(), vb.to_bits());
                }
            }
        }
    }

    #[test]
    fn merged_poisson_gives_every_model_at_least_one_request() {
        let models = Topology::paper_models();
        // total_n below the model count must not produce empty lanes.
        let merged = merged_poisson(&models, 1, 100.0, 1, 2, 0.0);
        assert_eq!(merged.len(), models.len());
    }

    use crate::server::ModelRegistry;

    fn one_lane_registry() -> (ModelRegistry, Vec<String>) {
        use crate::model::LstmAutoencoder;
        use crate::server::{QuantBackend, ServerConfig};
        use std::sync::Arc;
        let topo = Topology::from_name("F32-D2").unwrap();
        let mut reg = ModelRegistry::new();
        reg.register(
            &topo.name,
            Arc::new(QuantBackend::new(LstmAutoencoder::random(topo.clone(), 5))),
            ServerConfig::default(),
        );
        (reg, vec![topo.name])
    }

    #[test]
    fn replay_async_accounts_for_every_trace_entry() {
        let (reg, models) = one_lane_registry();
        let mut gen = TelemetryGen::new(32, 7);
        let trace: Vec<(usize, TimedRequest)> =
            poisson_trace(&mut gen, 11, 5000.0, 60, 4, 0.2)
                .into_iter()
                .map(|r| (0usize, r))
                .collect();
        let n = trace.len() as u64;
        let stats = replay_async(&reg, &models, trace);
        assert_eq!(stats.accepted + stats.shed + stats.rejected, n);
        assert_eq!(stats.completed + stats.failed, stats.accepted);
        assert_eq!(stats.failed, 0, "healthy lane: every accepted ticket completes");
        assert!(stats.max_outstanding >= 1);
        reg.shutdown();
    }

    #[test]
    fn replay_fleet_accounts_every_entry() {
        let (reg, models) = one_lane_registry();
        let mut gen = TelemetryGen::new(32, 7);
        let trace: Vec<(usize, TimedRequest)> = poisson_trace(&mut gen, 11, 5000.0, 80, 4, 0.1)
            .into_iter()
            .map(|r| (0usize, r))
            .collect();
        let stats = replay_fleet(&reg, &models, trace, true);
        assert_eq!(stats.offered, 80);
        assert!(stats.conserves(), "conservation must hold: {stats:?}");
        assert_eq!(stats.rejected_closed, 0, "healthy lane loses nothing");
        assert_eq!(stats.completed + stats.shed, 80);
        assert!(stats.max_outstanding >= 1);
        reg.shutdown();
    }

    #[test]
    fn closed_loop_drivers_complete_their_quota() {
        let (reg, models) = one_lane_registry();
        // 41 over 2 clients: the odd request must be served, not dropped.
        let blocking = closed_loop_blocking(&reg, &models, 2, 41, 4, 3);
        assert_eq!(blocking.completed, 41, "remainder requests are served");
        assert_eq!(blocking.max_outstanding, 2, "one in flight per client");
        let async_stats = closed_loop_async(&reg, &models, 2, 8, 41, 4, 3);
        assert_eq!(async_stats.completed, 41, "remainder requests are served");
        assert_eq!(async_stats.failed, 0);
        assert!(
            async_stats.max_outstanding > blocking.max_outstanding,
            "tickets must hold more in flight than one-per-thread"
        );
        reg.shutdown();
    }

    #[test]
    fn multi_stream_trace_is_ordered_and_covers_every_stream() {
        let models = Topology::paper_models();
        let (streams, per) = (12usize, 20usize);
        let trace = multi_stream_trace(&models, 21, streams, 50.0, per, 0.1);
        assert_eq!(trace.len(), streams * (per + 2), "open + samples + close per stream");
        for w in trace.windows(2) {
            assert!(w[1].at_s >= w[0].at_s, "events must be time-sorted");
        }
        for s in 0..streams as u64 {
            let evs: Vec<&TimedStreamEvent> =
                trace.iter().filter(|e| e.stream == s).collect();
            assert_eq!(evs.len(), per + 2);
            assert!(matches!(evs[0].event, StreamEvent::Open { .. }), "stream {s} opens first");
            assert!(
                matches!(evs.last().unwrap().event, StreamEvent::Close),
                "stream {s} closes last"
            );
            let mi = evs[0].model;
            assert_eq!(mi, s as usize % models.len(), "round-robin model assignment");
            for e in &evs[1..=per] {
                assert_eq!(e.model, mi);
                match &e.event {
                    StreamEvent::Sample(row) => {
                        assert_eq!(row.len(), models[mi].features, "sample width");
                    }
                    other => panic!("stream {s}: expected Sample, got {other:?}"),
                }
            }
        }
    }

    #[test]
    fn replay_streams_conserves_and_closes_every_session() {
        let (reg, models) = one_lane_registry();
        let topo = Topology::from_name("F32-D2").unwrap();
        let (streams, per) = (8usize, 25usize);
        let trace = multi_stream_trace(&[topo], 31, streams, 2000.0, per, 0.1);
        let stats = replay_streams(&reg, &models, trace, true);
        assert_eq!(stats.fleet.offered, (streams * per) as u64, "offered counts samples only");
        assert!(stats.fleet.conserves(), "conservation must hold: {stats:?}");
        assert_eq!(stats.fleet.rejected_closed, 0, "healthy lane loses nothing");
        assert_eq!(stats.fleet.completed + stats.fleet.shed, (streams * per) as u64);
        assert_eq!(stats.opened, streams as u64);
        assert_eq!(stats.closed, streams as u64);
        assert_eq!(stats.resets, 0, "no eviction pressure, no resets");
        reg.shutdown();
    }
}
