//! Request traces for the serving experiments: Poisson (open-loop) and
//! closed-loop arrival processes over telemetry windows, plus the
//! multi-model merge used by the fleet driver.

use super::{TelemetryGen, Window};
use crate::model::Topology;
use crate::util::rng::Xoshiro256;

/// One timed request.
#[derive(Clone, Debug)]
pub struct TimedRequest {
    /// Arrival offset from trace start, seconds.
    pub at_s: f64,
    pub window: Window,
    pub id: u64,
}

/// An open-loop Poisson trace: `rate_rps` requests/second for `n`
/// requests, windows drawn from the telemetry generator with the given
/// anomaly rate.
pub fn poisson_trace(
    gen: &mut TelemetryGen,
    seed: u64,
    rate_rps: f64,
    n: usize,
    t: usize,
    anomaly_rate: f64,
) -> Vec<TimedRequest> {
    assert!(rate_rps > 0.0);
    let mut rng = Xoshiro256::seeded(seed);
    let mut at = 0.0f64;
    let kinds = super::AnomalyKind::all();
    (0..n as u64)
        .map(|id| {
            at += rng.exponential(rate_rps);
            let window = if rng.next_f64() < anomaly_rate {
                gen.anomalous_window(t, kinds[rng.below(4) as usize])
            } else {
                gen.benign_window(t)
            };
            TimedRequest { at_s: at, window, id }
        })
        .collect()
}

/// One independent Poisson stream per model — `total_rate` split evenly,
/// `total_n` divided per lane (at least one request each) — merged into a
/// single arrival-ordered schedule of `(model index, request)`. Windows
/// for model `i` are drawn at that model's feature width with seeds
/// derived from `base_seed + i`, so the schedule is deterministic.
///
/// Shared by the `fleet` CLI subcommand and the multi-model example so
/// the mixed-traffic recipe lives in one place.
pub fn merged_poisson(
    models: &[Topology],
    base_seed: u64,
    total_rate: f64,
    total_n: usize,
    t: usize,
    anomaly_rate: f64,
) -> Vec<(usize, TimedRequest)> {
    assert!(!models.is_empty(), "merged_poisson needs at least one model");
    let per_rate = total_rate / models.len() as f64;
    let per_n = (total_n / models.len()).max(1);
    let mut merged = Vec::with_capacity(per_n * models.len());
    for (mi, topo) in models.iter().enumerate() {
        let mut gen = TelemetryGen::new(topo.features, base_seed + mi as u64);
        let seed = base_seed.wrapping_add(1000) + mi as u64;
        for req in poisson_trace(&mut gen, seed, per_rate, per_n, t, anomaly_rate) {
            merged.push((mi, req));
        }
    }
    merged.sort_by(|a, b| a.1.at_s.total_cmp(&b.1.at_s));
    merged
}

/// A shifting-Poisson trace for autoscaling experiments: one global
/// Poisson arrival stream at `rate_rps`, with a **hot model** that
/// rotates every `rotate_every` requests. Each arrival goes to the
/// current hot model with probability `hot_frac`, else uniformly to one
/// of the others — so the aggregate rate is constant while the per-lane
/// load shifts phase by phase, the workload a static per-lane allocation
/// wastes threads on and an autoscaler can follow.
///
/// Deterministic for a given `base_seed` (arrivals, model choices, and
/// windows all derive from it). Windows for model `i` are drawn at that
/// model's feature width.
pub fn rotating_hot_poisson(
    models: &[Topology],
    base_seed: u64,
    rate_rps: f64,
    n: usize,
    t: usize,
    anomaly_rate: f64,
    hot_frac: f64,
    rotate_every: usize,
) -> Vec<(usize, TimedRequest)> {
    assert!(!models.is_empty(), "rotating_hot_poisson needs at least one model");
    assert!(rate_rps > 0.0);
    let mut rng = Xoshiro256::seeded(base_seed.wrapping_add(2000));
    let mut gens: Vec<TelemetryGen> = models
        .iter()
        .enumerate()
        .map(|(i, m)| TelemetryGen::new(m.features, base_seed + i as u64))
        .collect();
    let kinds = super::AnomalyKind::all();
    let period = rotate_every.max(1);
    let mut at = 0.0f64;
    (0..n)
        .map(|i| {
            at += rng.exponential(rate_rps);
            let hot = (i / period) % models.len();
            let mi = if models.len() == 1 || rng.next_f64() < hot_frac {
                hot
            } else {
                // Uniform over the non-hot models.
                let mut j = rng.below(models.len() as u64 - 1) as usize;
                if j >= hot {
                    j += 1;
                }
                j
            };
            let window = if rng.next_f64() < anomaly_rate {
                gens[mi].anomalous_window(t, kinds[rng.below(4) as usize])
            } else {
                gens[mi].benign_window(t)
            };
            (mi, TimedRequest { at_s: at, window, id: i as u64 })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_close() {
        let mut g = TelemetryGen::new(8, 1);
        let trace = poisson_trace(&mut g, 2, 500.0, 2000, 4, 0.0);
        let span = trace.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((rate - 500.0).abs() < 50.0, "rate {rate}");
        // Arrivals sorted, ids sequential.
        for (i, w) in trace.windows(2).enumerate() {
            assert!(w[1].at_s >= w[0].at_s, "at {i}");
        }
    }

    #[test]
    fn anomaly_rate_respected_in_trace() {
        let mut g = TelemetryGen::new(8, 1);
        let trace = poisson_trace(&mut g, 3, 100.0, 1000, 4, 0.25);
        let anomalous = trace.iter().filter(|r| r.window.anomaly.is_some()).count();
        assert!((180..320).contains(&anomalous), "{anomalous}");
    }

    #[test]
    fn merged_poisson_is_arrival_ordered_and_covers_every_model() {
        let models = Topology::paper_models();
        let merged = merged_poisson(&models, 5, 4000.0, 200, 4, 0.1);
        assert_eq!(merged.len(), 200 / models.len() * models.len());
        for w in merged.windows(2) {
            assert!(w[1].1.at_s >= w[0].1.at_s, "arrivals must be sorted");
        }
        for (mi, topo) in models.iter().enumerate() {
            let cnt = merged.iter().filter(|(i, _)| *i == mi).count();
            assert_eq!(cnt, 200 / models.len(), "{}", topo.name);
            // Windows carry that model's feature width.
            let (_, req) = merged.iter().find(|(i, _)| *i == mi).unwrap();
            assert_eq!(req.window.data[0].len(), topo.features);
        }
    }

    #[test]
    fn rotating_hot_trace_shifts_the_hot_model_each_phase() {
        let models = Topology::paper_models();
        let n = 800;
        let rotate = 200;
        let trace = rotating_hot_poisson(&models, 9, 1000.0, n, 4, 0.0, 0.8, rotate);
        assert_eq!(trace.len(), n);
        // Arrival-ordered (single global stream).
        for w in trace.windows(2) {
            assert!(w[1].1.at_s >= w[0].1.at_s);
        }
        // In each phase the hot model dominates, and the hot model is a
        // different lane each phase.
        for phase in 0..n / rotate {
            let hot = phase % models.len();
            let slice = &trace[phase * rotate..(phase + 1) * rotate];
            let hot_cnt = slice.iter().filter(|(mi, _)| *mi == hot).count();
            assert!(
                hot_cnt > rotate / 2,
                "phase {phase}: hot lane {hot} got {hot_cnt}/{rotate}"
            );
        }
        // Windows carry each model's feature width.
        for (mi, req) in &trace {
            assert_eq!(req.window.data[0].len(), models[*mi].features);
        }
    }

    #[test]
    fn rotating_hot_trace_with_full_hot_fraction_is_single_lane_per_phase() {
        let models = Topology::paper_models();
        let trace = rotating_hot_poisson(&models, 3, 500.0, 100, 2, 0.0, 1.0, 50);
        assert!(trace[..50].iter().all(|(mi, _)| *mi == 0));
        assert!(trace[50..].iter().all(|(mi, _)| *mi == 1));
    }

    #[test]
    fn merged_poisson_gives_every_model_at_least_one_request() {
        let models = Topology::paper_models();
        // total_n below the model count must not produce empty lanes.
        let merged = merged_poisson(&models, 1, 100.0, 1, 2, 0.0);
        assert_eq!(merged.len(), models.len());
    }
}
