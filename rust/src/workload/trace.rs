//! Request traces for the serving experiments: Poisson (open-loop) and
//! closed-loop arrival processes over telemetry windows.

use super::{TelemetryGen, Window};
use crate::util::rng::Xoshiro256;

/// One timed request.
#[derive(Clone, Debug)]
pub struct TimedRequest {
    /// Arrival offset from trace start, seconds.
    pub at_s: f64,
    pub window: Window,
    pub id: u64,
}

/// An open-loop Poisson trace: `rate_rps` requests/second for `n`
/// requests, windows drawn from the telemetry generator with the given
/// anomaly rate.
pub fn poisson_trace(
    gen: &mut TelemetryGen,
    seed: u64,
    rate_rps: f64,
    n: usize,
    t: usize,
    anomaly_rate: f64,
) -> Vec<TimedRequest> {
    assert!(rate_rps > 0.0);
    let mut rng = Xoshiro256::seeded(seed);
    let mut at = 0.0f64;
    let kinds = super::AnomalyKind::all();
    (0..n as u64)
        .map(|id| {
            at += rng.exponential(rate_rps);
            let window = if rng.next_f64() < anomaly_rate {
                gen.anomalous_window(t, kinds[rng.below(4) as usize])
            } else {
                gen.benign_window(t)
            };
            TimedRequest { at_s: at, window, id }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_close() {
        let mut g = TelemetryGen::new(8, 1);
        let trace = poisson_trace(&mut g, 2, 500.0, 2000, 4, 0.0);
        let span = trace.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((rate - 500.0).abs() < 50.0, "rate {rate}");
        // Arrivals sorted, ids sequential.
        for (i, w) in trace.windows(2).enumerate() {
            assert!(w[1].at_s >= w[0].at_s, "at {i}");
        }
    }

    #[test]
    fn anomaly_rate_respected_in_trace() {
        let mut g = TelemetryGen::new(8, 1);
        let trace = poisson_trace(&mut g, 3, 100.0, 1000, 4, 0.25);
        let anomalous = trace.iter().filter(|r| r.window.anomaly.is_some()).count();
        assert!((180..320).contains(&anomalous), "{anomalous}");
    }
}
