//! FPGA device catalog. The paper targets the AMD Zynq UltraScale+
//! MPSoC ZCU104 board (XCZU7EV device); smaller devices are included for
//! the §4.1 claim that the RH_m-based configurability "shows potential
//! for various FPGAs, including resource-constrained embedded devices"
//! (explored by `examples/design_space.rs`).

/// Available resources of an FPGA device.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FpgaDevice {
    pub name: &'static str,
    pub luts: u64,
    pub ffs: u64,
    /// BRAM36 blocks (36 Kb each).
    pub bram36: u64,
    pub dsps: u64,
    /// Nominal kernel clock (Hz) for latency conversion.
    pub clock_hz: f64,
}

impl FpgaDevice {
    /// XCZU7EV on the ZCU104 (paper's platform, 300 MHz target).
    pub const ZCU104: FpgaDevice = FpgaDevice {
        name: "XCZU7EV (ZCU104)",
        luts: 230_400,
        ffs: 460_800,
        bram36: 312,
        dsps: 1_728,
        clock_hz: 300.0e6,
    };

    /// XCZU3EG (Ultra96-class embedded board).
    pub const ULTRA96: FpgaDevice = FpgaDevice {
        name: "XCZU3EG (Ultra96)",
        luts: 70_560,
        ffs: 141_120,
        bram36: 216,
        dsps: 360,
        clock_hz: 250.0e6,
    };

    /// XC7Z020 (PYNQ-Z2 class, older Zynq-7000).
    pub const PYNQ_Z2: FpgaDevice = FpgaDevice {
        name: "XC7Z020 (PYNQ-Z2)",
        luts: 53_200,
        ffs: 106_400,
        bram36: 140,
        dsps: 220,
        clock_hz: 142.0e6,
    };

    /// Alveo U50-class datacenter card (for headroom studies).
    pub const ALVEO_U50: FpgaDevice = FpgaDevice {
        name: "XCU50 (Alveo U50)",
        luts: 872_000,
        ffs: 1_743_000,
        bram36: 1_344,
        dsps: 5_952,
        clock_hz: 300.0e6,
    };

    pub fn catalog() -> &'static [FpgaDevice] {
        const ALL: [FpgaDevice; 4] = [
            FpgaDevice::ZCU104,
            FpgaDevice::ULTRA96,
            FpgaDevice::PYNQ_Z2,
            FpgaDevice::ALVEO_U50,
        ];
        &ALL
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zcu104_matches_datasheet() {
        let d = FpgaDevice::ZCU104;
        assert_eq!(d.luts, 230_400);
        assert_eq!(d.dsps, 1_728);
        assert_eq!(d.bram36, 312);
        assert_eq!(d.clock_hz, 300.0e6);
    }

    #[test]
    fn catalog_ordered_reasonably() {
        let c = FpgaDevice::catalog();
        assert!(c.len() >= 4);
        assert!(FpgaDevice::ALVEO_U50.dsps > FpgaDevice::ZCU104.dsps);
        assert!(FpgaDevice::ZCU104.dsps > FpgaDevice::ULTRA96.dsps);
    }
}
