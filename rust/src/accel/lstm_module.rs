//! One `LSTM_i` dataflow module (paper §3.1, Figure 2): an `MVM_X` and an
//! `MVM_H` unit running concurrently, feeding a pipelined activation +
//! element-wise unit, all coupled by internal FIFOs.
//!
//! Timing view: the module is a single-server stage with constant service
//! time `Lat_t_i = max(X_t_i, H_t_i)` (Eq 2) — MVM_X and MVM_H overlap,
//! the activation pipeline's `LH` drain is the `+LH` term of Eqs 3–4.
//! Functional view: delegates to the bit-accurate Q8.24 + PWL cell.

use super::mvm::MvmSpec;
use super::reuse::LayerHw;
use crate::fixed::Q8_24;
use crate::model::lstm::{QuantLstmCell, QuantLstmState, StepScratch};
use crate::model::weights::LayerWeights;

/// An instantiated module: hardware shape + (optionally) weights for
/// functional execution.
pub struct LstmModule {
    pub hw: LayerHw,
    pub mvm_x: MvmSpec,
    pub mvm_h: MvmSpec,
    cell: Option<QuantLstmCell>,
    state: QuantLstmState,
    scratch: StepScratch,
}

impl LstmModule {
    /// Timing-only module (no weights): used by pure latency sweeps.
    pub fn timing_only(hw: &LayerHw) -> LstmModule {
        LstmModule {
            hw: hw.clone(),
            mvm_x: MvmSpec::with_multipliers(hw.lx, hw.lh, hw.mx),
            mvm_h: MvmSpec::with_multipliers(hw.lh, hw.lh, hw.mh),
            cell: None,
            state: QuantLstmState::zeros(hw.lh),
            scratch: StepScratch::new(),
        }
    }

    /// Full module with functional datapath.
    pub fn with_weights(hw: &LayerHw, w: &LayerWeights) -> LstmModule {
        assert_eq!(hw.lx, w.dims.lx);
        assert_eq!(hw.lh, w.dims.lh);
        let mut m = Self::timing_only(hw);
        m.cell = Some(QuantLstmCell::new(w));
        m
    }

    /// Service latency per timestep (Eq 2).
    pub fn service_latency(&self) -> u64 {
        self.mvm_x.latency().max(self.mvm_h.latency())
    }

    /// Idle fraction of the *faster* MVM unit while the slower one
    /// finishes — 0 for an intra-balanced module (Eq 7's goal).
    pub fn intra_module_idle(&self) -> f64 {
        let x = self.mvm_x.latency() as f64;
        let h = self.mvm_h.latency() as f64;
        (x - h).abs() / x.max(h)
    }

    /// Reset recurrent state (start of a new sequence).
    pub fn reset(&mut self) {
        self.state.reset(self.hw.lh);
    }

    /// Process one timestep functionally; panics on timing-only modules.
    /// Runs the zero-alloc scratch kernel on the module-owned state, so
    /// the only allocation per step is the returned `h` snapshot.
    pub fn step(&mut self, x: &[Q8_24]) -> Vec<Q8_24> {
        let cell = self.cell.as_ref().expect("module has no weights loaded");
        cell.step_into(&mut self.state, x, &mut self.scratch);
        self.state.h.clone()
    }

    pub fn has_weights(&self) -> bool {
        self.cell.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::reuse::BalancedConfig;
    use crate::model::topology::{LayerDims, Topology};
    use crate::model::weights::LayerWeights;
    use crate::util::rng::Xoshiro256;

    fn f32d2() -> BalancedConfig {
        BalancedConfig::balance(&Topology::from_name("F32-D2").unwrap(), 1)
    }

    #[test]
    fn service_latency_matches_layerhw() {
        for hw in &f32d2().layers {
            let m = LstmModule::timing_only(hw);
            assert_eq!(m.service_latency(), hw.lat_t());
        }
    }

    #[test]
    fn balanced_module_has_low_intra_idle() {
        for hw in &f32d2().layers {
            let m = LstmModule::timing_only(hw);
            // Integer rounding can leave a few cycles of skew; Eq 7 keeps
            // it under one reuse quantum.
            assert!(m.intra_module_idle() < 0.35, "idle {}", m.intra_module_idle());
        }
    }

    #[test]
    fn functional_step_matches_cell_directly() {
        let dims = LayerDims { lx: 8, lh: 8 };
        let w = LayerWeights::random(dims, &mut Xoshiro256::seeded(1));
        let hw = &BalancedConfig::balance(&Topology::new(8, 2).unwrap(), 1).layers[0];
        // hw dims are 8→4 for F8-D2; build a matching hw manually instead.
        let hw = LayerHw { lx: 8, lh: 8, ..hw.clone() };
        let mut m = LstmModule::with_weights(&hw, &w);
        let x: Vec<Q8_24> = (0..8).map(|i| Q8_24::from_f64(0.05 * i as f64)).collect();
        let h1 = m.step(&x);
        // Direct cell.
        let cell = QuantLstmCell::new(&w);
        let s1 = cell.step(&QuantLstmState::zeros(8), &x);
        assert_eq!(h1, s1.h);
        // Second step uses recurrent state.
        let h2 = m.step(&x);
        let s2 = cell.step(&s1, &x);
        assert_eq!(h2, s2.h);
        // Reset clears state.
        m.reset();
        assert_eq!(m.step(&x), s1.h);
    }

    #[test]
    #[should_panic(expected = "no weights")]
    fn timing_only_cannot_step() {
        let hw = f32d2().layers[0].clone();
        LstmModule::timing_only(&hw).step(&[Q8_24::ZERO; 32]);
    }
}
