//! FPGA resource model → Table 1 (LUT/FF/BRAM/DSP % and RH_m).
//!
//! Structural counting + documented calibration (DESIGN.md §6). We do not
//! have the authors' HLS pragmas, so the model counts what the balanced
//! configuration *implies* structurally and uses constants fitted (least
//! squares over the four Table-1 rows) where the mapping is
//! toolchain-specific:
//!
//! - **DSP**: `⌈2.5 DSP per multiplier⌉` — a 32×32 Q8.24 product on
//!   DSP48E2 slices (27×24 native) needs a 2-DSP cascade plus shared
//!   correction logic amortized across the array.
//! - **BRAM**: structural max(capacity, port) per weight array — cyclic
//!   partitioning into `M` banks, two banks packed per true-dual-port
//!   BRAM36 — plus FIFO and DMA buffers. The paper's own BRAM column is
//!   non-monotone in width/depth; our structural count reproduces the
//!   F32 rows closely and underestimates the F64 rows (their RTL
//!   realization evidently replicates weights more aggressively at high
//!   reuse; we report both numbers side by side rather than inventing a
//!   fudge term).
//! - **LUT/FF**: affine model in (multipliers, datapath elements)
//!   calibrated on Table 1: control/mux/interp logic per multiplier and
//!   per vector lane.
//!
//! The *trends* the paper draws from Table 1 are asserted by tests:
//! wider models need larger RH_m to fit; depth is cheaper than width;
//! every configuration fits the XCZU7EV.

use super::platform::FpgaDevice;
use super::reuse::{div_ceil, BalancedConfig};

/// Absolute resource usage estimate.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResourceUsage {
    pub luts: u64,
    pub ffs: u64,
    pub bram36: u64,
    pub dsps: u64,
}

impl ResourceUsage {
    pub fn add(&mut self, o: ResourceUsage) {
        self.luts += o.luts;
        self.ffs += o.ffs;
        self.bram36 += o.bram36;
        self.dsps += o.dsps;
    }

    /// Utilization percentages on a device (Table-1 columns).
    pub fn pct(&self, dev: &FpgaDevice) -> ResourcePct {
        ResourcePct {
            lut: 100.0 * self.luts as f64 / dev.luts as f64,
            ff: 100.0 * self.ffs as f64 / dev.ffs as f64,
            bram: 100.0 * self.bram36 as f64 / dev.bram36 as f64,
            dsp: 100.0 * self.dsps as f64 / dev.dsps as f64,
        }
    }

    /// Does the design fit the device (≤ 100% everywhere, with a routing
    /// headroom margin on LUTs)?
    pub fn fits(&self, dev: &FpgaDevice) -> bool {
        let p = self.pct(dev);
        p.lut <= 85.0 && p.ff <= 90.0 && p.bram <= 100.0 && p.dsp <= 100.0
    }
}

/// Utilization percentages.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourcePct {
    pub lut: f64,
    pub ff: f64,
    pub bram: f64,
    pub dsp: f64,
}

impl ResourcePct {
    pub fn mean(&self) -> f64 {
        (self.lut + self.ff + self.bram + self.dsp) / 4.0
    }
}

// ---- calibration constants (DESIGN.md §6) --------------------------------

/// DSP slices per Q8.24 multiplier.
const DSP_PER_MULT: f64 = 2.5;
/// LUTs per multiplier (accumulator correction, control FSM share).
const LUT_PER_MULT: f64 = 94.0;
/// LUTs per datapath element lane (LX+LH per layer: quantize, PWL
/// interpolation, element-wise unit, FIFO handshake).
const LUT_PER_ELEM: f64 = 447.0;
/// FFs per multiplier (pipeline registers in the MAC cascade).
const FF_PER_MULT: f64 = 69.0;
/// FFs per element lane.
const FF_PER_ELEM: f64 = 202.0;
/// Static FF base (DMA engines, AXI, control).
const FF_BASE: f64 = 23_000.0;
/// Static BRAM base (DMA/AXI stream buffers).
const BRAM_BASE: u64 = 8;
/// Words per BRAM36 at 32-bit width.
const WORDS_PER_BRAM: u64 = 1_024;

/// Per-layer structural resource estimate.
pub fn layer_usage(lx: usize, lh: usize, mx: u64, mh: u64, fifo_words: u64) -> ResourceUsage {
    let mults = mx + mh;
    let elems = (lx + lh) as u64;
    // Weight storage: wx is 4·LH×LX words cyclically partitioned into MX
    // banks; wh is 4·LH×LH into MH banks. Each bank is ⌈depth/1024⌉
    // BRAM36-halves; two banks pack into one true-dual-port BRAM36.
    let wx_words = 4 * lh as u64 * lx as u64;
    let wh_words = 4 * lh as u64 * lh as u64;
    let banks = |words: u64, m: u64| -> u64 {
        let depth = div_ceil(words, m.max(1));
        m * div_ceil(depth, WORDS_PER_BRAM)
    };
    let weight_halves = banks(wx_words, mx) + banks(wh_words, mh);
    let fifo_brams = div_ceil(fifo_words, WORDS_PER_BRAM * 2); // simple dual port
    let bram = div_ceil(weight_halves, 2) + fifo_brams;
    ResourceUsage {
        luts: (LUT_PER_MULT * mults as f64 + LUT_PER_ELEM * elems as f64) as u64,
        ffs: (FF_PER_MULT * mults as f64 + FF_PER_ELEM * elems as f64) as u64,
        bram36: bram,
        dsps: (DSP_PER_MULT * mults as f64).ceil() as u64,
    }
}

/// Whole-accelerator estimate for a balanced configuration.
pub fn estimate(cfg: &BalancedConfig) -> ResourceUsage {
    let mut total = ResourceUsage { luts: 0, ffs: FF_BASE as u64, bram36: BRAM_BASE, dsps: 0 };
    let cap_timesteps = 2u64;
    for l in &cfg.layers {
        // FIFO feeding this module holds `cap` timestep-vectors of LX words.
        let fifo_words = cap_timesteps * l.lx as u64;
        total.add(layer_usage(l.lx, l.lh, l.mx, l.mh, fifo_words));
    }
    total
}

/// Pick the smallest `RH_m` whose design fits a device — the §4.1
/// procedure ("determined based on the resource constraints of the
/// target FPGA, ensuring synthesizability while maximizing exploited
/// parallelism"). Returns `(rh_m, usage)`.
pub fn min_fitting_rh_m(
    topo: &crate::model::Topology,
    dev: &FpgaDevice,
    max_rh_m: u64,
) -> Option<(u64, ResourceUsage)> {
    for rh_m in 1..=max_rh_m {
        let cfg = BalancedConfig::balance(topo, rh_m);
        let usage = estimate(&cfg);
        if usage.fits(dev) {
            return Some((rh_m, usage));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;

    fn paper_pct(topo_name: &str) -> ResourcePct {
        let topo = Topology::from_name(topo_name).unwrap();
        let cfg = BalancedConfig::paper_config(&topo);
        estimate(&cfg).pct(&FpgaDevice::ZCU104)
    }

    #[test]
    fn all_paper_configs_fit_zcu104() {
        for topo in Topology::paper_models() {
            let cfg = BalancedConfig::paper_config(&topo);
            let usage = estimate(&cfg);
            assert!(
                usage.fits(&FpgaDevice::ZCU104),
                "{} does not fit: {:?}",
                topo.name,
                usage.pct(&FpgaDevice::ZCU104)
            );
        }
    }

    #[test]
    fn dsp_pct_tracks_table1_closely() {
        // Table 1 DSP%: F32-D2 34.72, F64-D2 18.06, F32-D6 48.15, F64-D6 16.67.
        for (name, paper) in [
            ("F32-D2", 34.72),
            ("F64-D2", 18.06),
            ("F32-D6", 48.15),
            ("F64-D6", 16.67),
        ] {
            let got = paper_pct(name).dsp;
            assert!(
                (got - paper).abs() < 8.0,
                "{name}: model {got:.2}% vs paper {paper}%"
            );
        }
    }

    #[test]
    fn lut_pct_tracks_table1() {
        for (name, paper) in [
            ("F32-D2", 26.11),
            ("F64-D2", 43.04),
            ("F32-D6", 42.47),
            ("F64-D6", 69.27),
        ] {
            let got = paper_pct(name).lut;
            assert!(
                (got - paper).abs() < 10.0,
                "{name}: model {got:.2}% vs paper {paper}%"
            );
        }
    }

    #[test]
    fn width_costs_more_than_depth() {
        // §4.1: "adding depth has a less pronounced resource impact than
        // increasing input feature dimensions" — compare at equal RH_m.
        let lut = |name: &str, rh| {
            let topo = Topology::from_name(name).unwrap();
            estimate(&BalancedConfig::balance(&topo, rh)).luts
        };
        let widen = lut("F64-D2", 4) as f64 / lut("F32-D2", 4) as f64;
        let deepen = lut("F32-D6", 4) as f64 / lut("F32-D2", 4) as f64;
        assert!(widen > deepen, "widen {widen:.2}x vs deepen {deepen:.2}x");
    }

    #[test]
    fn f64_models_need_larger_rh_m_than_f32() {
        // §4.1: narrow models allow RH_m = 1, wide models are forced up.
        let dev = FpgaDevice::ZCU104;
        let fit = |name: &str| {
            min_fitting_rh_m(&Topology::from_name(name).unwrap(), &dev, 64).unwrap().0
        };
        assert!(fit("F32-D2") <= fit("F64-D2"));
        assert!(fit("F32-D6") <= fit("F64-D6"));
    }

    #[test]
    fn smaller_devices_force_larger_rh_m() {
        // F32-D2 fits the ZCU104 at RH_m = 1 but exceeds the Ultra96's
        // 360 DSPs there, forcing a higher reuse factor.
        let topo = Topology::from_name("F32-D2").unwrap();
        let zcu = min_fitting_rh_m(&topo, &FpgaDevice::ZCU104, 128).unwrap().0;
        let u96 = min_fitting_rh_m(&topo, &FpgaDevice::ULTRA96, 128).unwrap().0;
        assert_eq!(zcu, 1);
        assert!(u96 > zcu, "Ultra96 RH_m {u96} vs ZCU104 {zcu}");
        // Models whose element-lane logic alone exceeds a device never
        // fit, at any RH_m (width is the hard constraint, §4.1).
        let wide = Topology::from_name("F64-D2").unwrap();
        assert!(min_fitting_rh_m(&wide, &FpgaDevice::PYNQ_Z2, 256).is_none());
    }

    #[test]
    fn usage_monotone_decreasing_in_rh_m() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let mut prev = u64::MAX;
        for rh_m in [1u64, 2, 4, 8, 16] {
            let d = estimate(&BalancedConfig::balance(&topo, rh_m)).dsps;
            assert!(d <= prev, "DSPs should not grow with RH_m");
            prev = d;
        }
    }
}
