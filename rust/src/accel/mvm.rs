//! MVM unit model (paper §3.1): each `LSTM_i` module contains an `MVM_X`
//! and an `MVM_H` unit computing the blue/orange matrix-vector products of
//! Figure 1 across all four gates.
//!
//! A unit with `M` parallel multipliers streams `4·LH·n_in` MACs per
//! timestep, taking `⌈n_in·4·LH / M⌉` compute cycles — i.e. an effective
//! reuse factor `R = 4·LH/M` cycles per input element (Eqs 5–6) — then
//! drains `LH` cycles through the activation/element-wise pipeline
//! (the `+LH` term of Eqs 3–4). This module captures the *timing* and
//! *occupancy* view; the functional arithmetic lives in
//! [`crate::model::lstm`] (wide-MAC Q8.24), which the hardware reproduces
//! element-for-element.

use super::reuse::div_ceil;

/// Static description of one MVM unit.
#[derive(Clone, Copy, Debug)]
pub struct MvmSpec {
    /// Number of input elements consumed per timestep (LX for MVM_X,
    /// LH for MVM_H).
    pub n_in: usize,
    /// Hidden dimension LH (output rows per gate; also drain cycles).
    pub lh: usize,
    /// Parallel multipliers.
    pub multipliers: u64,
}

impl MvmSpec {
    /// Build from a multiplier count.
    pub fn with_multipliers(n_in: usize, lh: usize, multipliers: u64) -> MvmSpec {
        assert!(multipliers >= 1);
        MvmSpec { n_in, lh, multipliers }
    }

    /// Build from an integer reuse factor R (cycles per element):
    /// `M = ⌈4·LH/R⌉` (Eqs 5–6).
    pub fn new(n_in: usize, lh: usize, reuse: u64) -> MvmSpec {
        assert!(reuse >= 1);
        Self::with_multipliers(n_in, lh, div_ceil(4 * lh as u64, reuse))
    }

    /// Effective reuse factor `4·LH / M` (cycles per input element).
    pub fn reuse(&self) -> f64 {
        4.0 * self.lh as f64 / self.multipliers as f64
    }

    /// Per-timestep latency (Eqs 3–4): `⌈n_in·4·LH/M⌉ + LH`.
    pub fn latency(&self) -> u64 {
        self.compute_cycles() + self.lh as u64
    }

    /// Cycles during which the multiplier array is actually multiplying.
    pub fn compute_cycles(&self) -> u64 {
        div_ceil(self.macs(), self.multipliers)
    }

    /// Total useful MAC operations per timestep: `4 · LH · n_in`.
    pub fn macs(&self) -> u64 {
        4 * self.lh as u64 * self.n_in as u64
    }

    /// Multiplier-array efficiency during the compute phase:
    /// `macs / (multipliers · compute_cycles)` ∈ (0, 1]. Equals 1 when
    /// `M` divides `4·LH·n_in` exactly.
    pub fn multiplier_efficiency(&self) -> f64 {
        self.macs() as f64 / (self.multipliers * self.compute_cycles()) as f64
    }

    /// Fraction of a given module interval this unit is busy.
    pub fn occupancy_in(&self, module_latency: u64) -> f64 {
        self.latency() as f64 / module_latency as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::props;

    #[test]
    fn latency_eq3_eq4() {
        // MVM_X of F32-D2 layer 1: LX=16, LH=32, RX=2 → 16·2 + 32 = 64.
        let x = MvmSpec::new(16, 32, 2);
        assert_eq!(x.latency(), 64);
        // MVM_H: LH=32, RH=1 → 32·1 + 32 = 64.
        let h = MvmSpec::new(32, 32, 1);
        assert_eq!(h.latency(), 64);
    }

    #[test]
    fn multiplier_count_inverse_in_reuse() {
        assert_eq!(MvmSpec::new(32, 32, 1).multipliers, 128);
        assert_eq!(MvmSpec::new(32, 32, 4).multipliers, 32);
    }

    #[test]
    fn fractional_effective_reuse_supported() {
        // 43 multipliers on 4·LH = 64 rows → R_eff = 1.488; latency for
        // 32 elements: ⌈32·64/43⌉ + 16 = 48 + 16 = 64 (the F32-D2 layer-0
        // MVM_X case that integer-R rounding would push to 80).
        let spec = MvmSpec::with_multipliers(32, 16, 43);
        assert_eq!(spec.latency(), 64);
        assert!((spec.reuse() - 64.0 / 43.0).abs() < 1e-12);
    }

    #[test]
    fn efficiency_is_one_when_reuse_divides() {
        props("mvm_eff", 256, |g| {
            let lh = 1usize << g.usize_in(2, 7);
            let n_in = 1usize << g.usize_in(2, 7);
            let reuse = 1u64 << g.usize_in(0, 4); // divides 4·lh (pow2)
            let spec = MvmSpec::new(n_in, lh, reuse);
            assert!((spec.multiplier_efficiency() - 1.0).abs() < 1e-12);
        });
    }

    #[test]
    fn efficiency_below_one_on_ragged_counts() {
        // 4·LH = 64, R = 7 → M = ⌈64/7⌉ = 10; 16 elements → 1024 MACs,
        // ⌈1024/10⌉ = 103 cycles, eff = 1024/1030.
        let spec = MvmSpec::new(16, 16, 7);
        assert_eq!(spec.multipliers, 10);
        let eff = spec.multiplier_efficiency();
        assert!((eff - 1024.0 / 1030.0).abs() < 1e-12);
    }

    #[test]
    fn macs_match_topology_accounting() {
        use crate::model::Topology;
        for t in Topology::paper_models() {
            let total: u64 = t
                .layers
                .iter()
                .map(|l| {
                    MvmSpec::new(l.lx, l.lh, 1).macs() + MvmSpec::new(l.lh, l.lh, 1).macs()
                })
                .sum();
            assert_eq!(total, t.macs_per_timestep());
        }
    }

    #[test]
    fn latency_monotone_in_multipliers() {
        props("mvm_monotone", 128, |g| {
            let lh = g.usize_in(2, 64);
            let n_in = g.usize_in(1, 64);
            let m1 = g.u64_below(64) + 1;
            let m2 = m1 + g.u64_below(64) + 1;
            let a = MvmSpec::with_multipliers(n_in, lh, m1).latency();
            let b = MvmSpec::with_multipliers(n_in, lh, m2).latency();
            assert!(b <= a, "more multipliers must not be slower");
        });
    }
}
