//! Multi-sequence (throughput-mode) scheduling: back-to-back sequences
//! through the dataflow pipeline.
//!
//! The paper evaluates single-sequence latency; a deployment (its §1
//! motivation: continuous network-traffic / ECG monitoring) streams many
//! windows. Because each `LSTM_i` module's recurrent state must reset
//! between sequences, a module can start sequence `s+1`'s timestep 0 as
//! soon as it finished sequence `s`'s last timestep — sequences pipeline
//! across *modules* exactly like timesteps do, with no drain in between.
//!
//! Steady-state throughput is therefore `hz / (T · Lat_t_m)` sequences/s
//! — the pipeline fill is paid once per *batch*, not per sequence
//! (tested), which is the dataflow architecture's serving story.

use super::dataflow::{DataflowSim, SimOptions};
use super::latency::LatencyModel;
use super::reuse::BalancedConfig;

/// Result of streaming `n_seq` back-to-back sequences of length `t`.
#[derive(Clone, Debug)]
pub struct BatchRunResult {
    pub n_seq: usize,
    pub t: usize,
    /// Completion cycle of each sequence's last timestep.
    pub seq_done: Vec<u64>,
    pub total_cycles: u64,
}

impl BatchRunResult {
    /// Sequences per second at clock `hz`, amortized over the batch.
    pub fn throughput_seq_per_s(&self, hz: f64) -> f64 {
        self.n_seq as f64 / (self.total_cycles as f64 / hz)
    }

    /// Per-sequence latency (issue of its first timestep → completion),
    /// for sequence `s` — grows by at most fill for s = 0 then stabilizes.
    pub fn seq_latency_cycles(&self, s: usize) -> u64 {
        let issue = s as u64 * self.steady_issue_interval();
        self.seq_done[s].saturating_sub(issue)
    }

    fn steady_issue_interval(&self) -> u64 {
        if self.n_seq < 2 {
            self.seq_done[0]
        } else {
            self.seq_done[self.n_seq - 1].saturating_sub(self.seq_done[self.n_seq - 2])
        }
    }
}

/// Simulate `n_seq` sequences streamed back-to-back: equivalent to one
/// long sequence of `n_seq · t` timesteps whose outputs are grouped per
/// sequence (state reset is a zero-cost mux on the FPGA — the module is
/// busy `Lat_t` regardless; the reader just tags sequence boundaries).
pub fn run_batch(cfg: &BalancedConfig, opts: SimOptions, t: usize, n_seq: usize) -> BatchRunResult {
    assert!(t >= 1 && n_seq >= 1);
    let run = DataflowSim::with_options(cfg, opts).run_sequence(t * n_seq);
    let seq_done: Vec<u64> =
        (0..n_seq).map(|s| run.output_times[(s + 1) * t - 1]).collect();
    BatchRunResult { n_seq, t, seq_done, total_cycles: run.total_cycles }
}

/// Analytical steady-state throughput (sequences/s).
pub fn steady_throughput(cfg: &BalancedConfig, t: usize, hz: f64) -> f64 {
    let lm = LatencyModel::of(cfg);
    hz / (t as u64 * lm.lat_t_m()) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Topology;

    fn cfg() -> BalancedConfig {
        BalancedConfig::paper_config(&Topology::from_name("F32-D6").unwrap())
    }

    #[test]
    fn fill_paid_once_per_batch() {
        let cfg = cfg();
        let lm = LatencyModel::of(&cfg);
        let t = 16;
        let single = lm.acc_lat(t);
        let batch = run_batch(&cfg, SimOptions::default(), t, 8);
        // 8 sequences take far less than 8 independent runs.
        assert!(batch.total_cycles < 8 * single);
        // Exactly: fill + 8·T·bottleneck.
        assert_eq!(batch.total_cycles, lm.acc_lat(8 * t));
    }

    #[test]
    fn throughput_approaches_analytical_steady_state() {
        let cfg = cfg();
        let hz = 300.0e6;
        let t = 16;
        let analytical = steady_throughput(&cfg, t, hz);
        let measured = run_batch(&cfg, SimOptions::default(), t, 64).throughput_seq_per_s(hz);
        let rel = (measured - analytical).abs() / analytical;
        assert!(rel < 0.05, "measured {measured:.1} vs analytical {analytical:.1}");
    }

    #[test]
    fn sequence_completions_evenly_spaced_in_steady_state() {
        let cfg = cfg();
        let lm = LatencyModel::of(&cfg);
        let t = 8;
        let batch = run_batch(&cfg, SimOptions::default(), t, 16);
        let spacing: Vec<u64> =
            batch.seq_done.windows(2).map(|w| w[1] - w[0]).collect();
        for s in spacing.iter().skip(1) {
            assert_eq!(*s, t as u64 * lm.lat_t_m());
        }
    }

    #[test]
    fn single_sequence_degenerates_to_acc_lat() {
        let cfg = cfg();
        let lm = LatencyModel::of(&cfg);
        let b = run_batch(&cfg, SimOptions::default(), 16, 1);
        assert_eq!(b.total_cycles, lm.acc_lat(16));
        assert_eq!(b.seq_latency_cycles(0), lm.acc_lat(16));
    }
}
