//! Cycle-accurate dataflow simulator (paper §3.1–3.2).
//!
//! Architecture simulated: `DataReader → LSTM_0 → … → LSTM_{N−1} →
//! DataWriter`, every arrow a bounded FIFO of timestep-vector tokens.
//! Module semantics (matching Eq 1's fill accounting): a module pops a
//! complete `x_t` vector, is busy `Lat_t_i` cycles (MVM_X ∥ MVM_H + the
//! activation drain), then pushes `h_t` downstream — blocking after
//! service if the FIFO is full.
//!
//! The simulator evaluates the exact **max-plus recurrence** of that
//! discrete-event system (service times are constant, so the recurrence
//! *is* the DES — [`super::stepped`] validates this cycle-by-cycle):
//!
//! ```text
//! start_i(t) = max(push_{i−1}(t), push_i(t−1))
//! fin_i(t)   = start_i(t) + Lat_t_i
//! push_i(t)  = max(fin_i(t), start_{i+1}(t − C_{i+1}))   // backpressure
//! ```
//!
//! With adequate FIFOs and a balanced config, `push_{N−1}(T−1)` equals
//! the paper's Eq 1 exactly (integration-tested).

use super::reuse::BalancedConfig;
use crate::model::lstm::QuantLstmCell;
use crate::model::ModelWeights;

/// Simulation options.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Capacity, in timestep-vectors, of every inter-module FIFO.
    pub fifo_capacity: usize,
    /// DataReader cycles to deliver one timestep (0 = DMA fully
    /// overlapped, the paper's Eq-1 idealization; `LX_0` models a
    /// 1-word/cycle stream).
    pub reader_cycles_per_t: u64,
    /// DataWriter cycles to drain one timestep.
    pub writer_cycles_per_t: u64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { fifo_capacity: 2, reader_cycles_per_t: 0, writer_cycles_per_t: 0 }
    }
}

/// Per-module statistics from a simulated run.
#[derive(Clone, Debug)]
pub struct ModuleStats {
    /// Constant service latency (cycles).
    pub service: u64,
    /// Total cycles busy computing (T · service).
    pub busy: u64,
    /// Cycles spent waiting for input after being free (starvation).
    pub starved: u64,
    /// Cycles spent blocked pushing output (backpressure).
    pub blocked: u64,
    /// Busy / (busy + starved + blocked + lead-in) over the module's
    /// active window.
    pub utilization: f64,
}

/// Result of simulating one sequence.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Total cycles from t=0 issue to the last output timestep pushed.
    pub total_cycles: u64,
    /// Cycle at which each output timestep left the last module.
    pub output_times: Vec<u64>,
    pub per_module: Vec<ModuleStats>,
    /// Steady-state initiation interval observed (cycles between the last
    /// two outputs) — equals `Lat_t_m` when the pipeline is healthy.
    pub steady_ii: u64,
}

impl RunResult {
    pub fn total_ms(&self, hz: f64) -> f64 {
        crate::cycles_to_ms(self.total_cycles, hz)
    }

    /// Aggregate utilization across modules (resource-weighted by service
    /// time — the quantity dataflow balancing maximizes).
    pub fn mean_utilization(&self) -> f64 {
        let n = self.per_module.len() as f64;
        self.per_module.iter().map(|m| m.utilization).sum::<f64>() / n
    }
}

/// The dataflow accelerator simulator.
pub struct DataflowSim {
    pub cfg: BalancedConfig,
    pub opts: SimOptions,
    service: Vec<u64>,
}

impl DataflowSim {
    pub fn new(cfg: &BalancedConfig) -> DataflowSim {
        Self::with_options(cfg, SimOptions::default())
    }

    pub fn with_options(cfg: &BalancedConfig, opts: SimOptions) -> DataflowSim {
        let service = cfg.layers.iter().map(|l| l.lat_t()).collect();
        DataflowSim { cfg: cfg.clone(), opts, service }
    }

    /// Simulate the timing of one sequence of `t` timesteps.
    ///
    /// The recurrence only ever references timestep `ts − 1` (same
    /// module) and `ts − cap` (downstream start), so the state is kept in
    /// a rolling window of `cap + 1` columns instead of full `N × T`
    /// tables — O(N·cap) memory, cache-resident for any T, with
    /// per-module stall statistics accumulated inline. (This replaced
    /// the original full-table implementation after profiling showed the
    /// tables falling out of L2 beyond T ≈ 10⁴; see EXPERIMENTS.md §Perf.)
    pub fn run_sequence(&self, t: usize) -> RunResult {
        assert!(t >= 1);
        let n = self.service.len();
        let cap = self.opts.fifo_capacity.max(1);
        let window = cap + 1;
        // Rolling columns indexed by ts % window.
        let mut start_w = vec![0u64; n * window];
        let mut push_w = vec![0u64; n * window];
        let mut output_times = Vec::with_capacity(t);
        // Inline stats.
        let mut starved = vec![0u64; n];
        let mut blocked = vec![0u64; n];
        let mut first_start = vec![0u64; n];
        let mut last_push = vec![0u64; n];
        for ts in 0..t {
            let col = ts % window;
            let prev_col = (ts + window - 1) % window; // ts − 1
            let back_col = (ts + window - cap) % window; // ts − cap
            for i in 0..n {
                // Input availability: reader (i = 0) or upstream push
                // (current column — module i−1 already updated this ts).
                let ready = if i == 0 {
                    self.opts.reader_cycles_per_t * (ts as u64 + 1)
                } else {
                    push_w[(i - 1) * window + col]
                };
                // Module frees after its previous push completes.
                let free = if ts == 0 { 0 } else { push_w[i * window + prev_col] };
                let s = ready.max(free);
                let fin = s + self.service[i];
                // Backpressure: the slot in the downstream FIFO frees when
                // the consumer *starts* timestep ts − cap.
                let p = if i + 1 < n {
                    if ts >= cap {
                        fin.max(start_w[(i + 1) * window + back_col])
                    } else {
                        fin
                    }
                } else {
                    // DataWriter drains at its own rate.
                    fin.max(self.opts.writer_cycles_per_t * (ts as u64 + 1))
                };
                if ts > 0 {
                    starved[i] += s.saturating_sub(push_w[i * window + prev_col]);
                } else {
                    first_start[i] = s;
                }
                blocked[i] += p - fin;
                last_push[i] = p;
                start_w[i * window + col] = s;
                push_w[i * window + col] = p;
            }
            output_times.push(push_w[(n - 1) * window + col]);
        }
        let total_cycles = output_times[t - 1];
        let steady_ii = if t >= 2 {
            output_times[t - 1] - output_times[t - 2]
        } else {
            self.service[n - 1]
        };
        let per_module = (0..n)
            .map(|i| {
                let service = self.service[i];
                let busy = service * t as u64;
                let win = last_push[i] - first_start[i];
                let utilization =
                    if win == 0 { 1.0 } else { (busy as f64 / win as f64).min(1.0) };
                ModuleStats {
                    service,
                    busy,
                    starved: starved[i],
                    blocked: blocked[i],
                    utilization,
                }
            })
            .collect();
        RunResult { total_cycles, output_times, per_module, steady_ii }
    }

    /// Simulate timing *and* compute the functional output through the
    /// bit-accurate Q8.24 datapath. `x` is `[T][F]` on the fixed-point
    /// grid; returns (timing, reconstruction `[T][F]`).
    pub fn run_with_data(
        &self,
        weights: &ModelWeights,
        x: &[Vec<f32>],
    ) -> (RunResult, Vec<Vec<f32>>) {
        weights.validate(&self.cfg.topo).expect("weights match topology");
        let timing = self.run_sequence(x.len());
        // Functional pass: module-by-module streaming, same order the
        // hardware computes (timing and function are independent — the
        // datapath is data-oblivious). Runs on the engine's scratch path:
        // the original per-step `state.h.clone()` churn is gone, rows are
        // rewritten in place with reused state/pre-activation buffers
        // (EXPERIMENTS.md §Perf), and the output is bit-identical.
        let cells: Vec<QuantLstmCell> =
            weights.layers.iter().map(QuantLstmCell::new).collect();
        let mut seq = crate::engine::quantize_window(x);
        crate::engine::forward_in_place(&cells, &mut seq);
        (timing, crate::engine::dequantize_window(seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::latency::LatencyModel;
    use crate::model::Topology;
    use crate::util::prop::props;

    #[test]
    fn matches_eq1_for_all_paper_models() {
        for topo in Topology::paper_models() {
            let rh_m = BalancedConfig::paper_rh_m(&topo.name).unwrap();
            let cfg = BalancedConfig::balance(&topo, rh_m);
            let lm = LatencyModel::of(&cfg);
            let sim = DataflowSim::new(&cfg);
            for t in [1usize, 2, 4, 6, 16, 64] {
                assert_eq!(
                    sim.run_sequence(t).total_cycles,
                    lm.acc_lat(t),
                    "{} T={t}",
                    topo.name
                );
            }
        }
    }

    #[test]
    fn matches_eq1_for_random_balanced_configs() {
        props("sim_eq1", 64, |g| {
            let f = 1usize << g.usize_in(3, 6);
            let d = 2 * g.usize_in(1, 3);
            let Ok(topo) = Topology::new(f, d) else { return };
            let cfg = BalancedConfig::balance(&topo, g.u64_below(8) + 1);
            let lm = LatencyModel::of(&cfg);
            let t = g.usize_in(1, 128);
            let sim = DataflowSim::new(&cfg);
            assert_eq!(sim.run_sequence(t).total_cycles, lm.acc_lat(t));
        });
    }

    #[test]
    fn steady_ii_equals_bottleneck() {
        let topo = Topology::from_name("F64-D6").unwrap();
        let cfg = BalancedConfig::balance(&topo, 8);
        let lm = LatencyModel::of(&cfg);
        let run = DataflowSim::new(&cfg).run_sequence(32);
        assert_eq!(run.steady_ii, lm.lat_t_m());
    }

    #[test]
    fn unbalanced_config_shows_stalls_and_lower_utilization() {
        let topo = Topology::from_name("F32-D6").unwrap();
        let bal = DataflowSim::new(&BalancedConfig::balance(&topo, 1)).run_sequence(64);
        let uni = DataflowSim::new(&BalancedConfig::uniform(&topo, 1)).run_sequence(64);
        assert!(bal.mean_utilization() > 0.9, "balanced util {}", bal.mean_utilization());
        assert!(
            uni.mean_utilization() < bal.mean_utilization(),
            "uniform {} vs balanced {}",
            uni.mean_utilization(),
            bal.mean_utilization()
        );
        // The uniform config starves the small middle layers.
        let total_starved: u64 = uni.per_module.iter().map(|m| m.starved).sum();
        assert!(total_starved > 0);
    }

    #[test]
    fn tiny_fifo_capacity_cannot_beat_unbounded() {
        props("fifo_monotone", 48, |g| {
            let topo = g.choose(&Topology::paper_models()).clone();
            // Unbalanced on purpose so backpressure matters.
            let cfg = BalancedConfig::uniform(&topo, g.u64_below(4) + 1);
            let t = g.usize_in(2, 64);
            let small = DataflowSim::with_options(
                &cfg,
                SimOptions { fifo_capacity: 1, ..Default::default() },
            )
            .run_sequence(t);
            let big = DataflowSim::with_options(
                &cfg,
                SimOptions { fifo_capacity: 1024, ..Default::default() },
            )
            .run_sequence(t);
            assert!(small.total_cycles >= big.total_cycles);
        });
    }

    #[test]
    fn reader_rate_shifts_but_does_not_bottleneck() {
        let topo = Topology::from_name("F32-D2").unwrap();
        let cfg = BalancedConfig::balance(&topo, 1);
        let lm = LatencyModel::of(&cfg);
        // 1 word/cycle reader: LX_0 = 32 cycles per timestep < Lat_t = 64.
        let run = DataflowSim::with_options(
            &cfg,
            SimOptions { reader_cycles_per_t: 32, ..Default::default() },
        )
        .run_sequence(64);
        // Reader adds at most its first-timestep delivery to the total.
        assert!(run.total_cycles >= lm.acc_lat(64));
        assert!(run.total_cycles <= lm.acc_lat(64) + 32);
        assert_eq!(run.steady_ii, lm.lat_t_m());
    }

    #[test]
    fn output_times_monotone_spaced_by_at_least_bottleneck() {
        let topo = Topology::from_name("F64-D2").unwrap();
        let cfg = BalancedConfig::balance(&topo, 4);
        let run = DataflowSim::new(&cfg).run_sequence(32);
        let lm = LatencyModel::of(&cfg);
        for w in run.output_times.windows(2) {
            assert!(w[1] > w[0]);
            assert!(w[1] - w[0] >= lm.lat_t[lm.lat_t.len() - 1].min(lm.lat_t_m()));
        }
    }

    #[test]
    fn functional_output_matches_golden_quant_model() {
        use crate::model::{LstmAutoencoder, ModelWeights};
        let topo = Topology::from_name("F32-D2").unwrap();
        let weights = ModelWeights::random(&topo, 11);
        let cfg = BalancedConfig::balance(&topo, 1);
        let sim = DataflowSim::new(&cfg);
        let mut rng = crate::util::rng::Xoshiro256::seeded(5);
        let x: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..32).map(|_| rng.uniform(-1.0, 1.0) as f32).collect())
            .collect();
        let (_, sim_out) = sim.run_with_data(&weights, &x);
        let ae = LstmAutoencoder::new(topo, weights).unwrap();
        let golden = ae.forward_quant(&x);
        assert_eq!(sim_out, golden, "simulator functional path == golden Q8.24 model");
    }
}
