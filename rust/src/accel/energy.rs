//! Platform power and energy models → Table 3 (energy per timestep, mJ).
//!
//! The paper computes energy-per-timestep as `P · latency / T` from
//! wall-power measurements: FPGA 11–12 W, CPU 255–265 W, GPU 35–40 W
//! (§4.2). We substitute a resource-proportional FPGA power model that
//! lands in the paper's measured band, and the paper's reported constants
//! for CPU/GPU (DESIGN.md §6):
//!
//! ```text
//! P_fpga = P_STATIC + P_DYN_SCALE · mean_utilization
//! ```
//!
//! with `P_STATIC = 9 W` (MPSoC PS + idle PL + board) and
//! `P_DYN_SCALE = 8 W` at 300 MHz — giving 11.3 W for LSTM-AE-F32-D2 and
//! 12.4 W for LSTM-AE-F64-D6, matching the 11–12 W the paper reports.

use super::platform::FpgaDevice;
use super::resources::ResourcePct;

/// Idle + board power of the MPSoC platform (W).
pub const FPGA_STATIC_W: f64 = 9.0;
/// Dynamic power at 100% mean resource utilization, 300 MHz (W).
pub const FPGA_DYN_SCALE_W: f64 = 8.0;
/// Paper's CPU package power band midpoint (Xeon Gold 5218R under
/// PyTorch inference: 255–265 W reported).
pub const CPU_POWER_W: f64 = 260.0;
/// Paper's GPU board power band midpoint (V100: 35–40 W reported for
/// these small models).
pub const GPU_POWER_W: f64 = 37.5;

/// FPGA power for a design with the given utilization, scaled by clock
/// relative to the 300 MHz calibration point.
pub fn fpga_power_w(pct: &ResourcePct, dev: &FpgaDevice) -> f64 {
    let clock_scale = dev.clock_hz / 300.0e6;
    FPGA_STATIC_W + FPGA_DYN_SCALE_W * (pct.mean() / 100.0) * clock_scale
}

/// Energy per timestep in millijoules: `P(W) · latency(ms) / T` —
/// W·ms = mJ, the paper's Table-3 unit.
pub fn energy_per_timestep_mj(power_w: f64, latency_ms: f64, t: usize) -> f64 {
    assert!(t >= 1);
    power_w * latency_ms / t as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::resources::estimate;
    use crate::accel::reuse::BalancedConfig;
    use crate::model::Topology;

    #[test]
    fn fpga_power_in_paper_band() {
        for topo in Topology::paper_models() {
            let cfg = BalancedConfig::paper_config(&topo);
            let pct = estimate(&cfg).pct(&FpgaDevice::ZCU104);
            let p = fpga_power_w(&pct, &FpgaDevice::ZCU104);
            assert!(
                (10.0..=13.5).contains(&p),
                "{}: {p:.1} W outside the paper's 11-12 W band (±1.5)",
                topo.name
            );
        }
    }

    #[test]
    fn energy_unit_conversion() {
        // 11 W × 0.033 ms / 1 timestep = 0.363 mJ (paper's F32-D2 T=1 row
        // is 0.362 — same arithmetic).
        let e = energy_per_timestep_mj(11.0, 0.033, 1);
        assert!((e - 0.363).abs() < 1e-9);
    }

    #[test]
    fn energy_decreases_with_sequence_length_at_fixed_slope() {
        // Affine latency in T ⇒ energy/timestep strictly decreases in T.
        let cfg = BalancedConfig::paper_config(&Topology::from_name("F32-D2").unwrap());
        let lm = crate::accel::latency::LatencyModel::of(&cfg);
        let pct = estimate(&cfg).pct(&FpgaDevice::ZCU104);
        let p = fpga_power_w(&pct, &FpgaDevice::ZCU104);
        let mut prev = f64::INFINITY;
        for t in [1usize, 2, 4, 6, 16, 64] {
            let e = energy_per_timestep_mj(p, lm.acc_lat_ms(t, 300.0e6), t);
            assert!(e < prev, "T={t}: {e} !< {prev}");
            prev = e;
        }
    }

    #[test]
    fn platform_power_ordering() {
        assert!(CPU_POWER_W > GPU_POWER_W);
        let pct = ResourcePct { lut: 30.0, ff: 15.0, bram: 40.0, dsp: 35.0 };
        assert!(GPU_POWER_W > fpga_power_w(&pct, &FpgaDevice::ZCU104));
    }
}
